//! Persistent scoped thread pool — std-only (rayon/crossbeam are
//! unavailable offline).
//!
//! The consensus epoch loop runs thousands of rounds; spawning OS threads
//! per round would dominate the per-round cost at Table-1 shapes.  The
//! pool keeps its workers alive for the engine's lifetime and hands out
//! *scopes*: [`ThreadPool::scope`] lets callers spawn closures that borrow
//! non-`'static` data (partition slices, workspace buffers) and guarantees
//! every spawned job has finished before `scope` returns — the same
//! contract as `std::thread::scope`, without re-spawning threads.
//!
//! Soundness of the lifetime-erasing transmute in [`Scope::spawn`] rests
//! on exactly two invariants, both enforced here:
//!
//! 1. `scope` does not return (even by panic — see [`WaitGuard`]) until
//!    the pending-job count is zero, so borrows can never dangle;
//! 2. `'env` is a free lifetime parameter of `scope`, so the borrow
//!    checker rejects spawning closures that borrow locals of the scope
//!    body itself (a free region is required to outlive the closure).
//!
//! This is the crossbeam-utils `scope` design reduced to what the engine
//! needs.

use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::obs::{self, Counter, Histogram};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Pool metric handles (`pool.queue_wait_ns` / `pool.run_ns` /
/// `pool.jobs`), resolved from the global registry once at pool
/// construction and cloned into every scope — recording is lock-free and
/// a no-op while metrics are disabled.
#[derive(Clone)]
struct PoolObs {
    queue_wait_ns: Arc<Histogram>,
    run_ns: Arc<Histogram>,
    jobs: Arc<Counter>,
}

impl PoolObs {
    fn new() -> Self {
        Self {
            queue_wait_ns: obs::histogram("pool.queue_wait_ns"),
            run_ns: obs::histogram("pool.run_ns"),
            jobs: obs::counter("pool.jobs"),
        }
    }
}

/// Persistent worker pool; cheap to share behind an `Arc`.
pub struct ThreadPool {
    /// `Mutex` (not bare `Sender`) so the pool is `Sync` on every
    /// supported toolchain; spawning locks it briefly per job.
    injector: Mutex<Option<Sender<Job>>>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    size: usize,
    obs: PoolObs,
}

impl ThreadPool {
    /// Spawn a pool with `threads` workers; `0` means one per available
    /// hardware thread.
    pub fn new(threads: usize) -> Self {
        let size = if threads == 0 {
            default_threads()
        } else {
            threads
        };
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("dapc-pool-{i}"))
                    .spawn(move || worker_loop(&rx))
                    .expect("spawn pool worker"),
            );
        }
        Self {
            injector: Mutex::new(Some(tx)),
            workers: Mutex::new(workers),
            size,
            obs: PoolObs::new(),
        }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Run `f` with a [`Scope`]; every job spawned on the scope completes
    /// before this returns.  Panics from jobs are re-raised here (after
    /// all sibling jobs finish) so failures are not silently swallowed.
    pub fn scope<'env, F, R>(&self, f: F) -> R
    where
        F: FnOnce(&Scope<'env>) -> R,
    {
        let tx = self
            .injector
            .lock()
            .expect("pool injector poisoned")
            .as_ref()
            .expect("pool is shut down")
            .clone();
        let pending = Arc::new(Pending::default());
        let scope =
            Scope { tx, pending, obs: self.obs.clone(), _env: PhantomData };
        let guard = WaitGuard(&scope.pending);
        let out = f(&scope);
        drop(guard); // blocks until pending == 0, panic-safe
        if scope.pending.panicked.load(Ordering::SeqCst) {
            panic!("dapc thread pool: a scoped job panicked");
        }
        out
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // closing the channel ends every worker's recv loop
        if let Ok(mut inj) = self.injector.lock() {
            inj.take();
        }
        if let Ok(mut workers) = self.workers.lock() {
            for h in workers.drain(..) {
                let _ = h.join();
            }
        }
    }
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool").field("size", &self.size).finish()
    }
}

/// One hardware thread per worker by default (capped: the consensus round
/// fans out over J <= a few dozen partitions; more threads only add
/// wakeup latency).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(1, 64)
}

fn worker_loop(rx: &Mutex<Receiver<Job>>) {
    loop {
        // hold the lock only while dequeuing, never while running a job
        let job = match rx.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => return,
        };
        match job {
            Ok(job) => job(),
            Err(_) => return, // channel closed: pool dropped
        }
    }
}

/// Outstanding-job counter a scope waits on.
#[derive(Default)]
struct Pending {
    count: Mutex<usize>,
    zero: Condvar,
    panicked: AtomicBool,
}

impl Pending {
    fn inc(&self) {
        *self.count.lock().expect("pending poisoned") += 1;
    }

    fn dec(&self) {
        let mut c = self.count.lock().expect("pending poisoned");
        *c -= 1;
        if *c == 0 {
            self.zero.notify_all();
        }
    }

    fn wait_zero(&self) {
        let mut c = self.count.lock().expect("pending poisoned");
        while *c > 0 {
            c = self.zero.wait(c).expect("pending poisoned");
        }
    }
}

/// Waits for the scope's jobs even when the scope body unwinds — the
/// borrows held by in-flight jobs must not outlive the caller's frame.
struct WaitGuard<'a>(&'a Arc<Pending>);

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        self.0.wait_zero();
    }
}

/// Spawn handle passed to the closure given to [`ThreadPool::scope`].
pub struct Scope<'env> {
    tx: Sender<Job>,
    pending: Arc<Pending>,
    obs: PoolObs,
    /// Invariant over `'env` (mirrors `std::thread::Scope`).
    _env: PhantomData<&'env mut &'env ()>,
}

impl<'env> Scope<'env> {
    /// Queue `f` on the pool.  `f` may borrow anything that outlives the
    /// enclosing `scope` call; it runs on an arbitrary pool worker.
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'env,
    {
        self.pending.inc();
        let pending = Arc::clone(&self.pending);
        let pobs = self.obs.clone();
        let enqueued = obs::now();
        let job: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
            // queue wait = enqueue -> a worker actually picks the job up
            obs::record_since(&pobs.queue_wait_ns, enqueued);
            let started = obs::now();
            let result =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
            obs::record_since(&pobs.run_ns, started);
            pobs.jobs.inc();
            if result.is_err() {
                pending.panicked.store(true, Ordering::SeqCst);
            }
            pending.dec();
        });
        // SAFETY: the job is only erased to 'static, never extended in
        // use: `scope` (via WaitGuard even on unwind) blocks until this
        // job has run to completion, so every borrow in `f` is live for
        // the job's whole execution.  Box<dyn FnOnce> has identical
        // layout regardless of the trait object's lifetime bound.
        let job: Job = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(job)
        };
        self.tx.send(job).expect("pool workers are gone");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn scope_runs_all_jobs_and_waits() {
        let pool = ThreadPool::new(4);
        let counter = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..64 {
                s.spawn(|| {
                    counter.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        // scope returned => every job observed complete
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn jobs_can_borrow_and_mutate_disjoint_slices() {
        let pool = ThreadPool::new(3);
        let mut data = vec![0usize; 10];
        pool.scope(|s| {
            for (i, slot) in data.iter_mut().enumerate() {
                s.spawn(move || {
                    *slot = i * i;
                });
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i * i);
        }
    }

    #[test]
    fn scope_reusable_and_returns_value() {
        let pool = ThreadPool::new(2);
        let mut out = [0usize; 2];
        for round in 0..5 {
            // borrows must come from outside the scope body
            let (a, b) = out.split_at_mut(1);
            let (a0, b0) = (&mut a[0], &mut b[0]);
            let got = pool.scope(|s| {
                s.spawn(move || *a0 = round);
                s.spawn(move || *b0 = round + 1);
                42
            });
            assert_eq!(got, 42);
            assert_eq!(out, [round, round + 1]);
        }
    }

    #[test]
    fn empty_scope_is_fine() {
        let pool = ThreadPool::new(1);
        let v = pool.scope(|_| 7);
        assert_eq!(v, 7);
    }

    #[test]
    fn worker_panic_propagates_to_scope_caller() {
        let pool = ThreadPool::new(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || {
                pool.scope(|s| {
                    s.spawn(|| panic!("boom"));
                });
            },
        ));
        assert!(caught.is_err());
        // the pool survives a job panic
        let ok = AtomicUsize::new(0);
        pool.scope(|s| {
            s.spawn(|| {
                ok.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(ok.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn zero_threads_means_auto() {
        let pool = ThreadPool::new(0);
        assert!(pool.size() >= 1);
    }
}
