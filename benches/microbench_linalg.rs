//! Microbenchmarks of the native linalg primitives — the L3 profile
//! baseline for the §Perf optimization pass (gemm/gemv dominate the
//! consensus epochs; QR dominates init).
//!
//! Since the SIMD dispatch layer (`linalg::simd`) every vector kernel is
//! benched **per backend**: the lane-structured scalar fallback vs the
//! AVX2+FMA path (when the CPU has it), on identical inputs.  The two
//! are bit-identical by contract, so any delta between the lines is
//! pure throughput — that comparison is the evidence the ROADMAP's
//! "explicit SIMD" lever asks for, and it lands in
//! `BENCH_microbench_linalg.json` (kernel/backend/n fields per record)
//! which CI validates and uploads.  Timing *ratios* are deliberately
//! not asserted here: shared CI runners jitter too much for a hard
//! gate, and the JSON keeps the trajectory reviewable instead.
//!
//! Three gemm comparison lines ride along for the raw-speed tier: the
//! packed trailing-sweep gemm vs the column-separable per-column dots it
//! replaced (at the QR sweep shape), tier-0 vs the opt-in tier-1 FMA
//! microkernel on identical inputs, and the direct-vs-packed small-`n`
//! crossover that the per-shape `GemmPath::Auto` dispatch encodes.  The
//! wide (f64-accumulating) microkernel of the prepacked epoch path gets
//! the same treatment: GFLOP/s per backend and tier vs the widened
//! row-dot oracle it is bitwise-equal to, on identical inputs.

use dapc::benchkit::{black_box, quick_mode, Bench, BenchResult, JsonReport};
use dapc::linalg::simd::{self, Backend, KernelTier, MR, NR};
use dapc::linalg::{blas, inverse, qr, triangular, Matrix};
use dapc::rng::seeded;

fn randm(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut g = seeded(seed);
    Matrix::from_fn(rows, cols, |_, _| g.normal_f32())
}

fn randv(len: usize, seed: u64) -> Vec<f32> {
    let mut g = seeded(seed);
    (0..len).map(|_| g.normal_f32()).collect()
}

fn speedup_line(kernel: &str, n: usize, per_backend: &[(Backend, BenchResult)]) {
    if let (Some(s), Some(a)) = (
        per_backend.iter().find(|(b, _)| *b == Backend::Scalar),
        per_backend.iter().find(|(b, _)| *b == Backend::Avx2Fma),
    ) {
        println!(
            "  -> {kernel} {n}: avx2+fma {:.2}x vs scalar",
            s.1.stats.median() / a.1.stats.median().max(1e-12)
        );
    }
}

fn main() {
    let bench = Bench::default();
    let mut report = JsonReport::new("microbench_linalg");
    let active = simd::active();

    println!("=== linalg microbenches ===");
    println!("kernel dispatch: {}", simd::description());

    // -----------------------------------------------------------------
    // Vector kernels, per backend (dot / dot_wide / axpy)
    // -----------------------------------------------------------------
    let lens: &[usize] = if quick_mode() { &[4096] } else { &[1024, 4096, 65536] };
    for &n in lens {
        let x = randv(n, 11);
        let y = randv(n, 12);
        let mut xw = vec![0.0f64; n];
        blas::widen(&x, &mut xw);

        let mut dots = Vec::new();
        for &b in &simd::available() {
            let res = bench.run(&format!("dot         {n} [{}]", b.name()), || {
                black_box(simd::dot_on(b, &x, &y));
            });
            report.add(
                &res,
                &[("n", n as f64)],
                &[("kernel", "dot"), ("backend", b.name())],
            );
            dots.push((b, res));
        }
        speedup_line("dot", n, &dots);

        let mut wides = Vec::new();
        for &b in &simd::available() {
            let res = bench.run(&format!("dot_wide    {n} [{}]", b.name()), || {
                black_box(simd::dot_wide_on(b, &xw, &y));
            });
            report.add(
                &res,
                &[("n", n as f64)],
                &[("kernel", "dot_wide"), ("backend", b.name())],
            );
            wides.push((b, res));
        }
        speedup_line("dot_wide", n, &wides);

        let mut axpys = Vec::new();
        for &b in &simd::available() {
            let mut acc = y.clone();
            let res = bench.run(&format!("axpy        {n} [{}]", b.name()), || {
                simd::axpy_on(b, 1e-4, &x, &mut acc);
                black_box(acc[0]);
            });
            report.add(
                &res,
                &[("n", n as f64)],
                &[("kernel", "axpy"), ("backend", b.name())],
            );
            axpys.push((b, res));
        }
        speedup_line("axpy", n, &axpys);
        println!();
    }

    // -----------------------------------------------------------------
    // The gemm register microkernel, per backend (the packing around it
    // is backend-independent, so this isolates exactly what dispatches)
    // -----------------------------------------------------------------
    let kc = 256; // the KC default in blas.rs
    let reps = 10_000; // 2*kc*MR*NR flops per call is too brief to time alone
    let ap = randv(kc * MR, 21);
    let bp = randv(kc * NR, 22);
    let mut micro = Vec::new();
    for &b in &simd::available() {
        let mut acc = [[0.0f32; NR]; MR];
        let res = bench.run(&format!("microkernel kc={kc} x{reps} [{}]", b.name()), || {
            for _ in 0..reps {
                simd::microkernel_on(b, kc, &ap, &bp, &mut acc);
            }
            black_box(acc[0][0]);
        });
        let gflops = (2 * kc * MR * NR * reps) as f64 / res.stats.median() / 1e9;
        println!("  -> microkernel [{}]: {gflops:.2} GFLOP/s", b.name());
        report.add(
            &res,
            &[("kc", kc as f64), ("reps", reps as f64), ("gflops", gflops)],
            &[("kernel", "microkernel"), ("backend", b.name())],
        );
        micro.push((b, res));
    }
    speedup_line("microkernel", kc, &micro);
    println!();

    // -----------------------------------------------------------------
    // The wide (f64-accumulating) microkernel of the prepacked epoch
    // path vs the row-dot oracle it replaced, on identical inputs: the
    // baseline widens each A row and runs NR dot_wide calls per tile,
    // exactly as the epoch loop did before prepacked panels.  Per
    // backend, with the tier-1 fused line riding along.
    // -----------------------------------------------------------------
    let mut rows_a = vec![vec![0.0f32; kc]; MR];
    for (p, tile) in ap.chunks_exact(MR).enumerate() {
        for (row, &v) in rows_a.iter_mut().zip(tile) {
            row[p] = v;
        }
    }
    let mut cols_b = vec![vec![0.0f32; kc]; NR];
    for (p, panel) in bp.chunks_exact(NR).enumerate() {
        for (col, &v) in cols_b.iter_mut().zip(panel) {
            col[p] = v;
        }
    }
    let wide_flops = (2 * kc * MR * NR * reps) as f64;
    for &b in &simd::available() {
        let mut wrow = vec![0.0f64; kc];
        let mut out = [[0.0f64; NR]; MR];
        let base_res = bench.run(&format!("wide row-dot kc={kc} x{reps} [{}]", b.name()), || {
            for _ in 0..reps {
                for (row, o) in rows_a.iter().zip(out.iter_mut()) {
                    blas::widen(row, &mut wrow);
                    for (col, oj) in cols_b.iter().zip(o.iter_mut()) {
                        *oj = simd::dot_wide_on(b, &wrow, col);
                    }
                }
            }
            black_box(out[0][0]);
        });
        let base_gflops = wide_flops / base_res.stats.median() / 1e9;
        report.add(
            &base_res,
            &[("kc", kc as f64), ("reps", reps as f64), ("gflops", base_gflops)],
            &[("kernel", "wide_row_dot"), ("backend", b.name())],
        );
        let mut tier_med = Vec::new();
        for (label, tier) in [("t0", KernelTier::Deterministic), ("t1", KernelTier::Fast)] {
            let res = bench.run(
                &format!("wide microkernel {label} kc={kc} x{reps} [{}]", b.name()),
                || {
                    for _ in 0..reps {
                        simd::microkernel_wide_tier_on(b, tier, kc, &ap, &bp, &mut out);
                    }
                    black_box(out[0][0]);
                },
            );
            let gflops = wide_flops / res.stats.median() / 1e9;
            let lab = format!("wide_microkernel_{label}");
            report.add(
                &res,
                &[("kc", kc as f64), ("reps", reps as f64), ("gflops", gflops)],
                &[("kernel", lab.as_str()), ("backend", b.name())],
            );
            tier_med.push((res.stats.median(), gflops));
        }
        println!(
            "  -> wide microkernel [{}]: t0 {:.2} GFLOP/s ({:.2}x vs row-dot's {:.2}), \
             t1 {:.2}x vs t0",
            b.name(),
            tier_med[0].1,
            base_res.stats.median() / tier_med[0].0.max(1e-12),
            base_gflops,
            tier_med[0].0 / tier_med[1].0.max(1e-12)
        );
    }
    println!();

    // -----------------------------------------------------------------
    // The packed trailing-sweep gemm vs the column-separable baseline it
    // replaced, plus the kernel-tier line (tier-0 unfused vs tier-1
    // FMA), at the QR sweep shape: W = Vᵀ·B with nb = PANEL reflectors
    // applied to a block of trailing columns
    // -----------------------------------------------------------------
    let nb = qr::PANEL;
    let (lp, ncols) = if quick_mode() { (256, 128) } else { (480, 288) };
    let vrows = randv(nb * lp, 31); // reflector block, row-major nb x lp
    let bcols = randv(lp * ncols, 32); // trailing columns, column-major
    let mut w = vec![0.0f32; nb * ncols];

    let cols_res = bench.run(&format!("sweep gemm {nb}x{lp}x{ncols} [columns]"), || {
        for j in 0..ncols {
            let col = &bcols[j * lp..(j + 1) * lp];
            for s in 0..nb {
                w[s * ncols + j] = blas::dot(&vrows[s * lp..(s + 1) * lp], col) as f32;
            }
        }
        black_box(w[0]);
    });
    let cols_med = cols_res.stats.median();
    report.add(
        &cols_res,
        &[("n", ncols as f64)],
        &[("kernel", "sweep_columns"), ("backend", active.name())],
    );

    // the reflector block packs once per sweep (as in qr::apply_block);
    // the column block re-packs every call, as it does per chunk
    let mut vt_pack = vec![0.0f32; blas::packed_a_len(nb, lp)];
    blas::pack_a_strided(&vrows, lp, 1, nb, lp, &mut vt_pack);
    let mut b_pack = vec![0.0f32; blas::packed_b_len(lp, ncols)];
    let tiers = [
        ("t0", KernelTier::Deterministic),
        ("t1", KernelTier::Fast),
    ];
    let mut packed_med = Vec::new();
    for (label, tier) in tiers {
        let res = bench.run(&format!("sweep gemm {nb}x{lp}x{ncols} [packed {label}]"), || {
            blas::pack_b_strided(&bcols, 1, lp, lp, ncols, &mut b_pack);
            blas::packed_gemm_into(
                active,
                tier,
                nb,
                ncols,
                lp,
                &vt_pack,
                &b_pack,
                blas::Accum::Store,
                &mut w,
                ncols,
                1,
            );
            black_box(w[0]);
        });
        packed_med.push(res.stats.median());
        let lab = format!("sweep_packed_{label}");
        report.add(
            &res,
            &[("n", ncols as f64)],
            &[("kernel", lab.as_str()), ("backend", active.name())],
        );
    }
    println!(
        "  -> sweep gemm {nb}x{lp}x{ncols}: packed t0 {:.2}x vs columns, t1 {:.2}x vs t0",
        cols_med / packed_med[0].max(1e-12),
        packed_med[0] / packed_med[1].max(1e-12)
    );
    println!();

    // -----------------------------------------------------------------
    // Per-shape dispatch crossover: at n < NR the packed path wastes
    // most of every microtile, so the direct dot/axpy path wins — Auto
    // switches on n < NR (or m < MR); these lines record the crossover
    // that rule encodes
    // -----------------------------------------------------------------
    let km = 192;
    let paths = [
        ("direct", blas::GemmPath::Direct),
        ("packed", blas::GemmPath::Packed),
    ];
    for &nn in &[2usize, 4, NR, 4 * NR] {
        let a = randm(km, km, 41);
        let b = randm(km, nn, 42);
        let mut c = Matrix::zeros(km, nn);
        let mut medians = Vec::new();
        for (label, path) in paths {
            let res = bench.run(&format!("gemm {km}x{km}x{nn} [{label}]"), || {
                blas::gemm_into_with(path, &a, &b, &mut c);
                black_box(c.as_slice()[0]);
            });
            medians.push(res.stats.median());
            let lab = format!("gemm_smalln_{label}");
            report.add(
                &res,
                &[("m", km as f64), ("n", nn as f64)],
                &[("kernel", lab.as_str()), ("backend", active.name())],
            );
        }
        println!(
            "  -> n={nn}: direct {:.2}x vs packed",
            medians[1] / medians[0].max(1e-12)
        );
    }
    println!();

    // -----------------------------------------------------------------
    // Composite kernels on the ACTIVE dispatch path (these go through
    // the public blas/qr entry points like the solvers do)
    // -----------------------------------------------------------------
    let sizes: &[usize] = if quick_mode() { &[128] } else { &[128, 256, 512] };
    for &n in sizes {
        let a = randm(n, n, 1);
        let b = randm(n, n, 2);
        let tall = randm(4 * n, n, 3);
        let x: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();

        let gemm_res = bench.run(&format!("gemm        {n}x{n} * {n}x{n}"), || {
            black_box(blas::gemm(&a, &b).as_slice()[0]);
        });
        // effective GFLOP/s for the gemm (2 n^3 flops)
        let gflops = 2.0 * (n as f64).powi(3) / gemm_res.stats.median() / 1e9;
        println!("  -> gemm {n}: {gflops:.2} GFLOP/s");
        report.add(
            &gemm_res,
            &[("n", n as f64), ("gflops", gflops)],
            &[("kernel", "gemm"), ("backend", active.name())],
        );

        let gemv_res = bench.run(&format!("gemv        {n}x{n}"), || {
            let mut y = vec![0.0f32; n];
            blas::gemv(&a, &x, &mut y);
            black_box(y[0]);
        });
        report.add(
            &gemv_res,
            &[("n", n as f64)],
            &[("kernel", "gemv"), ("backend", active.name())],
        );
        let gram_res = bench.run(&format!("gram        {}x{n}", 4 * n), || {
            black_box(blas::gram(&tall).as_slice()[0]);
        });
        report.add(
            &gram_res,
            &[("n", n as f64)],
            &[("kernel", "gram"), ("backend", active.name())],
        );
        let qr_res = bench.run(&format!("qr          {}x{n}", 4 * n), || {
            black_box(qr::householder_qr(&tall).r.as_slice()[0]);
        });
        report.add(
            &qr_res,
            &[("n", n as f64)],
            &[("kernel", "qr"), ("backend", active.name())],
        );
        let inv_res = bench.run(&format!("gj_inverse  {n}x{n}"), || {
            let g = blas::gram(&tall);
            black_box(inverse::gauss_jordan_inverse(&g).unwrap().as_slice()[0]);
        });
        report.add(
            &inv_res,
            &[("n", n as f64)],
            &[("kernel", "gj_inverse"), ("backend", active.name())],
        );
        let r = {
            let f = qr::householder_qr(&tall);
            f.r
        };
        let bs_res = bench.run(&format!("backsub     {n}"), || {
            black_box(triangular::back_substitute(&r, &x)[0]);
        });
        report.add(
            &bs_res,
            &[("n", n as f64)],
            &[("kernel", "backsub"), ("backend", active.name())],
        );
        println!();
    }

    match report.write() {
        Ok(path) => println!("wrote {} ({} records)", path.display(), report.len()),
        Err(e) => {
            eprintln!("failed to write bench json: {e}");
            std::process::exit(1);
        }
    }
}
