//! # Structured observability: metrics registry, histograms, phase spans
//!
//! Process-wide, std-only observability for every layer of the solver:
//! atomic [`Counter`]s, f64 [`Gauge`]s, and log2-bucketed latency
//! [`Histogram`]s (HdrHistogram-lite: 65 power-of-two buckets with
//! p50/p95/p99/p99.9 extraction) held in a global [`MetricsRegistry`],
//! plus RAII phase [`Span`]s.  Export surfaces (JSON, Prometheus text,
//! `TableBuilder` summaries, artifact validation) live in [`export`].
//!
//! ## The never-touch-numerics contract
//!
//! Instrumentation **wraps** kernels; it never enters them.  Recording
//! happens strictly outside the flop-carrying code — at driver phase
//! boundaries (seed/update/mix), service entry points (cold register,
//! warm and batched RHS), pool job wrappers (queue-wait/run), and
//! transport frame boundaries (per-worker scatter/gather, per-kind frame
//! and byte counts) — so enabling or disabling metrics can never change
//! a solver result.  Every `assert_eq!` equivalence suite must produce
//! bitwise-identical outputs with metrics enabled and with
//! `DAPC_METRICS=off`; `rust/tests/observability.rs` enforces this over
//! the warm-session suite.
//!
//! ## Cluster telemetry (wire v4)
//!
//! Workers record into their own process-global registry; the leader
//! pulls a flattened snapshot ([`MetricsRegistry::snapshot_flat`]) over
//! the wire-v4 telemetry frames (`StatsRequest` -> `StatsReport`, see
//! `coordinator::message`) and re-exports each entry as a
//! `cluster.w{id}.{name}` gauge, so a distributed run prints one
//! cluster-wide view.  In-process clusters (`LocalCluster`) share the
//! leader's process-global registry, so their per-worker split is exact
//! only across process boundaries — the shared-registry caveat is
//! documented on `Leader::collect_worker_stats`.
//!
//! ## Overhead and gating
//!
//! Recording is lock-free: relaxed atomic ops on pre-registered `Arc`
//! handles; the registry mutex is touched only at get-or-create time, so
//! hot paths fetch their handles once up front.  `DAPC_METRICS=off`
//! disables all recording and clock reads ([`now`] returns `None`);
//! [`set_enabled`] flips the same switch at runtime so tests can prove
//! the off path in-process.

pub mod export;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Number of log2 buckets: bucket 0 holds the value 0, bucket `b >= 1`
/// covers `[2^(b-1), 2^b - 1]`, and bucket 64 tops out at `u64::MAX`.
pub const HIST_BUCKETS: usize = 65;

// ---------------------------------------------------------------------------
// Global enable switch
// ---------------------------------------------------------------------------

const STATE_UNSET: u8 = 0;
const STATE_OFF: u8 = 1;
const STATE_ON: u8 = 2;

static ENABLED: AtomicU8 = AtomicU8::new(STATE_UNSET);

/// Whether recording is enabled.  The first call reads `DAPC_METRICS`
/// (any value other than `off` enables); every later call is one relaxed
/// atomic load.
pub fn enabled() -> bool {
    match ENABLED.load(Ordering::Relaxed) {
        STATE_ON => true,
        STATE_OFF => false,
        _ => {
            let on = crate::config::envvars::metrics_enabled();
            ENABLED.store(
                if on { STATE_ON } else { STATE_OFF },
                Ordering::Relaxed,
            );
            on
        }
    }
}

/// Flip recording at runtime.  This exists so the observability suite
/// can prove the disabled path in one process (env vars are read once);
/// production code should set `DAPC_METRICS=off` instead.
pub fn set_enabled(on: bool) {
    ENABLED.store(if on { STATE_ON } else { STATE_OFF }, Ordering::Relaxed);
}

/// `Some(Instant::now())` when metrics are enabled, `None` otherwise.
///
/// The `None` short-circuit keeps the disabled path free of clock
/// reads; pair with [`record_since`].
pub fn now() -> Option<Instant> {
    if enabled() {
        Some(Instant::now())
    } else {
        None
    }
}

/// Record the nanoseconds elapsed since `started` (no-op on `None`).
pub fn record_since(hist: &Histogram, started: Option<Instant>) {
    if let Some(t0) = started {
        hist.record(t0.elapsed().as_nanos() as u64);
    }
}

// ---------------------------------------------------------------------------
// Metric primitives
// ---------------------------------------------------------------------------

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn add(&self, n: u64) {
        if enabled() {
            self.value.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Last-write-wins f64 gauge (bits stored in an `AtomicU64`).
#[derive(Debug)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Default for Gauge {
    fn default() -> Self {
        Self { bits: AtomicU64::new(0f64.to_bits()) }
    }
}

impl Gauge {
    pub fn set(&self, v: f64) {
        if enabled() {
            self.bits.store(v.to_bits(), Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Map a value to its log2 bucket (0 -> 0, otherwise
/// `64 - leading_zeros`, i.e. one-past the highest set bit).
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        64 - v.leading_zeros() as usize
    }
}

/// Largest value bucket `b` can hold (`2^b - 1`, saturating at
/// `u64::MAX` for the top bucket).
pub fn bucket_upper(b: usize) -> u64 {
    if b == 0 {
        0
    } else if b >= 64 {
        u64::MAX
    } else {
        (1u64 << b) - 1
    }
}

/// HdrHistogram-lite: 65 log2 buckets over `u64` (nanoseconds by
/// convention), lock-free relaxed-atomic recording, quantiles by
/// cumulative walk.
///
/// A reported quantile is the **upper bound** of the bucket containing
/// the target rank `ceil(q * count)`, so quantile extraction is monotone
/// in `q` by construction and over-reports a sample by at most one
/// bucket width (2x).  Note a quantile may therefore exceed the true
/// maximum sample (the max shares a bucket whose upper bound is above
/// it) — consumers must not assume `p999 <= max`.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    /// Record one observation (gated on [`enabled`]).
    pub fn record(&self, v: u64) {
        if !enabled() {
            return;
        }
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Smallest recorded value (0 when empty).
    pub fn min(&self) -> u64 {
        if self.count() == 0 {
            0
        } else {
            self.min.load(Ordering::Relaxed)
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Quantile for `q` in `[0, 1]`: the upper bound of the bucket
    /// holding rank `ceil(q * count)` (clamped to `[1, count]`).
    /// Returns 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        let count = self.count();
        if count == 0 {
            return 0;
        }
        let rank = ((q * count as f64).ceil() as u64).clamp(1, count);
        let mut cum = 0u64;
        for (b, bucket) in self.buckets.iter().enumerate() {
            cum += bucket.load(Ordering::Relaxed);
            if cum >= rank {
                return bucket_upper(b);
            }
        }
        // A concurrent recorder bumped `count` before its bucket: fall
        // back to the max bound.
        self.max()
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    pub fn p999(&self) -> u64 {
        self.quantile(0.999)
    }

    /// Point-in-time copy of the full state (non-empty buckets only).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let buckets: Vec<(usize, u64)> = self
            .buckets
            .iter()
            .enumerate()
            .filter_map(|(b, c)| {
                let c = c.load(Ordering::Relaxed);
                (c > 0).then_some((b, c))
            })
            .collect();
        HistogramSnapshot {
            count: self.count(),
            sum: self.sum(),
            min: self.min(),
            max: self.max(),
            p50: self.p50(),
            p95: self.p95(),
            p99: self.p99(),
            p999: self.p999(),
            buckets,
        }
    }
}

/// Point-in-time copy of one histogram.
#[derive(Debug, Clone)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
    pub p999: u64,
    /// `(bucket index, count)` for every non-empty bucket.
    pub buckets: Vec<(usize, u64)>,
}

/// RAII phase span: records nanoseconds from construction to drop into
/// its histogram.  Does nothing (not even a clock read) when metrics
/// are disabled.
pub struct Span {
    hist: Arc<Histogram>,
    started: Option<Instant>,
}

impl Span {
    pub fn enter(hist: Arc<Histogram>) -> Self {
        Self { started: now(), hist }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        record_since(&self.hist, self.started);
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// Named metrics, get-or-create.  The map mutexes are taken only at
/// registration; recording through the returned `Arc` handles is
/// lock-free, so hot loops fetch their handles once before iterating.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut map = self.counters.lock().expect("metrics registry poisoned");
        map.entry(name.to_string()).or_default().clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut map = self.gauges.lock().expect("metrics registry poisoned");
        map.entry(name.to_string()).or_default().clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut map =
            self.histograms.lock().expect("metrics registry poisoned");
        map.entry(name.to_string()).or_default().clone()
    }

    /// True when nothing has ever been registered.
    pub fn is_empty(&self) -> bool {
        self.counters.lock().expect("metrics registry poisoned").is_empty()
            && self.gauges.lock().expect("metrics registry poisoned").is_empty()
            && self
                .histograms
                .lock()
                .expect("metrics registry poisoned")
                .is_empty()
    }

    /// Sorted point-in-time snapshot of every metric (BTreeMap order,
    /// so renders are deterministic for a given set of names).
    pub fn snapshot(&self) -> Snapshot {
        let counters = self
            .counters
            .lock()
            .expect("metrics registry poisoned")
            .iter()
            .map(|(k, v)| (k.clone(), v.get()))
            .collect();
        let gauges = self
            .gauges
            .lock()
            .expect("metrics registry poisoned")
            .iter()
            .map(|(k, v)| (k.clone(), v.get()))
            .collect();
        let histograms = self
            .histograms
            .lock()
            .expect("metrics registry poisoned")
            .iter()
            .map(|(k, v)| (k.clone(), v.snapshot()))
            .collect();
        Snapshot { counters, gauges, histograms }
    }

    /// Flattened `(name, value)` view: counters and gauges verbatim,
    /// histograms exploded into `.count`/`.sum`/`.p50`/`.p95`/`.p99`/
    /// `.max` entries.  This is what a worker ships in a wire-v4
    /// `StatsReport`.
    pub fn snapshot_flat(&self) -> Vec<(String, f64)> {
        let snap = self.snapshot();
        let mut out = Vec::new();
        for (name, v) in &snap.counters {
            out.push((name.clone(), *v as f64));
        }
        for (name, v) in &snap.gauges {
            out.push((name.clone(), *v));
        }
        for (name, h) in &snap.histograms {
            out.push((format!("{name}.count"), h.count as f64));
            out.push((format!("{name}.sum"), h.sum as f64));
            out.push((format!("{name}.p50"), h.p50 as f64));
            out.push((format!("{name}.p95"), h.p95 as f64));
            out.push((format!("{name}.p99"), h.p99 as f64));
            out.push((format!("{name}.max"), h.max as f64));
        }
        out
    }
}

/// Point-in-time view of a whole [`MetricsRegistry`].
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, f64)>,
    pub histograms: Vec<(String, HistogramSnapshot)>,
}

/// The process-global registry.
pub fn global() -> &'static MetricsRegistry {
    static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();
    GLOBAL.get_or_init(MetricsRegistry::new)
}

/// Get-or-create a counter in the global registry.
pub fn counter(name: &str) -> Arc<Counter> {
    global().counter(name)
}

/// Get-or-create a gauge in the global registry.
pub fn gauge(name: &str) -> Arc<Gauge> {
    global().gauge(name)
}

/// Get-or-create a histogram in the global registry.
pub fn histogram(name: &str) -> Arc<Histogram> {
    global().histogram(name)
}

/// Serializes tests that record metrics or toggle [`set_enabled`]:
/// the switch is process-global, and `cargo test` runs test threads in
/// parallel.
#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(u64::MAX), 64);
        // every bucket's bounds map back to the bucket itself
        for b in 0..HIST_BUCKETS {
            assert_eq!(bucket_index(bucket_upper(b)), b, "upper edge of {b}");
            if b >= 1 {
                assert_eq!(bucket_index(1u64 << (b - 1)), b, "low edge of {b}");
            }
        }
        assert_eq!(bucket_upper(0), 0);
        assert_eq!(bucket_upper(1), 1);
        assert_eq!(bucket_upper(10), 1023);
        assert_eq!(bucket_upper(64), u64::MAX);
    }

    #[test]
    fn histogram_counts_sum_and_quantiles() {
        let _g = test_lock();
        set_enabled(true);
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.sum(), 500_500);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 1000);
        // rank 500 lands in bucket [256, 511]
        assert_eq!(h.p50(), 511);
        // rank 1000 lands in bucket [512, 1023]
        assert_eq!(h.quantile(1.0), 1023);
        // monotone in q, and never below the true value's bucket floor
        let mut last = 0u64;
        for i in 0..=100 {
            let q = i as f64 / 100.0;
            let v = h.quantile(q);
            assert!(v >= last, "quantile({q}) = {v} < {last}");
            last = v;
        }
    }

    #[test]
    fn empty_histogram_is_all_zeros() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert!(h.snapshot().buckets.is_empty());
    }

    #[test]
    fn disabled_records_nothing() {
        let _g = test_lock();
        set_enabled(false);
        let h = Histogram::new();
        let c = Counter::default();
        let g = Gauge::default();
        h.record(42);
        c.inc();
        g.set(3.5);
        assert!(now().is_none());
        set_enabled(true);
        assert_eq!(h.count(), 0);
        assert_eq!(c.get(), 0);
        assert_eq!(g.get(), 0.0);
    }

    #[test]
    fn registry_get_or_create_returns_same_handle() {
        let reg = MetricsRegistry::new();
        assert!(reg.is_empty());
        let a = reg.histogram("x.ns");
        let b = reg.histogram("x.ns");
        assert!(Arc::ptr_eq(&a, &b));
        assert!(!Arc::ptr_eq(&a, &reg.histogram("y.ns")));
        let c1 = reg.counter("n");
        c1.add(0); // no-op either way; handle identity is the point
        assert!(Arc::ptr_eq(&c1, &reg.counter("n")));
        assert!(!reg.is_empty());
    }

    #[test]
    fn snapshot_flat_explodes_histograms() {
        let _g = test_lock();
        set_enabled(true);
        let reg = MetricsRegistry::new();
        reg.counter("events").add(3);
        reg.gauge("load").set(0.5);
        reg.histogram("lat.ns").record(100);
        let flat = reg.snapshot_flat();
        let keys: Vec<&str> =
            flat.iter().map(|(k, _)| k.as_str()).collect();
        assert!(keys.contains(&"events"));
        assert!(keys.contains(&"load"));
        assert!(keys.contains(&"lat.ns.count"));
        assert!(keys.contains(&"lat.ns.p99"));
        let count = flat
            .iter()
            .find(|(k, _)| k == "lat.ns.count")
            .map(|(_, v)| *v)
            .unwrap();
        assert_eq!(count, 1.0);
    }

    #[test]
    fn span_records_on_drop() {
        let _g = test_lock();
        set_enabled(true);
        let h = Arc::new(Histogram::new());
        {
            let _span = Span::enter(h.clone());
        }
        assert_eq!(h.count(), 1);
    }
}
