//! Metrics and reporting: convergence traces (Fig. 2), wall-clock timing
//! (Table 1), CSV export and markdown table formatting.

mod table;
mod timer;
mod trace;

pub use table::TableBuilder;
pub use timer::{StopWatch, TimingStats};
pub use trace::ConvergenceTrace;
