//! Compressed Sparse Row matrix — the storage format the paper's pipeline
//! keeps `A` in between partitioning steps (scipy `csr_matrix` analog).

use crate::error::{DapcError, Result};
use crate::linalg::Matrix;

/// CSR sparse matrix over f32.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<usize>,
    values: Vec<f32>,
}

impl CsrMatrix {
    /// Build from raw CSR arrays, validating the structure.
    pub fn from_raw(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<usize>,
        values: Vec<f32>,
    ) -> Result<Self> {
        if indptr.len() != rows + 1 {
            return Err(DapcError::Shape(format!(
                "indptr length {} != rows+1 = {}",
                indptr.len(),
                rows + 1
            )));
        }
        if indices.len() != values.len() {
            return Err(DapcError::Shape(
                "indices/values length mismatch".into(),
            ));
        }
        if *indptr.last().unwrap_or(&0) != indices.len() {
            return Err(DapcError::Shape(
                "indptr tail does not match nnz".into(),
            ));
        }
        if indptr.windows(2).any(|w| w[0] > w[1]) {
            return Err(DapcError::Shape("indptr not monotone".into()));
        }
        if indices.iter().any(|&c| c >= cols) {
            return Err(DapcError::Shape("column index out of bounds".into()));
        }
        Ok(Self { rows, cols, indptr, indices, values })
    }

    /// Build from a dense matrix, keeping nonzeros.
    pub fn from_dense(m: &Matrix) -> Self {
        let (rows, cols) = m.shape();
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for i in 0..rows {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 {
                    indices.push(j);
                    values.push(v);
                }
            }
            indptr.push(indices.len());
        }
        Self { rows, cols, indptr, indices, values }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Number of stored nonzeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Nonzeros in row i.
    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    /// Sparsity percentage (the paper quotes 99.85 for c-27).
    pub fn sparsity_pct(&self) -> f64 {
        let total = (self.rows * self.cols) as f64;
        if total == 0.0 {
            return 0.0;
        }
        100.0 * (1.0 - self.nnz() as f64 / total)
    }

    /// Value at (i, j) — O(log nnz_row) binary search.
    pub fn get(&self, i: usize, j: usize) -> f32 {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        match self.indices[lo..hi].binary_search(&j) {
            Ok(k) => self.values[lo + k],
            Err(_) => 0.0,
        }
    }

    /// Row `i` as (indices, values) slices.
    pub fn row(&self, i: usize) -> (&[usize], &[f32]) {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Sparse mat-vec `y = A x` into a caller-provided buffer — the
    /// allocation-free entry point the solvers' steady-state loops use
    /// (gradient and residual evaluation reuse one scratch vector).
    pub fn spmv_into(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            let (idx, vals) = self.row(i);
            let mut acc = 0.0f64;
            for (&j, &v) in idx.iter().zip(vals) {
                acc += v as f64 * x[j] as f64;
            }
            y[i] = acc as f32;
        }
    }

    /// Sparse mat-vec `y = A x` (alias of [`Self::spmv_into`], kept for
    /// existing callers).
    pub fn spmv(&self, x: &[f32], y: &mut [f32]) {
        self.spmv_into(x, y);
    }

    /// Rows `[start, end)` densified — the paper's `create_submatrices`
    /// does exactly this (`A[lo:hi, :].toarray()`).
    pub fn slice_rows_dense(&self, start: usize, end: usize) -> Matrix {
        assert!(start <= end && end <= self.rows);
        let mut out = Matrix::zeros(end - start, self.cols);
        for i in start..end {
            let (idx, vals) = self.row(i);
            let row = out.row_mut(i - start);
            for (&j, &v) in idx.iter().zip(vals) {
                row[j] = v;
            }
        }
        out
    }

    /// Full densification.
    pub fn to_dense(&self) -> Matrix {
        self.slice_rows_dense(0, self.rows)
    }

    /// Vertically stack two CSR matrices (used to build `[A; D_A]`, eq. 8).
    pub fn vstack(&self, other: &CsrMatrix) -> Result<CsrMatrix> {
        if self.cols != other.cols {
            return Err(DapcError::Shape(format!(
                "vstack column mismatch: {} vs {}",
                self.cols, other.cols
            )));
        }
        let mut indptr = self.indptr.clone();
        let offset = *indptr.last().unwrap();
        indptr.extend(other.indptr[1..].iter().map(|&p| p + offset));
        let mut indices = self.indices.clone();
        indices.extend_from_slice(&other.indices);
        let mut values = self.values.clone();
        values.extend_from_slice(&other.values);
        CsrMatrix::from_raw(self.rows + other.rows, self.cols, indptr, indices, values)
    }

    /// Structural rank lower bound: rows with at least one nonzero.
    /// (Cheap sanity check used by the partitioner; exact numeric rank is
    /// established by the QR init itself.)
    pub fn nonempty_rows(&self) -> usize {
        (0..self.rows).filter(|&i| self.row_nnz(i) > 0).count()
    }

    /// Mean of stored values (paper §5 reports dataset mu/sigma over the
    /// full dense matrix, zeros included).
    pub fn dense_mean(&self) -> f64 {
        let total = (self.rows * self.cols) as f64;
        if total == 0.0 {
            return 0.0;
        }
        // audit:allow(fixed-order-reduce): reporting-only statistic over
        // the stored-value order, never fed back into solve state
        self.values.iter().map(|&v| v as f64).sum::<f64>() / total
    }

    /// Std-dev of the dense view (zeros included).
    pub fn dense_std(&self) -> f64 {
        let total = (self.rows * self.cols) as f64;
        if total == 0.0 {
            return 0.0;
        }
        let mean = self.dense_mean();
        let sq: f64 = self.values.iter().map(|&v| (v as f64).powi(2)).sum();
        // E[x^2] - mean^2 over the dense entries (zeros contribute 0 to sq)
        (sq / total - mean * mean).max(0.0).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::seeded;

    fn sample() -> CsrMatrix {
        // [[1, 0, 2], [0, 0, 0], [3, 4, 0]]
        CsrMatrix::from_raw(
            3,
            3,
            vec![0, 2, 2, 4],
            vec![0, 2, 0, 1],
            vec![1.0, 2.0, 3.0, 4.0],
        )
        .unwrap()
    }

    #[test]
    fn structure_validation() {
        assert!(CsrMatrix::from_raw(2, 2, vec![0, 1], vec![0], vec![1.0]).is_err());
        assert!(CsrMatrix::from_raw(2, 2, vec![0, 2, 1], vec![0, 1], vec![1.0, 1.0]).is_err());
        assert!(CsrMatrix::from_raw(1, 2, vec![0, 1], vec![5], vec![1.0]).is_err());
        assert!(CsrMatrix::from_raw(1, 2, vec![0, 2], vec![0], vec![1.0]).is_err());
    }

    #[test]
    fn get_and_row() {
        let m = sample();
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(0, 1), 0.0);
        assert_eq!(m.get(2, 1), 4.0);
        let (idx, vals) = m.row(2);
        assert_eq!(idx, &[0, 1]);
        assert_eq!(vals, &[3.0, 4.0]);
    }

    #[test]
    fn spmv_matches_dense() {
        let m = sample();
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [0.0f32; 3];
        m.spmv(&x, &mut y);
        assert_eq!(y, [7.0, 0.0, 11.0]);
        let d = m.to_dense();
        let mut yd = [0.0f32; 3];
        crate::linalg::blas::gemv(&d, &x, &mut yd);
        assert_eq!(y, yd);
    }

    #[test]
    fn dense_roundtrip() {
        let mut g = seeded(8);
        let d = Matrix::from_fn(10, 6, |_, _| {
            if g.uniform_f64() < 0.2 {
                g.normal_f32()
            } else {
                0.0
            }
        });
        let csr = CsrMatrix::from_dense(&d);
        assert_eq!(csr.to_dense(), d);
    }

    #[test]
    fn slice_rows_matches_paper_semantics() {
        let m = sample();
        let sl = m.slice_rows_dense(1, 3);
        assert_eq!(sl.shape(), (2, 3));
        assert_eq!(sl.row(0), &[0.0, 0.0, 0.0]);
        assert_eq!(sl.row(1), &[3.0, 4.0, 0.0]);
    }

    #[test]
    fn vstack_layout() {
        let m = sample();
        let s = m.vstack(&m).unwrap();
        assert_eq!(s.shape(), (6, 3));
        assert_eq!(s.get(3, 0), 1.0);
        assert_eq!(s.get(5, 1), 4.0);
        assert_eq!(s.nnz(), 8);
        // mismatched cols
        let other = CsrMatrix::from_raw(1, 2, vec![0, 0], vec![], vec![]).unwrap();
        assert!(m.vstack(&other).is_err());
    }

    #[test]
    fn sparsity_stats() {
        let m = sample();
        assert_eq!(m.nnz(), 4);
        assert!((m.sparsity_pct() - 100.0 * (1.0 - 4.0 / 9.0)).abs() < 1e-9);
        assert_eq!(m.nonempty_rows(), 2);
        assert!((m.dense_mean() - 10.0 / 9.0).abs() < 1e-9);
    }
}
