//! Microbenchmarks of the native linalg primitives — the L3 profile
//! baseline for the §Perf optimization pass (gemm/gemv dominate the
//! consensus epochs; QR dominates init).

use dapc::benchkit::{black_box, quick_mode, Bench};
use dapc::linalg::{blas, inverse, qr, triangular, Matrix};
use dapc::rng::seeded;

fn randm(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut g = seeded(seed);
    Matrix::from_fn(rows, cols, |_, _| g.normal_f32())
}

fn main() {
    let sizes: &[usize] = if quick_mode() { &[128] } else { &[128, 256, 512] };
    let bench = Bench::default();

    println!("=== linalg microbenches ===");
    for &n in sizes {
        let a = randm(n, n, 1);
        let b = randm(n, n, 2);
        let tall = randm(4 * n, n, 3);
        let x: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();

        let gemm_res = bench.run(&format!("gemm        {n}x{n} * {n}x{n}"), || {
            black_box(blas::gemm(&a, &b).as_slice()[0]);
        });
        // effective GFLOP/s for the gemm (2 n^3 flops)
        let gflops = 2.0 * (n as f64).powi(3) / gemm_res.stats.median() / 1e9;
        println!("  -> gemm {n}: {gflops:.2} GFLOP/s");

        bench.run(&format!("gemv        {n}x{n}"), || {
            let mut y = vec![0.0f32; n];
            blas::gemv(&a, &x, &mut y);
            black_box(y[0]);
        });
        bench.run(&format!("gram        {}x{n}", 4 * n), || {
            black_box(blas::gram(&tall).as_slice()[0]);
        });
        bench.run(&format!("qr          {}x{n}", 4 * n), || {
            black_box(qr::householder_qr(&tall).r.as_slice()[0]);
        });
        bench.run(&format!("gj_inverse  {n}x{n}"), || {
            let g = blas::gram(&tall);
            black_box(inverse::gauss_jordan_inverse(&g).unwrap().as_slice()[0]);
        });
        let r = {
            let f = qr::householder_qr(&tall);
            f.r
        };
        bench.run(&format!("backsub     {n}"), || {
            black_box(triangular::back_substitute(&r, &x)[0]);
        });
        println!();
    }
}
