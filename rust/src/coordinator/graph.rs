//! Lazy task-graph representation of Algorithm 1 — the analog of the
//! paper's Dask computational graph (Figure 1), with topological
//! scheduling order and Graphviz DOT export.

use std::collections::BTreeMap;

use crate::error::{DapcError, Result};

/// Node id in a task graph.
pub type NodeId = usize;

/// Task categories mirroring the paper's delayed functions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    CreateSubmatrices,
    QrDecomposition,
    InitialSolution,
    Projection,
    CreateIdentity,
    AverageInitial,
    UpdateSolution,
    AverageSolutions,
    Output,
}

impl TaskKind {
    fn label(&self) -> &'static str {
        match self {
            TaskKind::CreateSubmatrices => "create_submatrices",
            TaskKind::QrDecomposition => "qr_decomposition",
            TaskKind::InitialSolution => "initial_solution",
            TaskKind::Projection => "projection",
            TaskKind::CreateIdentity => "create_identity_matrix",
            TaskKind::AverageInitial => "average_initial_solutions",
            TaskKind::UpdateSolution => "update_solution",
            TaskKind::AverageSolutions => "average_solutions",
            TaskKind::Output => "output",
        }
    }
}

/// One task node.
#[derive(Debug, Clone)]
pub struct TaskNode {
    pub id: NodeId,
    pub kind: TaskKind,
    /// Partition index the task belongs to (None for leader-side tasks).
    pub partition: Option<usize>,
    /// Epoch for iterate-phase tasks.
    pub epoch: Option<usize>,
    pub deps: Vec<NodeId>,
}

/// DAG of tasks with scheduling helpers.
#[derive(Debug, Default)]
pub struct TaskGraph {
    nodes: Vec<TaskNode>,
}

impl TaskGraph {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(
        &mut self,
        kind: TaskKind,
        partition: Option<usize>,
        epoch: Option<usize>,
        deps: &[NodeId],
    ) -> NodeId {
        let id = self.nodes.len();
        for &d in deps {
            assert!(d < id, "dependency on a future node");
        }
        self.nodes.push(TaskNode {
            id,
            kind,
            partition,
            epoch,
            deps: deps.to_vec(),
        });
        id
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, id: NodeId) -> &TaskNode {
        &self.nodes[id]
    }

    /// Build the Algorithm-1 graph for J partitions and T epochs —
    /// structurally identical to the paper's Figure 1 (which shows J=2,
    /// T=1).
    pub fn algorithm1(j: usize, epochs: usize) -> Self {
        let mut g = Self::new();
        let identity = g.add(TaskKind::CreateIdentity, None, None, &[]);
        let mut x_nodes = Vec::with_capacity(j);
        let mut p_nodes = Vec::with_capacity(j);
        for part in 0..j {
            let sub = g.add(TaskKind::CreateSubmatrices, Some(part), None, &[]);
            let qr = g.add(TaskKind::QrDecomposition, Some(part), None, &[sub]);
            let x0 = g.add(TaskKind::InitialSolution, Some(part), None, &[qr, sub]);
            let p = g.add(TaskKind::Projection, Some(part), None, &[identity, qr]);
            x_nodes.push(x0);
            p_nodes.push(p);
        }
        let mut avg = g.add(TaskKind::AverageInitial, None, None, &x_nodes);
        for t in 0..epochs {
            let mut updated = Vec::with_capacity(j);
            for part in 0..j {
                let deps = [x_nodes[part], avg, p_nodes[part]];
                updated.push(g.add(
                    TaskKind::UpdateSolution,
                    Some(part),
                    Some(t),
                    &deps,
                ));
            }
            let mut deps = updated.clone();
            deps.push(avg);
            avg = g.add(TaskKind::AverageSolutions, None, Some(t), &deps);
            x_nodes = updated;
        }
        g.add(TaskKind::Output, None, None, &[avg]);
        g
    }

    /// Kahn topological order; errors on cycles (impossible via `add`, but
    /// kept for graphs built from external descriptions).
    pub fn topo_order(&self) -> Result<Vec<NodeId>> {
        let n = self.nodes.len();
        let mut indeg = vec![0usize; n];
        let mut rev: Vec<Vec<NodeId>> = vec![Vec::new(); n];
        for node in &self.nodes {
            indeg[node.id] = node.deps.len();
            for &d in &node.deps {
                rev[d].push(node.id);
            }
        }
        let mut queue: Vec<NodeId> =
            (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(id) = queue.pop() {
            order.push(id);
            for &next in &rev[id] {
                indeg[next] -= 1;
                if indeg[next] == 0 {
                    queue.push(next);
                }
            }
        }
        if order.len() != n {
            return Err(DapcError::Coordinator("task graph has a cycle".into()));
        }
        Ok(order)
    }

    /// Parallel schedule: wave `w` contains every task whose dependencies
    /// all sit in earlier waves (what Dask's scheduler would co-schedule).
    pub fn waves(&self) -> Vec<Vec<NodeId>> {
        let mut level = vec![0usize; self.nodes.len()];
        for node in &self.nodes {
            level[node.id] = node
                .deps
                .iter()
                .map(|&d| level[d] + 1)
                .max()
                .unwrap_or(0);
        }
        let max_level = level.iter().copied().max().unwrap_or(0);
        let mut waves = vec![Vec::new(); max_level + 1];
        for node in &self.nodes {
            waves[level[node.id]].push(node.id);
        }
        waves
    }

    /// Graphviz DOT export (Figure 1 reproduction).
    pub fn to_dot(&self) -> String {
        let mut out = String::from("digraph dapc {\n  rankdir=BT;\n");
        // cluster per partition like the paper's figure
        let mut by_part: BTreeMap<Option<usize>, Vec<&TaskNode>> =
            BTreeMap::new();
        for n in &self.nodes {
            by_part.entry(n.partition).or_default().push(n);
        }
        for (part, nodes) in &by_part {
            if let Some(p) = part {
                out.push_str(&format!(
                    "  subgraph cluster_p{p} {{\n    label=\"partition {p}\";\n"
                ));
            }
            for n in nodes {
                let extra = n
                    .epoch
                    .map(|e| format!("\\n(epoch {e})"))
                    .unwrap_or_default();
                out.push_str(&format!(
                    "    n{} [label=\"{}{}\"];\n",
                    n.id,
                    n.kind.label(),
                    extra
                ));
            }
            if part.is_some() {
                out.push_str("  }\n");
            }
        }
        for n in &self.nodes {
            for &d in &n.deps {
                out.push_str(&format!("  n{d} -> n{};\n", n.id));
            }
        }
        out.push_str("}\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure1_shape() {
        // paper's Figure 1: J=2 partitions, T=1 epoch
        let g = TaskGraph::algorithm1(2, 1);
        // 1 identity + 2*(sub, qr, x0, p) + avg0 + 2 updates + avg1 + output
        assert_eq!(g.len(), 1 + 8 + 1 + 2 + 1 + 1);
        let kinds: Vec<_> = (0..g.len()).map(|i| g.node(i).kind).collect();
        assert_eq!(
            kinds.iter().filter(|k| **k == TaskKind::UpdateSolution).count(),
            2
        );
        assert_eq!(
            kinds.iter().filter(|k| **k == TaskKind::QrDecomposition).count(),
            2
        );
    }

    #[test]
    fn topo_order_respects_deps() {
        let g = TaskGraph::algorithm1(3, 4);
        let order = g.topo_order().unwrap();
        let pos: Vec<usize> = {
            let mut p = vec![0; g.len()];
            for (i, &id) in order.iter().enumerate() {
                p[id] = i;
            }
            p
        };
        for id in 0..g.len() {
            for &d in &g.node(id).deps {
                assert!(pos[d] < pos[id], "dep {d} after {id}");
            }
        }
    }

    #[test]
    fn waves_expose_parallelism() {
        // with J=4 the per-partition QR tasks all land in the same wave
        let g = TaskGraph::algorithm1(4, 1);
        let waves = g.waves();
        let qr_wave: Vec<usize> = (0..g.len())
            .filter(|&i| g.node(i).kind == TaskKind::QrDecomposition)
            .collect();
        let level_of = |id: usize| {
            waves.iter().position(|w| w.contains(&id)).unwrap()
        };
        let first = level_of(qr_wave[0]);
        assert!(qr_wave.iter().all(|&id| level_of(id) == first));
        // updates depend on the averaged initial solution => strictly later
        let upd = (0..g.len())
            .find(|&i| g.node(i).kind == TaskKind::UpdateSolution)
            .unwrap();
        assert!(level_of(upd) > first);
    }

    #[test]
    fn dot_export_wellformed() {
        let g = TaskGraph::algorithm1(2, 1);
        let dot = g.to_dot();
        assert!(dot.starts_with("digraph dapc {"));
        assert!(dot.contains("subgraph cluster_p0"));
        assert!(dot.contains("qr_decomposition"));
        assert!(dot.contains("->"));
        assert!(dot.trim_end().ends_with('}'));
        // one node line per task
        assert_eq!(dot.matches("[label=").count(), g.len());
    }

    #[test]
    fn epoch_scaling() {
        let g1 = TaskGraph::algorithm1(2, 1);
        let g5 = TaskGraph::algorithm1(2, 5);
        // each extra epoch adds J updates + 1 average
        assert_eq!(g5.len() - g1.len(), 4 * (2 + 1));
    }
}
