//! [`SessionConfig`]: the builder every session registration goes
//! through.
//!
//! `SolverSession::register` used to take positional
//! `(algorithm, opts)` arguments; call sites grew unreadable the moment
//! a caller needed to touch one knob (`register(b, a, alg,
//! SolveOptions { epochs, ..Default::default() })`).  The builder names
//! every knob, supplies defaults for the rest, and is the ONE
//! registration surface shared by [`super::SolverSession`] and
//! [`super::SessionManager`].

use crate::error::{DapcError, Result};
use crate::linalg::simd::KernelTier;
use crate::solver::{ApcVariant, SolveOptions};

use super::SessionAlgorithm;

/// Declarative registration config for a solver session.
///
/// ```
/// use dapc::service::SessionConfig;
/// use dapc::solver::ApcVariant;
///
/// let config = SessionConfig::apc(ApcVariant::Decomposed)
///     .partitions(4)
///     .epochs(60);
/// ```
///
/// `partitions` is a cross-check, not a request: the partition count is
/// owned by the backend (its worker count), and registration fails
/// loudly when the declared count disagrees instead of silently
/// repartitioning.  Leave it unset to accept whatever the backend has.
#[derive(Debug, Clone)]
pub struct SessionConfig {
    algorithm: SessionAlgorithm,
    partitions: Option<usize>,
    opts: SolveOptions,
}

impl SessionConfig {
    /// Config for `algorithm` with default [`SolveOptions`].
    pub fn new(algorithm: SessionAlgorithm) -> Self {
        Self { algorithm, partitions: None, opts: SolveOptions::default() }
    }

    /// Consensus session (decomposed or classical init).
    pub fn apc(variant: ApcVariant) -> Self {
        Self::new(SessionAlgorithm::Apc(variant))
    }

    /// Distributed-gradient-descent session.
    pub fn dgd() -> Self {
        Self::new(SessionAlgorithm::Dgd)
    }

    /// Declare the expected partition/worker count.  Registration fails
    /// if the backend disagrees.
    pub fn partitions(mut self, j: usize) -> Self {
        self.partitions = Some(j);
        self
    }

    /// Consensus epochs T (or gradient steps for DGD).
    pub fn epochs(mut self, epochs: usize) -> Self {
        self.opts.epochs = epochs;
        self
    }

    /// DGD step size (`0.0` = resolve automatically at registration).
    pub fn dgd_step(mut self, alpha: f32) -> Self {
        self.opts.dgd_step = alpha;
        self
    }

    /// Per-session f32 kernel-tier override for in-process native
    /// engines (see the two-tier contract in `linalg::simd`).
    pub fn kernel_tier(mut self, tier: KernelTier) -> Self {
        self.opts.kernel_tier = Some(tier);
        self
    }

    /// Request per-partition final estimates in each report.  Sessions
    /// reject this at registration (the serving layer returns raw
    /// solves only) — the builder still carries it so the rejection has
    /// one authoritative code path.
    pub fn collect_x_parts(mut self, on: bool) -> Self {
        self.opts.collect_x_parts = on;
        self
    }

    /// Escape hatch: replace the full [`SolveOptions`] (keeps the
    /// algorithm and partition declaration).
    pub fn options(mut self, opts: SolveOptions) -> Self {
        self.opts = opts;
        self
    }

    /// The algorithm this config registers.
    pub fn algorithm(&self) -> SessionAlgorithm {
        self.algorithm
    }

    /// The solve options this config carries.
    pub fn solve_options(&self) -> &SolveOptions {
        &self.opts
    }

    /// Resolve the partition count against the backend's, erroring on a
    /// mismatch (and on a zero-partition backend).
    pub(crate) fn resolve_partitions(&self, backend_j: usize) -> Result<usize> {
        if backend_j == 0 {
            return Err(DapcError::Coordinator(
                "solver session needs at least one partition/worker (got 0)"
                    .into(),
            ));
        }
        match self.partitions {
            Some(j) if j != backend_j => Err(DapcError::Config(format!(
                "SessionConfig declares {j} partitions but the backend has \
                 {backend_j} workers"
            ))),
            _ => Ok(backend_j),
        }
    }

    pub(crate) fn into_parts(self) -> (SessionAlgorithm, SolveOptions) {
        (self.algorithm, self.opts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_sets_every_knob() {
        let c = SessionConfig::apc(ApcVariant::Classical)
            .partitions(3)
            .epochs(12)
            .kernel_tier(KernelTier::Fast)
            .collect_x_parts(true);
        assert_eq!(
            c.algorithm(),
            SessionAlgorithm::Apc(ApcVariant::Classical)
        );
        assert_eq!(c.solve_options().epochs, 12);
        assert_eq!(c.solve_options().kernel_tier, Some(KernelTier::Fast));
        assert!(c.solve_options().collect_x_parts);
        assert_eq!(c.resolve_partitions(3).unwrap(), 3);
    }

    #[test]
    fn partition_mismatch_rejected() {
        let c = SessionConfig::dgd().partitions(4);
        let err = c.resolve_partitions(2).unwrap_err().to_string();
        assert!(err.contains("4 partitions"), "{err}");
        assert!(err.contains("2 workers"), "{err}");
        // unset accepts the backend's count; zero is always rejected
        assert_eq!(SessionConfig::dgd().resolve_partitions(5).unwrap(), 5);
        assert!(SessionConfig::dgd().resolve_partitions(0).is_err());
    }

    #[test]
    fn options_escape_hatch_replaces_solve_options() {
        let c = SessionConfig::dgd()
            .options(SolveOptions { epochs: 3, ..Default::default() });
        assert_eq!(c.solve_options().epochs, 3);
        assert_eq!(c.algorithm(), SessionAlgorithm::Dgd);
    }
}
