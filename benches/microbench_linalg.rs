//! Microbenchmarks of the native linalg primitives — the L3 profile
//! baseline for the §Perf optimization pass (gemm/gemv dominate the
//! consensus epochs; QR dominates init).
//!
//! Since the SIMD dispatch layer (`linalg::simd`) every vector kernel is
//! benched **per backend**: the lane-structured scalar fallback vs the
//! AVX2+FMA path (when the CPU has it), on identical inputs.  The two
//! are bit-identical by contract, so any delta between the lines is
//! pure throughput — that comparison is the evidence the ROADMAP's
//! "explicit SIMD" lever asks for, and it lands in
//! `BENCH_microbench_linalg.json` (kernel/backend/n fields per record)
//! which CI validates and uploads.  Timing *ratios* are deliberately
//! not asserted here: shared CI runners jitter too much for a hard
//! gate, and the JSON keeps the trajectory reviewable instead.

use dapc::benchkit::{black_box, quick_mode, Bench, BenchResult, JsonReport};
use dapc::linalg::simd::{self, Backend, MR, NR};
use dapc::linalg::{blas, inverse, qr, triangular, Matrix};
use dapc::rng::seeded;

fn randm(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut g = seeded(seed);
    Matrix::from_fn(rows, cols, |_, _| g.normal_f32())
}

fn randv(len: usize, seed: u64) -> Vec<f32> {
    let mut g = seeded(seed);
    (0..len).map(|_| g.normal_f32()).collect()
}

fn speedup_line(kernel: &str, n: usize, per_backend: &[(Backend, BenchResult)]) {
    if let (Some(s), Some(a)) = (
        per_backend.iter().find(|(b, _)| *b == Backend::Scalar),
        per_backend.iter().find(|(b, _)| *b == Backend::Avx2Fma),
    ) {
        println!(
            "  -> {kernel} {n}: avx2+fma {:.2}x vs scalar",
            s.1.stats.median() / a.1.stats.median().max(1e-12)
        );
    }
}

fn main() {
    let bench = Bench::default();
    let mut report = JsonReport::new("microbench_linalg");
    let active = simd::active();

    println!("=== linalg microbenches ===");
    println!("kernel dispatch: {}", simd::description());

    // -----------------------------------------------------------------
    // Vector kernels, per backend (dot / dot_wide / axpy)
    // -----------------------------------------------------------------
    let lens: &[usize] = if quick_mode() { &[4096] } else { &[1024, 4096, 65536] };
    for &n in lens {
        let x = randv(n, 11);
        let y = randv(n, 12);
        let mut xw = vec![0.0f64; n];
        blas::widen(&x, &mut xw);

        let mut dots = Vec::new();
        for &b in &simd::available() {
            let res = bench.run(&format!("dot         {n} [{}]", b.name()), || {
                black_box(simd::dot_on(b, &x, &y));
            });
            report.add(
                &res,
                &[("n", n as f64)],
                &[("kernel", "dot"), ("backend", b.name())],
            );
            dots.push((b, res));
        }
        speedup_line("dot", n, &dots);

        let mut wides = Vec::new();
        for &b in &simd::available() {
            let res = bench.run(&format!("dot_wide    {n} [{}]", b.name()), || {
                black_box(simd::dot_wide_on(b, &xw, &y));
            });
            report.add(
                &res,
                &[("n", n as f64)],
                &[("kernel", "dot_wide"), ("backend", b.name())],
            );
            wides.push((b, res));
        }
        speedup_line("dot_wide", n, &wides);

        let mut axpys = Vec::new();
        for &b in &simd::available() {
            let mut acc = y.clone();
            let res = bench.run(&format!("axpy        {n} [{}]", b.name()), || {
                simd::axpy_on(b, 1e-4, &x, &mut acc);
                black_box(acc[0]);
            });
            report.add(
                &res,
                &[("n", n as f64)],
                &[("kernel", "axpy"), ("backend", b.name())],
            );
            axpys.push((b, res));
        }
        speedup_line("axpy", n, &axpys);
        println!();
    }

    // -----------------------------------------------------------------
    // The gemm register microkernel, per backend (the packing around it
    // is backend-independent, so this isolates exactly what dispatches)
    // -----------------------------------------------------------------
    let kc = 256; // the KC default in blas.rs
    let reps = 10_000; // 2*kc*MR*NR flops per call is too brief to time alone
    let ap = randv(kc * MR, 21);
    let bp = randv(kc * NR, 22);
    let mut micro = Vec::new();
    for &b in &simd::available() {
        let mut acc = [[0.0f32; NR]; MR];
        let res = bench.run(&format!("microkernel kc={kc} x{reps} [{}]", b.name()), || {
            for _ in 0..reps {
                simd::microkernel_on(b, kc, &ap, &bp, &mut acc);
            }
            black_box(acc[0][0]);
        });
        let gflops = (2 * kc * MR * NR * reps) as f64 / res.stats.median() / 1e9;
        println!("  -> microkernel [{}]: {gflops:.2} GFLOP/s", b.name());
        report.add(
            &res,
            &[("kc", kc as f64), ("reps", reps as f64), ("gflops", gflops)],
            &[("kernel", "microkernel"), ("backend", b.name())],
        );
        micro.push((b, res));
    }
    speedup_line("microkernel", kc, &micro);
    println!();

    // -----------------------------------------------------------------
    // Composite kernels on the ACTIVE dispatch path (these go through
    // the public blas/qr entry points like the solvers do)
    // -----------------------------------------------------------------
    let sizes: &[usize] = if quick_mode() { &[128] } else { &[128, 256, 512] };
    for &n in sizes {
        let a = randm(n, n, 1);
        let b = randm(n, n, 2);
        let tall = randm(4 * n, n, 3);
        let x: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();

        let gemm_res = bench.run(&format!("gemm        {n}x{n} * {n}x{n}"), || {
            black_box(blas::gemm(&a, &b).as_slice()[0]);
        });
        // effective GFLOP/s for the gemm (2 n^3 flops)
        let gflops = 2.0 * (n as f64).powi(3) / gemm_res.stats.median() / 1e9;
        println!("  -> gemm {n}: {gflops:.2} GFLOP/s");
        report.add(
            &gemm_res,
            &[("n", n as f64), ("gflops", gflops)],
            &[("kernel", "gemm"), ("backend", active.name())],
        );

        let gemv_res = bench.run(&format!("gemv        {n}x{n}"), || {
            let mut y = vec![0.0f32; n];
            blas::gemv(&a, &x, &mut y);
            black_box(y[0]);
        });
        report.add(
            &gemv_res,
            &[("n", n as f64)],
            &[("kernel", "gemv"), ("backend", active.name())],
        );
        let gram_res = bench.run(&format!("gram        {}x{n}", 4 * n), || {
            black_box(blas::gram(&tall).as_slice()[0]);
        });
        report.add(
            &gram_res,
            &[("n", n as f64)],
            &[("kernel", "gram"), ("backend", active.name())],
        );
        let qr_res = bench.run(&format!("qr          {}x{n}", 4 * n), || {
            black_box(qr::householder_qr(&tall).r.as_slice()[0]);
        });
        report.add(
            &qr_res,
            &[("n", n as f64)],
            &[("kernel", "qr"), ("backend", active.name())],
        );
        let inv_res = bench.run(&format!("gj_inverse  {n}x{n}"), || {
            let g = blas::gram(&tall);
            black_box(inverse::gauss_jordan_inverse(&g).unwrap().as_slice()[0]);
        });
        report.add(
            &inv_res,
            &[("n", n as f64)],
            &[("kernel", "gj_inverse"), ("backend", active.name())],
        );
        let r = {
            let f = qr::householder_qr(&tall);
            f.r
        };
        let bs_res = bench.run(&format!("backsub     {n}"), || {
            black_box(triangular::back_substitute(&r, &x)[0]);
        });
        report.add(
            &bs_res,
            &[("n", n as f64)],
            &[("kernel", "backsub"), ("backend", active.name())],
        );
        println!();
    }

    match report.write() {
        Ok(path) => println!("wrote {} ({} records)", path.display(), report.len()),
        Err(e) => {
            eprintln!("failed to write bench json: {e}");
            std::process::exit(1);
        }
    }
}
