//! Distributed epoch cost: per-epoch wall time and wire bytes for the
//! threaded channel cluster at J ∈ {2, 4, 8}, through the unified
//! consensus driver.
//!
//! Wire traffic is counted by the `Transport` byte counters (framing
//! included).  Each J is run at three epoch budgets on fresh clusters;
//! total bytes must be EXACTLY affine in the epoch count
//! (`init_bytes + T * per_epoch_bytes`) — any super-linear growth would
//! mean the leader's per-epoch traffic (or retained buffers feeding it)
//! grows with T.  The bench asserts this flatness and records it in
//! `BENCH_distributed_epoch.json`.

use dapc::benchkit::{quick_mode, Bench, JsonReport};
use dapc::coordinator::LocalCluster;
use dapc::prelude::*;
use dapc::solver::{drive_apc, ApcVariant};
use dapc::sparse::generate::GeneratorConfig;

fn main() {
    // m = 16n keeps every J in {2,4,8} in the paper's tall regime
    let n = if quick_mode() { 64 } else { 256 };
    let m = 16 * n;
    let shape = format!("{m}x{n}");
    let ds = GeneratorConfig::table1(m, n).generate(2327);
    let bench = Bench::new(0, 1);
    let mut report = JsonReport::new("distributed_epoch");
    let budgets: [usize; 3] = if quick_mode() { [4, 8, 16] } else { [10, 20, 40] };

    println!(
        "=== distributed epoch cost: decomposed APC over the channel \
         cluster, {shape}, J in {{2,4,8}}, T in {budgets:?} ==="
    );
    for &j in &[2usize, 4, 8] {
        // (epochs, total wire bytes, iterate seconds)
        let mut runs: Vec<(usize, u64, f64)> = Vec::new();
        for &epochs in &budgets {
            let opts = SolveOptions { epochs, ..Default::default() };
            let mut wire_total = 0u64;
            let mut iterate_s = 0.0f64;
            let res = bench.run_once(&format!("J={j} T={epochs}"), || {
                let mut cluster = LocalCluster::spawn(j, NativeEngine::new)
                    .expect("cluster");
                let r = drive_apc(
                    cluster.leader.backend_mut(),
                    &ds.matrix,
                    &ds.rhs,
                    ApcVariant::Decomposed,
                    &opts,
                )
                .expect("solve");
                // read counters BEFORE shutdown frames are sent
                let (sent, received) = cluster.leader.wire_bytes();
                wire_total = sent + received;
                iterate_s = r.iterate_time.as_secs_f64();
                cluster.join();
            });
            runs.push((epochs, wire_total, iterate_s));
            report.add(
                &res,
                &[
                    ("j", j as f64),
                    ("epochs", epochs as f64),
                    ("iterate_s", iterate_s),
                    ("per_epoch_s", iterate_s / epochs as f64),
                    ("wire_bytes_total", wire_total as f64),
                ],
                &[("shape", shape.as_str()), ("backend", "cluster-channel")],
            );
        }

        // flatness: total bytes must be affine in T with one slope
        let (t0, b0, _) = runs[0];
        let (t1, b1, _) = runs[1];
        let (t2, b2, _) = runs[2];
        assert_eq!(
            (b1 - b0) % (t1 - t0) as u64,
            0,
            "J={j}: wire bytes not an integer multiple of epochs"
        );
        let per_epoch = (b1 - b0) / (t1 - t0) as u64;
        let init_bytes = b0 - t0 as u64 * per_epoch;
        assert_eq!(
            b2,
            init_bytes + t2 as u64 * per_epoch,
            "J={j}: per-epoch wire bytes are NOT flat in epoch count \
             (leader traffic grows with T)"
        );
        let (_, _, iter_s) = runs[2];
        println!(
            "  -> J={j}: init {init_bytes} B, {per_epoch} B/epoch (flat \
             across T={budgets:?}), {:.3} ms/epoch",
            1e3 * iter_s / t2 as f64
        );
        report.add(
            &bench.run_once(&format!("J={j} summary"), || {}),
            &[
                ("j", j as f64),
                ("wire_bytes_per_epoch", per_epoch as f64),
                ("wire_bytes_init", init_bytes as f64),
                ("flat_in_epoch_count", 1.0),
            ],
            &[("shape", shape.as_str()), ("backend", "cluster-channel")],
        );
    }

    match report.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
}
