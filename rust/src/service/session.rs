//! [`SolverSession`]: register a matrix once, then serve an arbitrary
//! stream of right-hand sides (single or batched) over any
//! [`SessionBackend`].

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{DapcError, Result};
use crate::obs::{self, Counter, Histogram};
use crate::partition::PartitionPlan;
use crate::solver::driver::apc_label;
use crate::solver::{
    auto_dgd_step, drive_apc_epochs_multi, drive_dgd_epochs_multi,
    init_kind_for, resident_partition_bytes, residual_norm, ApcVariant,
    SessionBackend, SolveOptions, SolveReport,
};
use crate::sparse::CsrMatrix;

use super::ServiceStats;

/// Service-layer metric handles, resolved from the global registry once
/// at registration.  Contract (checked by the metrics validator): the
/// `service.rhs_served` counter always equals `service.warm_rhs_ns`
/// observations plus `service.batch_rhs_ns` observations — a batch of k
/// records its amortized per-RHS latency k times.
struct SessionObs {
    cold_register_ns: Arc<Histogram>,
    warm_rhs_ns: Arc<Histogram>,
    batch_rhs_ns: Arc<Histogram>,
    rhs_served: Arc<Counter>,
}

impl SessionObs {
    fn new() -> Self {
        Self {
            cold_register_ns: obs::histogram("service.cold_register_ns"),
            warm_rhs_ns: obs::histogram("service.warm_rhs_ns"),
            batch_rhs_ns: obs::histogram("service.batch_rhs_ns"),
            rhs_served: obs::counter("service.rhs_served"),
        }
    }
}

/// Which algorithm a session serves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionAlgorithm {
    /// Consensus solves (decomposed or classical init, chosen once at
    /// registration together with the regime).
    Apc(ApcVariant),
    /// Distributed gradient descent (gradient-only workers, no
    /// factorization; the step size is resolved once at registration).
    Dgd,
}

/// A warm solver session: the matrix is registered (factorized and
/// retained partition-side) exactly once, after which [`Self::solve`]
/// and [`Self::solve_batch`] serve right-hand sides at per-RHS cost
/// O(l n + n^2) + epochs — never a second factorization.
///
/// Works over any [`SessionBackend`]: the in-process backend for
/// single-host serving, the cluster backend (wire protocol v4) for
/// distributed serving.  Warm results are bit-identical to cold
/// one-shot solves on both.
///
/// When metrics are enabled ([`crate::obs`]) the session feeds the
/// `service.cold_register_ns` / `service.warm_rhs_ns` /
/// `service.batch_rhs_ns` latency histograms and the
/// `service.rhs_served` counter — ROADMAP item 5's p50/p99 per-RHS
/// serving latency comes straight from these.
pub struct SolverSession<'b, B: SessionBackend + ?Sized> {
    backend: &'b mut B,
    a: CsrMatrix,
    plan: PartitionPlan,
    algorithm: SessionAlgorithm,
    opts: SolveOptions,
    n_target: usize,
    /// DGD step size, resolved once at registration (unused for APC).
    alpha: f32,
    /// Reused per-solve eq. (5)/(7) accumulators (k columns).
    accs: Vec<Vec<f64>>,
    stats: ServiceStats,
    obs: SessionObs,
}

impl<'b, B: SessionBackend + ?Sized> SolverSession<'b, B> {
    /// Register `a` into the backend: partition, factorize, retain.
    /// This is the session's one-time cold cost ([`ServiceStats`]
    /// records it).
    pub fn register(
        backend: &'b mut B,
        a: CsrMatrix,
        algorithm: SessionAlgorithm,
        opts: SolveOptions,
    ) -> Result<Self> {
        let j = backend.partitions();
        if j == 0 {
            return Err(DapcError::Coordinator(
                "solver session needs at least one partition/worker (got 0)"
                    .into(),
            ));
        }
        if opts.x_true.is_some() || opts.collect_x_parts {
            // the serving layer returns raw solves only; silently
            // dropping a requested trace/x_parts would hand callers a
            // report that is NOT equivalent to the cold path's
            return Err(DapcError::Config(
                "solver sessions do not support per-epoch traces (x_true) \
                 or x_parts collection; use the one-shot \
                 drive_apc/drive_dgd path for convergence analysis"
                    .into(),
            ));
        }
        let (m, n) = a.shape();
        let plan = PartitionPlan::contiguous(m, n, j)?;
        let session_obs = SessionObs::new();
        let t0 = Instant::now();
        let ot = obs::now();
        let (n_target, alpha) = match algorithm {
            SessionAlgorithm::Apc(variant) => {
                let kind = init_kind_for(variant, plan.regime);
                (backend.register_matrix(kind, &plan, &a)?, 0.0)
            }
            SessionAlgorithm::Dgd => {
                backend.register_grad(&plan, &a)?;
                let alpha = if opts.dgd_step > 0.0 {
                    opts.dgd_step
                } else {
                    auto_dgd_step(&a)
                };
                (plan.n, alpha)
            }
        };
        // pure shape arithmetic: what each registered partition keeps
        // resident for warm serving (block + projector + prepacked
        // panels + seed factors); DGD workers retain no factorization
        let resident = match algorithm {
            SessionAlgorithm::Apc(variant) => {
                let kind = init_kind_for(variant, plan.regime);
                plan.blocks
                    .iter()
                    .map(|b| resident_partition_bytes(kind, b.len(), plan.n))
                    .collect()
            }
            SessionAlgorithm::Dgd => Vec::new(),
        };
        obs::record_since(&session_obs.cold_register_ns, ot);
        let stats = ServiceStats {
            register_time: t0.elapsed(),
            resident_partition_bytes: resident,
            ..ServiceStats::default()
        };
        Ok(Self {
            backend,
            a,
            plan,
            algorithm,
            opts,
            n_target,
            alpha,
            accs: Vec::new(),
            stats,
            obs: session_obs,
        })
    }

    /// Serve one right-hand side through the warm session.
    pub fn solve(&mut self, b: &[f32]) -> Result<SolveReport> {
        let mut reports = self.solve_batch_refs(&[b])?;
        Ok(reports.pop().expect("one report per rhs"))
    }

    /// Serve `bs.len()` right-hand sides as ONE column-blocked batch:
    /// all columns move through a single epoch loop, so each projector
    /// sweep is shared by the whole batch.  Results are bit-identical
    /// to calling [`Self::solve`] per column; reported times are the
    /// batch cost divided evenly across columns (the amortized view).
    pub fn solve_batch(&mut self, bs: &[Vec<f32>]) -> Result<Vec<SolveReport>> {
        let refs: Vec<&[f32]> = bs.iter().map(|b| b.as_slice()).collect();
        self.solve_batch_refs(&refs)
    }

    fn solve_batch_refs(&mut self, bs: &[&[f32]]) -> Result<Vec<SolveReport>> {
        let k = bs.len();
        if k == 0 {
            return Err(DapcError::Shape(
                "solve_batch needs at least one rhs".into(),
            ));
        }
        let (m, n) = self.a.shape();
        for b in bs {
            if b.len() != m {
                return Err(DapcError::Shape(format!(
                    "rhs length {} != matrix rows {m}",
                    b.len()
                )));
            }
        }

        let t0 = Instant::now();
        let (seed_time, mut xbars, algorithm) = match self.algorithm {
            SessionAlgorithm::Apc(variant) => {
                self.accs.resize_with(k, Vec::new);
                self.backend.seed_rhs(&self.plan, bs, &mut self.accs)?;
                let seed_time = t0.elapsed();
                let xbars = drive_apc_epochs_multi(
                    &mut *self.backend,
                    &mut self.accs,
                    &self.opts,
                )?;
                (seed_time, xbars, apc_label(variant))
            }
            SessionAlgorithm::Dgd => {
                self.backend.seed_grad_rhs(&self.plan, bs)?;
                let seed_time = t0.elapsed();
                let xs = drive_dgd_epochs_multi(
                    &mut *self.backend,
                    k,
                    self.n_target,
                    self.alpha,
                    self.opts.epochs,
                )?;
                (seed_time, xs, "dgd")
            }
        };
        let total = t0.elapsed();
        let iterate_time = total.saturating_sub(seed_time);

        // amortized per-RHS timing view (f64 division: no clamping cast,
        // same fix as ServiceStats::amortized_per_rhs)
        let per_init =
            Duration::from_secs_f64(seed_time.as_secs_f64() / k as f64);
        let per_iter =
            Duration::from_secs_f64(iterate_time.as_secs_f64() / k as f64);

        let mut reports = Vec::with_capacity(k);
        for (mut xbar, b) in xbars.drain(..).zip(bs) {
            xbar.truncate(n);
            let residual = residual_norm(&self.a, b, &xbar);
            reports.push(SolveReport {
                xbar,
                x_parts: Vec::new(),
                trace: None,
                residual: Some(residual),
                init_time: per_init,
                iterate_time: per_iter,
                algorithm,
                engine: self.backend.backend_name(),
                epochs: self.opts.epochs,
            });
        }
        self.stats.record(k, total);
        // per-RHS latency: a single serve lands in the warm histogram, a
        // batch of k records its amortized per-RHS cost k times into the
        // batched one — so warm + batched observation counts always sum
        // to the rhs_served counter (the validator cross-checks this)
        let per_rhs_ns = (total.as_nanos() / k as u128) as u64;
        if k == 1 {
            self.obs.warm_rhs_ns.record(per_rhs_ns);
        } else {
            for _ in 0..k {
                self.obs.batch_rhs_ns.record(per_rhs_ns);
            }
        }
        self.obs.rhs_served.add(k as u64);
        Ok(reports)
    }

    /// Amortization counters for this session.
    pub fn stats(&self) -> &ServiceStats {
        &self.stats
    }

    /// The registered matrix.
    pub fn matrix(&self) -> &CsrMatrix {
        &self.a
    }

    /// Partition count the session was registered with.
    pub fn partitions(&self) -> usize {
        self.plan.j()
    }

    /// The algorithm this session serves.
    pub fn algorithm(&self) -> SessionAlgorithm {
        self.algorithm
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::{
        drive_apc, drive_dgd, InProcessBackend, NativeEngine, Solver as _,
    };
    use crate::sparse::generate::GeneratorConfig;

    fn opts(epochs: usize) -> SolveOptions {
        SolveOptions { epochs, ..Default::default() }
    }

    #[test]
    fn warm_solve_bitwise_matches_cold_solve() {
        let ds = GeneratorConfig::small_demo(16, 3).generate(11);
        let e = NativeEngine::new();
        for variant in [ApcVariant::Decomposed, ApcVariant::Classical] {
            let mut cold_backend = InProcessBackend::new(&e, 3);
            let cold = drive_apc(
                &mut cold_backend,
                &ds.matrix,
                &ds.rhs,
                variant,
                &opts(15),
            )
            .unwrap();

            let mut backend = InProcessBackend::new(&e, 3);
            let mut session = SolverSession::register(
                &mut backend,
                ds.matrix.clone(),
                SessionAlgorithm::Apc(variant),
                opts(15),
            )
            .unwrap();
            let warm = session.solve(&ds.rhs).unwrap();
            assert_eq!(warm.xbar, cold.xbar, "{variant:?}");
            assert_eq!(warm.residual, cold.residual, "{variant:?}");
            // second serve of the SAME rhs: state fully re-seeded
            let warm2 = session.solve(&ds.rhs).unwrap();
            assert_eq!(warm2.xbar, cold.xbar, "{variant:?} resolve");
        }
    }

    #[test]
    fn register_reports_resident_factorization_bytes() {
        let ds = GeneratorConfig::small_demo(16, 3).generate(11);
        let e = NativeEngine::new();
        let mut backend = InProcessBackend::new(&e, 3);
        let session = SolverSession::register(
            &mut backend,
            ds.matrix.clone(),
            SessionAlgorithm::Apc(ApcVariant::Decomposed),
            opts(5),
        )
        .unwrap();
        let stats = session.stats();
        assert_eq!(stats.resident_partition_bytes.len(), 3);
        let (m, n) = ds.matrix.shape();
        let plan = PartitionPlan::contiguous(m, n, 3).unwrap();
        let kind = init_kind_for(ApcVariant::Decomposed, plan.regime);
        for (blk, &bytes) in
            plan.blocks.iter().zip(&stats.resident_partition_bytes)
        {
            assert_eq!(
                bytes,
                resident_partition_bytes(kind, blk.len(), plan.n)
            );
        }
        assert!(stats.summary().contains("resident"));

        // DGD workers retain no factorization: nothing to report
        let mut b2 = InProcessBackend::new(&e, 2);
        let dgd = SolverSession::register(
            &mut b2,
            ds.matrix.clone(),
            SessionAlgorithm::Dgd,
            SolveOptions { epochs: 2, ..Default::default() },
        )
        .unwrap();
        assert!(dgd.stats().resident_partition_bytes.is_empty());
        assert!(!dgd.stats().summary().contains("resident"));
    }

    #[test]
    fn warm_dgd_bitwise_matches_cold_dgd() {
        let ds = GeneratorConfig::small_demo(12, 2).generate(12);
        let e = NativeEngine::new();
        let o = SolveOptions { epochs: 30, dgd_step: 0.0, ..Default::default() };

        let mut cold_backend = InProcessBackend::new(&e, 2);
        let cold =
            drive_dgd(&mut cold_backend, &ds.matrix, &ds.rhs, &o).unwrap();

        let mut backend = InProcessBackend::new(&e, 2);
        let mut session = SolverSession::register(
            &mut backend,
            ds.matrix.clone(),
            SessionAlgorithm::Dgd,
            o,
        )
        .unwrap();
        let warm = session.solve(&ds.rhs).unwrap();
        assert_eq!(warm.xbar, cold.xbar);
        assert_eq!(warm.residual, cold.residual);
    }

    #[test]
    fn batch_bitwise_matches_sequential_solves() {
        let ds = GeneratorConfig::small_demo(14, 2).generate(13);
        let e = NativeEngine::new();
        // three distinct consistent rhs against the one registered matrix
        let bs: Vec<Vec<f32>> = (0..3)
            .map(|i| {
                let mut g = crate::rng::seeded(400 + i);
                let x: Vec<f32> =
                    (0..ds.matrix.cols()).map(|_| g.normal_f32()).collect();
                let mut b = vec![0.0f32; ds.matrix.rows()];
                ds.matrix.spmv_into(&x, &mut b);
                b
            })
            .collect();

        let mut b1 = InProcessBackend::new(&e, 2);
        let mut seq = SolverSession::register(
            &mut b1,
            ds.matrix.clone(),
            SessionAlgorithm::Apc(ApcVariant::Decomposed),
            opts(20),
        )
        .unwrap();
        let singles: Vec<_> =
            bs.iter().map(|b| seq.solve(b).unwrap()).collect();

        let mut b2 = InProcessBackend::new(&e, 2);
        let mut batched = SolverSession::register(
            &mut b2,
            ds.matrix.clone(),
            SessionAlgorithm::Apc(ApcVariant::Decomposed),
            opts(20),
        )
        .unwrap();
        let batch = batched.solve_batch(&bs).unwrap();

        assert_eq!(batch.len(), 3);
        for (one, many) in singles.iter().zip(&batch) {
            assert_eq!(one.xbar, many.xbar);
            assert_eq!(one.residual, many.residual);
        }
        assert_eq!(batched.stats().rhs_served, 3);
        assert_eq!(batched.stats().solve_calls, 1);
        assert_eq!(batched.stats().max_batch, 3);
        assert_eq!(seq.stats().solve_calls, 3);
    }

    #[test]
    fn session_matches_solver_facade() {
        // the ergonomic one-shot facade and a warm session agree
        let ds = GeneratorConfig::small_demo(16, 2).generate(14);
        let e = NativeEngine::new();
        let via_facade = crate::solver::DapcSolver::new(opts(10))
            .solve(&e, &ds.matrix, &ds.rhs, 2)
            .unwrap();
        let mut backend = InProcessBackend::new(&e, 2);
        let mut session = SolverSession::register(
            &mut backend,
            ds.matrix.clone(),
            SessionAlgorithm::Apc(ApcVariant::Decomposed),
            opts(10),
        )
        .unwrap();
        assert_eq!(session.solve(&ds.rhs).unwrap().xbar, via_facade.xbar);
    }

    #[test]
    fn trace_and_x_parts_options_rejected_at_register() {
        let ds = GeneratorConfig::small_demo(8, 1).generate(16);
        let e = NativeEngine::new();
        for o in [
            SolveOptions {
                x_true: Some(ds.x_true.clone()),
                ..Default::default()
            },
            SolveOptions { collect_x_parts: true, ..Default::default() },
        ] {
            let mut backend = InProcessBackend::new(&e, 1);
            let err = SolverSession::register(
                &mut backend,
                ds.matrix.clone(),
                SessionAlgorithm::Apc(ApcVariant::Decomposed),
                o,
            )
            .map(|_| ())
            .unwrap_err();
            assert!(err.to_string().contains("do not support"), "{err}");
        }
    }

    #[test]
    fn per_rhs_histograms_sum_to_served_counter() {
        // the metrics-validate cross-check relies on this exact split:
        // k == 1 -> one warm observation, k > 1 -> k batched ones
        let _g = crate::obs::test_lock();
        crate::obs::set_enabled(true);
        // the registry is process-global and cumulative: diff baselines
        let warm0 = obs::histogram("service.warm_rhs_ns").count();
        let batch0 = obs::histogram("service.batch_rhs_ns").count();
        let served0 = obs::counter("service.rhs_served").get();

        let ds = GeneratorConfig::small_demo(14, 2).generate(21);
        let bs: Vec<Vec<f32>> = (0..3)
            .map(|i| {
                let mut g = crate::rng::seeded(700 + i);
                let x: Vec<f32> =
                    (0..ds.matrix.cols()).map(|_| g.normal_f32()).collect();
                let mut b = vec![0.0f32; ds.matrix.rows()];
                ds.matrix.spmv_into(&x, &mut b);
                b
            })
            .collect();
        let e = NativeEngine::new();
        let mut backend = InProcessBackend::new(&e, 2);
        let mut session = SolverSession::register(
            &mut backend,
            ds.matrix.clone(),
            SessionAlgorithm::Apc(ApcVariant::Decomposed),
            opts(5),
        )
        .unwrap();
        session.solve(&ds.rhs).unwrap();
        session.solve_batch(&bs).unwrap();

        let warm = obs::histogram("service.warm_rhs_ns").count() - warm0;
        let batch = obs::histogram("service.batch_rhs_ns").count() - batch0;
        let served = obs::counter("service.rhs_served").get() - served0;
        assert_eq!(warm, 1);
        assert_eq!(batch, 3);
        assert_eq!(served, warm + batch);
        assert!(
            obs::histogram("service.cold_register_ns").count() >= 1,
            "registration latency was not observed"
        );
        crate::obs::set_enabled(false);
    }

    #[test]
    fn bad_rhs_rejected() {
        let ds = GeneratorConfig::small_demo(8, 1).generate(15);
        let e = NativeEngine::new();
        let mut backend = InProcessBackend::new(&e, 1);
        let mut session = SolverSession::register(
            &mut backend,
            ds.matrix.clone(),
            SessionAlgorithm::Apc(ApcVariant::Decomposed),
            opts(5),
        )
        .unwrap();
        assert!(session.solve(&ds.rhs[..3]).is_err());
        assert!(session.solve_batch(&[]).is_err());
    }
}
