//! Blocked BLAS-like primitives for the native engine.
//!
//! `gemm` follows the BLIS/GotoBLAS decomposition: the operand matrices
//! are *packed* into contiguous panels sized to the cache hierarchy, and
//! an `MR x NR` register-tiled microkernel does all the flops over the
//! packed panels.  `gemv` accumulates per-row dot products (with a pooled
//! row-chunk-parallel variant for the consensus hot path).
//!
//! # Kernel dispatch (see [`super::simd`])
//!
//! The flop-carrying primitives — [`dot`], [`dot_wide`], [`axpy`],
//! [`widen`] and the gemm microkernel — are thin wrappers over the
//! runtime-dispatched SIMD layer in `linalg::simd`: AVX2+FMA intrinsics
//! when the CPU has them, a **lane-structured scalar fallback**
//! otherwise (or under `DAPC_FORCE_SCALAR=1`).  The two paths are
//! bit-identical by construction — the scalar fallback accumulates in
//! the same fixed 8-lane order with the same horizontal reduction tree
//! the vector path uses — so the dispatch choice, exactly like the
//! thread count, can never change a result.  `simd.rs` documents the
//! contract (lane order, remainder handling, where FMA is and is not
//! allowed, NaN policy); `tests/simd_lane_contract.rs` enforces it
//! bitwise across every `n % 8` remainder class.
//!
//! # Block-size tuning (`MC`/`KC`/`NC`)
//!
//! The three cache block sizes map onto the cache hierarchy:
//!
//! * `KC x NR` slivers of the packed B panel are streamed from L1 by the
//!   microkernel, so `KC` is chosen to keep one `MC x KC` A panel
//!   resident in L2: `MC * KC * 4 bytes` ≈ 64 KiB at the defaults —
//!   half of a typical 128-512 KiB L2, leaving room for the B sliver
//!   and C tile;
//! * `KC * NC * 4 bytes` (the packed B panel) targets L3 (512 KiB at the
//!   defaults);
//! * `MR x NR` (4 x 8, defined next to the microkernel in `simd.rs`)
//!   keeps the accumulator tile in registers: 32 f32 accumulators =
//!   4 vector registers of 8 lanes, held explicitly by the AVX2
//!   microkernel and reliably register-allocated by LLVM on the scalar
//!   fallback.
//!
//! Methodology: sweep one constant at a time against
//! `cargo bench --bench microbench_linalg` (the gemm GFLOP/s line) and
//! then confirm on `benches/parallel_scaling.rs` end-to-end — init-phase
//! QR is gemm-shaped, so end-to-end gains track the microbench.  Values
//! below were chosen for a generic x86-64 container; re-tune when the
//! deployment hardware is known (see ROADMAP "Performance").

use super::simd::{self, MR, NR};
use super::Matrix;
use crate::parallel::ThreadPool;

/// Rows of the packed A panel (L2 block).
const MC: usize = 64;
/// Shared (depth) dimension of both packed panels (L1/L2 block).
const KC: usize = 256;
/// Columns of the packed B panel (L3 block).
const NC: usize = 512;

/// `y += alpha * x` (axpy), runtime-dispatched (`linalg::simd`).
///
/// Elementwise f32 mul + add on both backends — no reduction, no f32
/// FMA — so the dispatch choice never changes a bit.  Length mismatch
/// is checked in release builds too: a silent mismatch here would read
/// past the kernel's assumptions in every caller.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    simd::axpy_on(simd::active(), alpha, x, y)
}

/// Dot product with f64 accumulation, runtime-dispatched
/// (`linalg::simd`).
///
/// Both backends accumulate in the same fixed 8-lane order (8
/// independent f64 accumulators, one shared horizontal reduction tree,
/// sequential `n % 8` tail added last), so the result is bit-identical
/// whichever path runs.  The AVX2 path may fuse the multiply-add: the
/// widened f32 products are exact in f64, so the fused rounding point
/// is the same one the scalar fallback rounds at.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    simd::dot_on(simd::active(), x, y)
}

/// Widen an f32 slice into a caller-provided f64 buffer.  f32 -> f64 is
/// exact, so downstream arithmetic over the widened values is
/// bit-identical to widening on the fly (and vectorizing the conversion
/// is trivially lane-safe).
#[inline]
pub fn widen(src: &[f32], dst: &mut [f64]) {
    simd::widen_on(simd::active(), src, dst)
}

/// [`dot`] against a pre-widened left operand: same fixed 8-lane f64
/// accumulator split, same summation order, same rounding points — the
/// result is bit-identical to `dot(x32, y)` whenever `x[i] == x32[i] as
/// f64`.  The batched multi-RHS update uses this to widen each projector
/// row ONCE and reuse it across every column of the batch.  (Unlike
/// [`dot`], no backend may fuse here: a general 53-bit x 24-bit product
/// is not exact, so both paths round the product before accumulating.)
#[inline]
pub fn dot_wide(x: &[f64], y: &[f32]) -> f64 {
    simd::dot_wide_on(simd::active(), x, y)
}

/// `y = A x` for row-major A (rows x cols), x of length cols.
pub fn gemv(a: &Matrix, x: &[f32], y: &mut [f32]) {
    assert_eq!(a.cols(), x.len());
    assert_eq!(a.rows(), y.len());
    for i in 0..a.rows() {
        y[i] = dot(a.row(i), x) as f32;
    }
}

/// `y = A x` with the row range split across pool workers.
///
/// Bitwise-identical to [`gemv`] for any thread count: each output row is
/// an independent [`dot`] over the same operands in the same order, so
/// parallelism never reorders a reduction.  Must not be called from
/// inside another scope on the same pool (the pool does not nest).
pub fn gemv_pooled(pool: &ThreadPool, a: &Matrix, x: &[f32], y: &mut [f32]) {
    assert_eq!(a.cols(), x.len());
    assert_eq!(a.rows(), y.len());
    let rows = a.rows();
    if rows == 0 {
        return;
    }
    let parts = pool.size().min(rows).max(1);
    let chunk = rows.div_ceil(parts);
    pool.scope(|s| {
        for (ci, yc) in y.chunks_mut(chunk).enumerate() {
            let lo = ci * chunk;
            s.spawn(move || {
                for (r, yi) in yc.iter_mut().enumerate() {
                    *yi = dot(a.row(lo + r), x) as f32;
                }
            });
        }
    });
}

/// `y = A^T x` for row-major A, x of length rows (avoids materializing A^T).
pub fn gemv_t(a: &Matrix, x: &[f32], y: &mut [f32]) {
    assert_eq!(a.rows(), x.len());
    assert_eq!(a.cols(), y.len());
    y.fill(0.0);
    for i in 0..a.rows() {
        axpy(x[i], a.row(i), y);
    }
}

/// `C = A B` (packed panels + register-tiled microkernel, row-major).
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows(), b.cols());
    gemm_into(a, b, &mut c);
    c
}

/// `C = A B` into a caller-provided output (overwritten).
pub fn gemm_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols(), b.rows(), "gemm inner dimension mismatch");
    assert_eq!(c.rows(), a.rows(), "gemm output rows mismatch");
    assert_eq!(c.cols(), b.cols(), "gemm output cols mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    c.as_mut_slice().fill(0.0);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    // one dispatch decision for the whole product, hoisted out of the
    // tile loops (the choice cannot affect the bits — simd module docs)
    let backend = simd::active();

    // pack buffers sized to the largest panel this problem needs
    let kc_max = KC.min(k);
    let mc_max = round_up(MC.min(m), MR);
    let nc_max = round_up(NC.min(n), NR);
    let mut a_pack = vec![0.0f32; mc_max * kc_max];
    let mut b_pack = vec![0.0f32; kc_max * nc_max];

    let mut jc = 0;
    while jc < n {
        let nc = NC.min(n - jc);
        let col_panels = nc.div_ceil(NR);
        let mut pc = 0;
        while pc < k {
            let kc = KC.min(k - pc);
            pack_b(b, pc, jc, kc, nc, &mut b_pack);
            let mut ic = 0;
            while ic < m {
                let mc = MC.min(m - ic);
                let row_panels = mc.div_ceil(MR);
                pack_a(a, ic, pc, mc, kc, &mut a_pack);
                for q in 0..col_panels {
                    let jr = q * NR;
                    let nr = NR.min(nc - jr);
                    let bp = &b_pack[q * kc * NR..(q + 1) * kc * NR];
                    for t in 0..row_panels {
                        let ir = t * MR;
                        let mr = MR.min(mc - ir);
                        let ap = &a_pack[t * kc * MR..(t + 1) * kc * MR];
                        let mut acc = [[0.0f32; NR]; MR];
                        simd::microkernel_on(backend, kc, ap, bp, &mut acc);
                        // fringe lanes were zero-padded in the packs, so
                        // the full tile is valid; write only the live part
                        for i in 0..mr {
                            let crow = c.row_mut(ic + ir + i);
                            for (j, &v) in acc[i][..nr].iter().enumerate() {
                                crow[jc + jr + j] += v;
                            }
                        }
                    }
                }
                ic += MC;
            }
            pc += KC;
        }
        jc += NC;
    }
}

#[inline]
fn round_up(x: usize, m: usize) -> usize {
    x.div_ceil(m) * m
}

/// Pack an `mc x kc` block of A into MR-row panels, k-major inside each
/// panel: `buf[q*kc*MR + p*MR + i] = A[ic + q*MR + i, pc + p]` (zero
/// padding for the ragged last panel).
fn pack_a(
    a: &Matrix,
    ic: usize,
    pc: usize,
    mc: usize,
    kc: usize,
    buf: &mut [f32],
) {
    let panels = mc.div_ceil(MR);
    for q in 0..panels {
        let r0 = q * MR;
        let rows = MR.min(mc - r0);
        let base = q * kc * MR;
        for i in 0..MR {
            if i < rows {
                let row = &a.row(ic + r0 + i)[pc..pc + kc];
                for (p, &v) in row.iter().enumerate() {
                    buf[base + p * MR + i] = v;
                }
            } else {
                for p in 0..kc {
                    buf[base + p * MR + i] = 0.0;
                }
            }
        }
    }
}

/// Pack a `kc x nc` block of B into NR-column panels, k-major inside each
/// panel: `buf[q*kc*NR + p*NR + j] = B[pc + p, jc + q*NR + j]` (zero
/// padding for the ragged last panel).
fn pack_b(
    b: &Matrix,
    pc: usize,
    jc: usize,
    kc: usize,
    nc: usize,
    buf: &mut [f32],
) {
    let panels = nc.div_ceil(NR);
    for p in 0..kc {
        let brow = b.row(pc + p);
        for q in 0..panels {
            let c0 = q * NR;
            let cols = NR.min(nc - c0);
            let off = q * kc * NR + p * NR;
            buf[off..off + cols]
                .copy_from_slice(&brow[jc + c0..jc + c0 + cols]);
            for j in cols..NR {
                buf[off + j] = 0.0;
            }
        }
    }
}

/// `C = A^T B` without materializing the transpose.
pub fn gemm_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows());
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    for kk in 0..k {
        let arow = a.row(kk);
        let brow = b.row(kk);
        for i in 0..m {
            let aik = arow[i];
            if aik != 0.0 {
                axpy(aik, brow, c.row_mut(i));
            }
        }
    }
    c
}

/// Gram matrix `A^T A` exploiting symmetry (classical-APC init cost).
pub fn gram(a: &Matrix) -> Matrix {
    let n = a.cols();
    let mut g = Matrix::zeros(n, n);
    for r in 0..a.rows() {
        let row = a.row(r);
        for i in 0..n {
            let ri = row[i];
            if ri != 0.0 {
                // only the upper triangle
                for j in i..n {
                    g[(i, j)] += ri * row[j];
                }
            }
        }
    }
    for i in 0..n {
        for j in 0..i {
            g[(i, j)] = g[(j, i)];
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::seeded;

    fn randm(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut g = seeded(seed);
        Matrix::from_fn(rows, cols, |_, _| g.normal_f32())
    }

    fn naive_gemm(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0f64;
                for k in 0..a.cols() {
                    s += a[(i, k)] as f64 * b[(k, j)] as f64;
                }
                c[(i, j)] = s as f32;
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive() {
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 33, 9), (70, 300, 40)] {
            let a = randm(m, k, 1);
            let b = randm(k, n, 2);
            let c = gemm(&a, &b);
            assert!(c.max_abs_diff(&naive_gemm(&a, &b)) < 1e-3, "({m},{k},{n})");
        }
    }

    #[test]
    fn gemm_fringe_and_blocking_shapes() {
        // shapes straddling every blocking boundary: the MR/NR fringes,
        // multi-panel MC/KC/NC loops, and exact multiples
        for &(m, k, n) in &[
            (4, 8, 8),     // exact single tile
            (5, 9, 11),    // all fringes
            (64, 256, 8),  // exact MC x KC panel
            (65, 257, 9),  // one past every L2 block edge
            (130, 70, 17), // several row panels, ragged everywhere
        ] {
            let a = randm(m, k, (m * 1000 + n) as u64);
            let b = randm(k, n, (k * 7 + 3) as u64);
            let c = gemm(&a, &b);
            assert!(
                c.max_abs_diff(&naive_gemm(&a, &b)) < 1e-3,
                "({m},{k},{n})"
            );
        }
    }

    #[test]
    fn gemm_into_overwrites_dirty_output() {
        let a = randm(6, 5, 10);
        let b = randm(5, 7, 11);
        let mut c = Matrix::from_fn(6, 7, |_, _| 123.0);
        gemm_into(&a, &b, &mut c);
        assert!(c.max_abs_diff(&naive_gemm(&a, &b)) < 1e-3);
    }

    #[test]
    fn gemm_tn_matches_explicit_transpose() {
        let a = randm(20, 12, 3);
        let b = randm(20, 7, 4);
        let c = gemm_tn(&a, &b);
        let want = gemm(&a.transpose(), &b);
        assert!(c.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn gram_matches_gemm() {
        let a = randm(30, 10, 5);
        let g = gram(&a);
        let want = gemm(&a.transpose(), &a);
        assert!(g.max_abs_diff(&want) < 1e-3);
        // symmetric
        assert!(g.max_abs_diff(&g.transpose()) < 1e-9);
    }

    #[test]
    fn gemv_both_orientations() {
        let a = randm(9, 13, 6);
        let x: Vec<f32> = (0..13).map(|i| i as f32 * 0.1).collect();
        let mut y = vec![0.0; 9];
        gemv(&a, &x, &mut y);
        let xv = Matrix::from_vec(13, 1, x.clone());
        let want = gemm(&a, &xv);
        for i in 0..9 {
            assert!((y[i] - want[(i, 0)]).abs() < 1e-4);
        }

        let z: Vec<f32> = (0..9).map(|i| 1.0 - i as f32 * 0.2).collect();
        let mut w = vec![0.0; 13];
        gemv_t(&a, &z, &mut w);
        let zv = Matrix::from_vec(9, 1, z);
        let want_t = gemm(&a.transpose(), &zv);
        for i in 0..13 {
            assert!((w[i] - want_t[(i, 0)]).abs() < 1e-4);
        }
    }

    #[test]
    fn gemv_pooled_bitwise_matches_serial() {
        let pool = ThreadPool::new(3);
        // rows chosen to leave a ragged last chunk
        for &(rows, cols) in &[(1, 5), (7, 16), (64, 33), (101, 29)] {
            let a = randm(rows, cols, rows as u64 + 50);
            let mut g = seeded(rows as u64 + 51);
            let x: Vec<f32> = (0..cols).map(|_| g.normal_f32()).collect();
            let mut y_serial = vec![0.0f32; rows];
            let mut y_pooled = vec![0.0f32; rows];
            gemv(&a, &x, &mut y_serial);
            gemv_pooled(&pool, &a, &x, &mut y_pooled);
            assert_eq!(y_serial, y_pooled, "({rows},{cols})");
        }
    }

    #[test]
    fn dot_wide_bitwise_matches_dot() {
        // the batched-solve contract: widening the left operand up front
        // must not change a single output bit, at any length (all tail
        // classes of the fixed 8-lane accumulator split)
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 31, 257] {
            let mut g = seeded(900 + len as u64);
            let x: Vec<f32> = (0..len).map(|_| g.normal_f32()).collect();
            let y: Vec<f32> = (0..len).map(|_| g.normal_f32()).collect();
            let mut xw = vec![0.0f64; len];
            widen(&x, &mut xw);
            assert_eq!(dot(&x, &y).to_bits(), dot_wide(&xw, &y).to_bits());
        }
    }

    #[test]
    fn dispatched_kernels_match_pinned_scalar_bitwise() {
        // whatever backend `active()` picked (native leg or the
        // DAPC_FORCE_SCALAR=1 CI leg), the public wrappers must agree
        // bitwise with the lane-structured scalar reference — the full
        // remainder-class sweep lives in tests/simd_lane_contract.rs
        use crate::linalg::simd::{self, Backend};
        let mut g = seeded(321);
        for len in [0usize, 1, 7, 8, 9, 64, 130] {
            let x: Vec<f32> = (0..len).map(|_| g.normal_f32()).collect();
            let y: Vec<f32> = (0..len).map(|_| g.normal_f32()).collect();
            assert_eq!(
                dot(&x, &y).to_bits(),
                simd::dot_on(Backend::Scalar, &x, &y).to_bits(),
                "dot len {len}"
            );
            let mut ya = y.clone();
            let mut yb = y.clone();
            axpy(0.37, &x, &mut ya);
            simd::axpy_on(Backend::Scalar, 0.37, &x, &mut yb);
            assert_eq!(ya, yb, "axpy len {len}");
        }
    }

    #[test]
    fn dot_f64_accumulation_stability() {
        // catastrophic in pure f32: 1e8 + tiny values
        let x = vec![1.0f32; 4096];
        let mut y = vec![1e-4f32; 4096];
        y[0] = 1e8;
        let d = dot(&x, &y);
        assert!((d - (1e8 + 4095.0 * 1e-4)).abs() / 1e8 < 1e-9);
    }

    #[test]
    fn axpy_basic() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [1.0f32, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
    }

    #[test]
    #[should_panic]
    fn axpy_length_mismatch_panics_in_release_too() {
        let x = [1.0f32, 2.0];
        let mut y = [0.0f32; 3];
        axpy(1.0, &x, &mut y);
    }

    #[test]
    #[should_panic]
    fn dot_length_mismatch_panics_in_release_too() {
        let _ = dot(&[1.0, 2.0], &[1.0]);
    }
}
