//! Figure 2 reproduction: MSE-vs-epochs curves for decomposed APC,
//! classical APC and DGD on a synthetic c-27-like dataset.
//!
//! The paper runs the modified `c-27` (n = 4563, m+n = 18252, w = 2
//! workers); default here is a 1/8-scale replica (n = 570) so the example
//! finishes in seconds — pass `--full` for paper scale.  Results go to
//! `target/figure2.csv` plus an ASCII rendering on stdout.
//!
//! ```sh
//! cargo run --release --example convergence_curves [-- --full] [--xla]
//! ```

use std::path::Path;

use dapc::metrics::ConvergenceTrace;
use dapc::prelude::*;
use dapc::runtime::executor::XlaExecutorHost;
use dapc::solver::{ComputeEngine, XlaEngine};
use dapc::sparse::generate::{Dataset, GeneratorConfig};

fn solve_all<E: ComputeEngine>(
    engine: &E,
    ds: &Dataset,
    epochs: usize,
    j: usize,
) -> Result<[ConvergenceTrace; 3]> {
    let opts = SolveOptions {
        epochs,
        eta: 0.9,
        gamma: 0.9,
        dgd_step: 0.0, // auto step for DGD
        x_true: Some(ds.x_true.clone()),
        ..Default::default()
    };
    let d = DapcSolver::new(opts.clone()).solve(engine, &ds.matrix, &ds.rhs, j)?;
    let c = ApcClassicalSolver::new(opts.clone())
        .solve(engine, &ds.matrix, &ds.rhs, j)?;
    let g = DgdSolver::new(opts).solve(engine, &ds.matrix, &ds.rhs, j)?;
    let mut dt = d.trace.expect("trace");
    let mut ct = c.trace.expect("trace");
    let mut gt = g.trace.expect("trace");
    dt.label = "decomposed-apc".into();
    ct.label = "classical-apc".into();
    gt.label = "dgd".into();
    Ok([dt, ct, gt])
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let full = args.iter().any(|a| a == "--full");
    let use_xla = args.iter().any(|a| a == "--xla");

    // c-27: n = 4563, total rows m+n = 18252 => matrix is 4n x n
    let n = if full { 4563 } else { 570 };
    let epochs = if full { 95 } else { 60 };
    let j = 2; // paper: w = 2 workers

    println!("Figure 2 reproduction: n={n}, m={}, J={j}, T={epochs}", 4 * n);
    let ds = GeneratorConfig::schenk_like(n).generate(27);
    println!(
        "dataset: {:.2}% sparse (paper c-27: 99.85%), mu={:.4} sigma={:.2}",
        ds.matrix.sparsity_pct(),
        ds.matrix.dense_mean(),
        ds.matrix.dense_std()
    );

    let [d, c, g] = if use_xla {
        let host = XlaExecutorHost::spawn(Path::new("artifacts"))?;
        let engine = XlaEngine::new(host.executor());
        solve_all(&engine, &ds, epochs, j)?
    } else {
        solve_all(&NativeEngine::new(), &ds, epochs, j)?
    };

    // paper §4: decomposed initial MSE >= classical initial MSE
    println!(
        "initial MSE: decomposed {:.3e}  classical {:.3e}  (paper: decomposed >= classical)",
        d.initial_mse().unwrap(),
        c.initial_mse().unwrap()
    );
    println!(
        "final MSE:   decomposed {:.3e}  classical {:.3e}  dgd {:.3e}",
        d.final_mse().unwrap(),
        c.final_mse().unwrap(),
        g.final_mse().unwrap()
    );

    std::fs::create_dir_all("target").ok();
    let csv = Path::new("target/figure2.csv");
    ConvergenceTrace::write_csv(csv, &[&d, &c, &g])?;
    println!("wrote {}", csv.display());
    println!("{}", ConvergenceTrace::ascii_chart(&[&d, &c, &g], 72, 18));
    Ok(())
}
