//! The lane-deterministic dispatch contract, enforced bitwise.
//!
//! `linalg::simd` promises that the AVX2+FMA kernels and the
//! lane-structured scalar fallbacks produce **bit-identical** results —
//! that is what keeps every `assert_eq!` equivalence suite in this repo
//! (cross-engine, warm == cold, batch == sequential, pooled == serial)
//! valid on any CPU and under `DAPC_FORCE_SCALAR=1`.  This suite sweeps
//! every kernel across all `n % 8` remainder classes at several
//! magnitudes, plus NaN-propagation cases matching the `norms::max_abs`
//! policy (a NaN is never silently dropped).
//!
//! On hardware without AVX2+FMA the vector leg is skipped (there is
//! nothing to compare); the dispatched-vs-scalar assertions still run
//! and the CI dispatch matrix covers the vector leg on x86-64 runners.

use dapc::linalg::simd::{self, Backend, KernelTier, LANES, MR, NR};
use dapc::linalg::{blas, Matrix};
use dapc::rng::seeded;

/// Scalar + (when the CPU supports it) the AVX2+FMA backend.
fn backends() -> Vec<Backend> {
    let v = simd::available();
    if !v.contains(&Backend::Avx2Fma) {
        eprintln!("simd_lane_contract: no avx2+fma, vector leg skipped");
    }
    v
}

/// Every remainder class `n % 8 ∈ 0..=7` around several magnitudes:
/// below one lane block, exactly at block boundaries, and deep into the
/// vector body.
fn sweep_lengths() -> Vec<usize> {
    let mut v = Vec::new();
    for base in [0usize, LANES, 8 * LANES, 32 * LANES, 125 * LANES] {
        for r in 0..LANES {
            v.push(base + r);
        }
    }
    v
}

fn rand_f32(len: usize, seed: u64) -> Vec<f32> {
    let mut g = seeded(seed);
    (0..len).map(|_| g.normal_f32()).collect()
}

/// f64 values that are NOT exact widenings of any f32 (the sum of two
/// scaled f32s needs more than 24 mantissa bits), exercising the
/// dot_wide rounding contract on genuinely wide inputs.
fn rand_f64_unwidenable(len: usize, seed: u64) -> Vec<f64> {
    let mut g = seeded(seed);
    (0..len)
        .map(|_| g.normal_f32() as f64 + g.normal_f32() as f64 * 1e-9)
        .collect()
}

fn assert_f64_bits_eq(a: f64, b: f64, ctx: &str) {
    let same = (a.is_nan() && b.is_nan()) || a.to_bits() == b.to_bits();
    assert!(same, "{ctx}: {a:?} ({:#x}) vs {b:?} ({:#x})", a.to_bits(), b.to_bits());
}

fn assert_f32_slice_bits_eq(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let same = (x.is_nan() && y.is_nan()) || x.to_bits() == y.to_bits();
        assert!(same, "{ctx}: element {i}: {x:?} vs {y:?}");
    }
}

#[test]
fn dot_bitwise_across_backends_and_remainder_classes() {
    let backends = backends();
    for &n in &sweep_lengths() {
        let x = rand_f32(n, 10_000 + n as u64);
        let y = rand_f32(n, 20_000 + n as u64);
        let reference = simd::dot_on(Backend::Scalar, &x, &y);
        for &b in &backends {
            let got = simd::dot_on(b, &x, &y);
            assert_f64_bits_eq(got, reference, &format!("dot n={n} {:?}", b));
        }
        // and the dispatched wrapper (whatever `active()` picked) agrees
        assert_f64_bits_eq(blas::dot(&x, &y), reference, &format!("dot dispatch n={n}"));
    }
}

#[test]
fn dot_wide_bitwise_across_backends_and_remainder_classes() {
    let backends = backends();
    for &n in &sweep_lengths() {
        let y = rand_f32(n, 30_000 + n as u64);
        // widened-f32 left operand (the batched-solve case) ...
        let x32 = rand_f32(n, 40_000 + n as u64);
        let mut xw = vec![0.0f64; n];
        blas::widen(&x32, &mut xw);
        // ... and a genuinely-f64 left operand (full rounding exposure)
        let xd = rand_f64_unwidenable(n, 50_000 + n as u64);
        for x in [&xw, &xd] {
            let reference = simd::dot_wide_on(Backend::Scalar, x, &y);
            for &b in &backends {
                let got = simd::dot_wide_on(b, x, &y);
                assert_f64_bits_eq(got, reference, &format!("dot_wide n={n} {:?}", b));
            }
            assert_f64_bits_eq(
                blas::dot_wide(x, &y),
                reference,
                &format!("dot_wide dispatch n={n}"),
            );
        }
        // the cross-kernel identity the batched update depends on:
        // pre-widening must not change a bit, on any backend
        for &b in &backends {
            assert_f64_bits_eq(
                simd::dot_wide_on(b, &xw, &y),
                simd::dot_on(b, &x32, &y),
                &format!("dot_wide == dot (widened) n={n} {:?}", b),
            );
        }
    }
}

#[test]
fn axpy_bitwise_across_backends_and_remainder_classes() {
    let backends = backends();
    for &n in &sweep_lengths() {
        let x = rand_f32(n, 60_000 + n as u64);
        let y0 = rand_f32(n, 70_000 + n as u64);
        let mut reference = y0.clone();
        simd::axpy_on(Backend::Scalar, -0.731, &x, &mut reference);
        for &b in &backends {
            let mut y = y0.clone();
            simd::axpy_on(b, -0.731, &x, &mut y);
            assert_f32_slice_bits_eq(&y, &reference, &format!("axpy n={n} {:?}", b));
        }
        let mut y = y0.clone();
        blas::axpy(-0.731, &x, &mut y);
        assert_f32_slice_bits_eq(&y, &reference, &format!("axpy dispatch n={n}"));
    }
}

#[test]
fn widen_bitwise_across_backends_and_remainder_classes() {
    let backends = backends();
    for &n in &sweep_lengths() {
        let src = rand_f32(n, 80_000 + n as u64);
        let mut reference = vec![0.0f64; n];
        simd::widen_on(Backend::Scalar, &src, &mut reference);
        // widening is exact: spot-check the definition, not just agreement
        for (d, &s) in reference.iter().zip(&src) {
            assert_eq!(*d, s as f64);
        }
        for &b in &backends {
            let mut dst = vec![0.0f64; n];
            simd::widen_on(b, &src, &mut dst);
            assert_eq!(dst, reference, "widen n={n} {:?}", b);
        }
    }
}

#[test]
fn gemm_microkernel_bitwise_across_backends_and_depths() {
    // kc sweeps the depth of the packed panels — the microkernel's only
    // loop — including 0, tiny depths, the KC default (256) and a ragged
    // past-the-block value.  Since pack_a/pack_b and the fringe writeback
    // in `gemm_into` are backend-independent plain code, microkernel
    // equality here lifts to full-gemm bit-equality under dispatch.
    let backends = backends();
    for &kc in &[0usize, 1, 2, 3, 5, 8, 13, 64, 256, 300] {
        let ap = rand_f32(kc * MR, 90_000 + kc as u64);
        let bp = rand_f32(kc * NR, 91_000 + kc as u64);
        let mut reference = [[0.1f32; NR]; MR]; // nonzero: kernel accumulates
        simd::microkernel_on(Backend::Scalar, kc, &ap, &bp, &mut reference);
        for &b in &backends {
            let mut acc = [[0.1f32; NR]; MR];
            simd::microkernel_on(b, kc, &ap, &bp, &mut acc);
            for (i, (got, want)) in acc.iter().zip(&reference).enumerate() {
                assert_f32_slice_bits_eq(
                    got,
                    want,
                    &format!("microkernel kc={kc} row {i} {:?}", b),
                );
            }
        }
    }
}

#[test]
fn gemm_end_to_end_matches_f64_oracle_under_active_dispatch() {
    // belt-and-braces for the lifting argument above: the dispatched
    // gemm (whatever backend is active in this process) stays correct
    // across fringe/blocking shapes
    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0f64;
                for k in 0..a.cols() {
                    s += a[(i, k)] as f64 * b[(k, j)] as f64;
                }
                c[(i, j)] = s as f32;
            }
        }
        c
    }
    for &(m, k, n) in &[(5, 9, 11), (64, 256, 8), (65, 257, 9)] {
        let mut g = seeded((m * 7 + k * 3 + n) as u64);
        let a = Matrix::from_fn(m, k, |_, _| g.normal_f32());
        let b = Matrix::from_fn(k, n, |_, _| g.normal_f32());
        let c = blas::gemm(&a, &b);
        assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-3, "({m},{k},{n})");
    }
}

// ---------------------------------------------------------------------------
// NaN propagation — matching the `norms::max_abs` policy: a NaN input is
// never silently dropped, on either dispatch path.
// ---------------------------------------------------------------------------

#[test]
fn dot_propagates_nan_from_any_position_on_all_backends() {
    // positions cover the first lane block, a mid-body lane, and the
    // sequential tail of a length with remainder 5
    let n = 3 * LANES + 5;
    let backends = backends();
    for &pos in &[0usize, 1, LANES + 3, 2 * LANES, n - 1] {
        for side in 0..2 {
            let mut x = rand_f32(n, 95_000 + pos as u64);
            let mut y = rand_f32(n, 96_000 + pos as u64);
            if side == 0 {
                x[pos] = f32::NAN;
            } else {
                y[pos] = f32::NAN;
            }
            for &b in &backends {
                let got = simd::dot_on(b, &x, &y);
                assert!(got.is_nan(), "dot NaN at {pos} side {side} {:?}: {got}", b);
            }
            assert!(blas::dot(&x, &y).is_nan(), "dispatched dot NaN at {pos}");
        }
    }
}

#[test]
fn dot_wide_propagates_nan_on_all_backends() {
    let n = 2 * LANES + 3;
    let backends = backends();
    for &pos in &[0usize, LANES + 1, n - 1] {
        let mut x = rand_f64_unwidenable(n, 97_000 + pos as u64);
        let y = rand_f32(n, 98_000 + pos as u64);
        x[pos] = f64::NAN;
        for &b in &backends {
            assert!(
                simd::dot_wide_on(b, &x, &y).is_nan(),
                "dot_wide NaN at {pos} {:?}",
                b
            );
        }
    }
}

#[test]
fn axpy_poisons_exactly_the_nan_lanes_on_all_backends() {
    let n = 2 * LANES + 6;
    let backends = backends();
    let pos = LANES + 2; // inside the vector body
    let tail_pos = n - 1; // inside the sequential tail
    let mut x = rand_f32(n, 99_000);
    let y0 = rand_f32(n, 99_001);
    x[pos] = f32::NAN;
    x[tail_pos] = f32::NAN;
    let mut reference = y0.clone();
    simd::axpy_on(Backend::Scalar, 2.5, &x, &mut reference);
    assert!(reference[pos].is_nan() && reference[tail_pos].is_nan());
    for &b in &backends {
        let mut y = y0.clone();
        simd::axpy_on(b, 2.5, &x, &mut y);
        // NaN lanes poisoned, all other lanes still bitwise identical
        assert_f32_slice_bits_eq(&y, &reference, &format!("axpy NaN {:?}", b));
    }
}

#[test]
fn microkernel_poisons_exactly_the_nan_column_on_all_backends() {
    let backends = backends();
    let kc = 9;
    let mut ap = rand_f32(kc * MR, 99_100);
    let bp = rand_f32(kc * NR, 99_101);
    ap[3 * MR + 1] = f32::NAN; // row 1 of the tile, depth step 3
    let mut reference = [[0.0f32; NR]; MR];
    simd::microkernel_on(Backend::Scalar, kc, &ap, &bp, &mut reference);
    for &v in &reference[1] {
        assert!(v.is_nan(), "NaN A element must poison its whole tile row");
    }
    for &b in &backends {
        let mut acc = [[0.0f32; NR]; MR];
        simd::microkernel_on(b, kc, &ap, &bp, &mut acc);
        for (i, (got, want)) in acc.iter().zip(&reference).enumerate() {
            assert_f32_slice_bits_eq(
                got,
                want,
                &format!("microkernel NaN row {i} {:?}", b),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Dispatch selection plumbing.
// ---------------------------------------------------------------------------

#[test]
fn forced_scalar_env_pins_the_scalar_backend() {
    // this binary runs twice in CI: natively and with DAPC_FORCE_SCALAR=1
    let forced = dapc::config::envvars::force_scalar();
    if forced {
        assert_eq!(simd::active(), Backend::Scalar);
        assert!(simd::description().contains("DAPC_FORCE_SCALAR"));
    } else if simd::avx2_available() {
        assert_eq!(simd::active(), Backend::Avx2Fma);
    } else {
        assert_eq!(simd::active(), Backend::Scalar);
    }
    // the selection rule itself, independent of this process's env
    assert_eq!(simd::select(true, true), Backend::Scalar);
    assert_eq!(simd::select(false, true), Backend::Avx2Fma);
    assert_eq!(simd::select(false, false), Backend::Scalar);
}

#[test]
fn kernel_tier_env_pins_the_active_tier() {
    // this binary also runs on the DAPC_KERNEL_TIER=fast leg of the CI
    // matrix; the process-wide tier must follow the env exactly
    let fast = dapc::config::envvars::fast_tier();
    if fast {
        assert_eq!(simd::active_tier(), KernelTier::Fast);
        assert!(simd::tier_description().contains("fast"));
    } else {
        assert_eq!(simd::active_tier(), KernelTier::Deterministic);
    }
    // the selection rule itself, independent of this process's env
    assert_eq!(simd::select_tier(true), KernelTier::Fast);
    assert_eq!(simd::select_tier(false), KernelTier::Deterministic);
    assert_eq!(KernelTier::default(), KernelTier::Deterministic);
}

// ---------------------------------------------------------------------------
// The two-tier microkernel contract.
// ---------------------------------------------------------------------------

#[test]
fn tier0_microkernel_entry_is_bitwise_the_lane_kernel_on_all_backends() {
    // the tier-0 route through `microkernel_tier_on` IS `microkernel_on`:
    // pinning Deterministic must reproduce the lane kernel bit for bit on
    // every backend and depth, so every pre-tier `assert_eq!` suite keeps
    // its meaning under the tier dispatch layer
    let backends = backends();
    for &kc in &[0usize, 1, 7, 64, 256, 300] {
        let ap = rand_f32(kc * MR, 101_000 + kc as u64);
        let bp = rand_f32(kc * NR, 102_000 + kc as u64);
        for &b in &backends {
            let mut want = [[0.25f32; NR]; MR];
            simd::microkernel_on(b, kc, &ap, &bp, &mut want);
            let mut got = [[0.25f32; NR]; MR];
            simd::microkernel_tier_on(b, KernelTier::Deterministic, kc, &ap, &bp, &mut got);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_f32_slice_bits_eq(g, w, &format!("tier0 kc={kc} row {i} {:?}", b));
            }
        }
    }
}

#[test]
fn tier1_microkernel_is_reproducible_and_within_the_fma_error_bound() {
    // tier-1 fuses the f32 multiply-add; it drops one rounding per depth
    // step, so |tier1 - tier0| is bounded by the unfused kernel's own
    // rounding budget: kc * eps relative to the accumulated magnitude
    let backends = backends();
    for &kc in &[1usize, 13, 256] {
        let ap = rand_f32(kc * MR, 103_000 + kc as u64);
        let bp = rand_f32(kc * NR, 104_000 + kc as u64);
        for &b in &backends {
            let mut t0 = [[0.0f32; NR]; MR];
            simd::microkernel_tier_on(b, KernelTier::Deterministic, kc, &ap, &bp, &mut t0);
            let mut t1 = [[0.0f32; NR]; MR];
            simd::microkernel_tier_on(b, KernelTier::Fast, kc, &ap, &bp, &mut t1);
            // run-twice reproducibility: within backend+tier, bitwise
            let mut t1b = [[0.0f32; NR]; MR];
            simd::microkernel_tier_on(b, KernelTier::Fast, kc, &ap, &bp, &mut t1b);
            for (i, (x, y)) in t1.iter().zip(&t1b).enumerate() {
                assert_f32_slice_bits_eq(x, y, &format!("tier1 rerun kc={kc} row {i} {:?}", b));
            }
            for i in 0..MR {
                for j in 0..NR {
                    let bound = 2.0 * kc as f32 * f32::EPSILON * t0[i][j].abs().max(1.0);
                    let diff = (t1[i][j] - t0[i][j]).abs();
                    assert!(
                        diff <= bound,
                        "tier1 kc={kc} ({i},{j}) {:?}: |{} - {}| = {diff} > {bound}",
                        b,
                        t1[i][j],
                        t0[i][j]
                    );
                }
            }
        }
    }
}
