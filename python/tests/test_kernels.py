"""Pallas consensus kernels (kernels/consensus.py) vs jnp oracles.

Hypothesis sweeps (J, n) shapes and hyper-parameters; the kernels must match
``kernels.ref`` bit-for-bit up to f32 rounding for every shape, including
ones that do not divide the default 128 block.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import consensus, ref

F32 = np.float32


def _mk(rng, j, n):
    x = rng.normal(size=(j, n)).astype(F32)
    xbar = rng.normal(size=(n,)).astype(F32)
    p = rng.normal(size=(j, n, n)).astype(F32)
    return x, xbar, p


class TestConsensusUpdate:
    @pytest.mark.parametrize("j,n", [(1, 8), (2, 32), (4, 128), (3, 96), (7, 13)])
    def test_matches_ref(self, rng, j, n):
        x, xbar, p = _mk(rng, j, n)
        got = consensus.consensus_update(
            jnp.asarray(x), jnp.asarray(xbar), jnp.asarray(p), jnp.float32(0.8)
        )
        want = ref.consensus_update_ref(x, xbar, p, 0.8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)

    def test_gamma_zero_is_identity(self, rng):
        x, xbar, p = _mk(rng, 3, 64)
        got = consensus.consensus_update(
            jnp.asarray(x), jnp.asarray(xbar), jnp.asarray(p), jnp.float32(0.0)
        )
        np.testing.assert_allclose(np.asarray(got), x, atol=0)

    def test_zero_projector_is_identity(self, rng):
        x, xbar, _ = _mk(rng, 2, 32)
        p = np.zeros((2, 32, 32), dtype=F32)
        got = consensus.consensus_update(
            jnp.asarray(x), jnp.asarray(xbar), jnp.asarray(p), jnp.float32(0.9)
        )
        np.testing.assert_allclose(np.asarray(got), x, atol=0)

    def test_fixed_point(self, rng):
        # x_j == xbar for all j is a fixed point of eq. (6).
        n, j = 48, 3
        xbar = rng.normal(size=(n,)).astype(F32)
        x = np.tile(xbar, (j, 1))
        p = rng.normal(size=(j, n, n)).astype(F32)
        got = consensus.consensus_update(
            jnp.asarray(x), jnp.asarray(xbar), jnp.asarray(p), jnp.float32(0.7)
        )
        np.testing.assert_allclose(np.asarray(got), x, atol=1e-6)

    @settings(deadline=None, max_examples=20)
    @given(
        j=st.integers(min_value=1, max_value=6),
        n=st.integers(min_value=1, max_value=80),
        gamma=st.floats(min_value=0.0, max_value=1.0, width=32),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_property_matches_ref(self, j, n, gamma, seed):
        g = np.random.default_rng(seed)
        x, xbar, p = _mk(g, j, n)
        got = consensus.consensus_update(
            jnp.asarray(x), jnp.asarray(xbar), jnp.asarray(p),
            jnp.float32(gamma),
        )
        want = ref.consensus_update_ref(x, xbar, p, gamma)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), atol=5e-3
        )


class TestEtaAverage:
    @pytest.mark.parametrize("j,n", [(1, 8), (2, 32), (4, 128), (5, 37)])
    def test_matches_ref(self, rng, j, n):
        x, xbar, _ = _mk(rng, j, n)
        got = consensus.eta_average(
            jnp.asarray(x), jnp.asarray(xbar), jnp.float32(0.35)
        )
        want = ref.eta_average_ref(x, xbar, 0.35)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)

    def test_eta_zero_keeps_xbar(self, rng):
        x, xbar, _ = _mk(rng, 4, 64)
        got = consensus.eta_average(jnp.asarray(x), jnp.asarray(xbar), jnp.float32(0.0))
        np.testing.assert_allclose(np.asarray(got), xbar, atol=0)

    def test_eta_one_is_mean(self, rng):
        x, xbar, _ = _mk(rng, 4, 64)
        got = consensus.eta_average(jnp.asarray(x), jnp.asarray(xbar), jnp.float32(1.0))
        np.testing.assert_allclose(np.asarray(got), x.mean(axis=0), atol=1e-6)

    @settings(deadline=None, max_examples=20)
    @given(
        j=st.integers(min_value=1, max_value=8),
        n=st.integers(min_value=1, max_value=100),
        eta=st.floats(min_value=0.0, max_value=1.0, width=32),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_property_matches_ref(self, j, n, eta, seed):
        g = np.random.default_rng(seed)
        x, xbar, _ = _mk(g, j, n)
        got = consensus.eta_average(
            jnp.asarray(x), jnp.asarray(xbar), jnp.float32(eta)
        )
        want = ref.eta_average_ref(x, xbar, eta)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), atol=1e-4
        )


class TestBlockSelection:
    def test_block_divides(self):
        assert consensus._block(256, 128) == 128
        assert consensus._block(96, 128) == 32
        assert consensus._block(13, 128) == 1
        assert consensus._block(128, 64) == 64
