//! Wire protocol between leader and workers.
//!
//! Hand-rolled binary framing (serde unavailable offline):
//!
//! ```text
//! frame   := u32 payload_len (LE) | u8 tag | payload
//! payload := fields in declaration order
//! vec<f32>:= u64 len | f32 * len        (LE)
//! matrix  := u64 rows | u64 cols | f32 * rows*cols (row-major)
//! string  := u64 len | utf8 bytes
//! ```
//!
//! The protocol is deliberately small: projectors are computed worker-side
//! and never serialized; per-epoch traffic is one n-vector each way per
//! worker (the paper's communication pattern).

use crate::error::{DapcError, Result};
use crate::linalg::Matrix;
use crate::solver::InitKind;

/// Protocol messages (both directions).
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Leader -> worker: here is your partition; run init.
    InitPartition {
        worker_id: u32,
        kind: InitKindWire,
        a: Matrix,
        b: Vec<f32>,
        /// Padded solution width the consensus loop runs at.
        n_target: u32,
    },
    /// Worker -> leader: init finished, here is x_j(0).
    InitDone { worker_id: u32, x0: Vec<f32> },
    /// Leader -> worker: consensus epoch t with the current average.
    RunUpdate { epoch: u32, gamma: f32, xbar: Vec<f32> },
    /// Worker -> leader: updated estimate x_j(t+1).
    UpdateDone { worker_id: u32, x: Vec<f32> },
    /// Leader -> worker: DGD gradient request at the current iterate.
    RunGrad { epoch: u32, x: Vec<f32> },
    /// Worker -> leader: local gradient.
    GradDone { worker_id: u32, grad: Vec<f32> },
    /// Worker -> leader: failure (leader aborts the run).
    WorkerError { worker_id: u32, message: String },
    /// Leader -> worker: done, exit the loop.
    Shutdown,
}

/// InitKind twin that is wire-encodable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InitKindWire {
    Qr = 0,
    Classical = 1,
    Fat = 2,
}

impl From<InitKind> for InitKindWire {
    fn from(k: InitKind) -> Self {
        match k {
            InitKind::Qr => Self::Qr,
            InitKind::Classical => Self::Classical,
            InitKind::Fat => Self::Fat,
        }
    }
}

impl From<InitKindWire> for InitKind {
    fn from(k: InitKindWire) -> Self {
        match k {
            InitKindWire::Qr => InitKind::Qr,
            InitKindWire::Classical => InitKind::Classical,
            InitKindWire::Fat => InitKind::Fat,
        }
    }
}

// --- encoding ---------------------------------------------------------------

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new(tag: u8) -> Self {
        Self { buf: vec![tag] }
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn vec_f32(&mut self, v: &[f32]) {
        self.buf.extend_from_slice(&(v.len() as u64).to_le_bytes());
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn matrix(&mut self, m: &Matrix) {
        self.buf.extend_from_slice(&(m.rows() as u64).to_le_bytes());
        self.buf.extend_from_slice(&(m.cols() as u64).to_le_bytes());
        for x in m.as_slice() {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn string(&mut self, s: &str) {
        self.buf.extend_from_slice(&(s.len() as u64).to_le_bytes());
        self.buf.extend_from_slice(s.as_bytes());
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(DapcError::Parse("truncated message".into()));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn vec_f32(&mut self) -> Result<Vec<f32>> {
        let len = self.u64()? as usize;
        let bytes = self.take(len * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn matrix(&mut self) -> Result<Matrix> {
        let rows = self.u64()? as usize;
        let cols = self.u64()? as usize;
        let bytes = self.take(rows * cols * 4)?;
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Matrix::from_vec(rows, cols, data))
    }

    fn string(&mut self) -> Result<String> {
        let len = self.u64()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| DapcError::Parse("invalid utf8 in message".into()))
    }

    fn finish(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(DapcError::Parse("trailing bytes in message".into()));
        }
        Ok(())
    }
}

impl Message {
    /// Encode to a tagged payload (no length prefix; transports add it).
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Message::InitPartition { worker_id, kind, a, b, n_target } => {
                let mut e = Enc::new(0);
                e.u32(*worker_id);
                e.buf.push(*kind as u8);
                e.matrix(a);
                e.vec_f32(b);
                e.u32(*n_target);
                e.buf
            }
            Message::InitDone { worker_id, x0 } => {
                let mut e = Enc::new(1);
                e.u32(*worker_id);
                e.vec_f32(x0);
                e.buf
            }
            Message::RunUpdate { epoch, gamma, xbar } => {
                let mut e = Enc::new(2);
                e.u32(*epoch);
                e.f32(*gamma);
                e.vec_f32(xbar);
                e.buf
            }
            Message::UpdateDone { worker_id, x } => {
                let mut e = Enc::new(3);
                e.u32(*worker_id);
                e.vec_f32(x);
                e.buf
            }
            Message::RunGrad { epoch, x } => {
                let mut e = Enc::new(4);
                e.u32(*epoch);
                e.vec_f32(x);
                e.buf
            }
            Message::GradDone { worker_id, grad } => {
                let mut e = Enc::new(5);
                e.u32(*worker_id);
                e.vec_f32(grad);
                e.buf
            }
            Message::WorkerError { worker_id, message } => {
                let mut e = Enc::new(6);
                e.u32(*worker_id);
                e.string(message);
                e.buf
            }
            Message::Shutdown => vec![7],
        }
    }

    /// Decode from a tagged payload.
    pub fn decode(buf: &[u8]) -> Result<Message> {
        let mut d = Dec { buf, pos: 0 };
        let tag = d.u8()?;
        let msg = match tag {
            0 => {
                let worker_id = d.u32()?;
                let kind = match d.u8()? {
                    0 => InitKindWire::Qr,
                    1 => InitKindWire::Classical,
                    2 => InitKindWire::Fat,
                    k => {
                        return Err(DapcError::Parse(format!(
                            "bad init kind {k}"
                        )))
                    }
                };
                let a = d.matrix()?;
                let b = d.vec_f32()?;
                let n_target = d.u32()?;
                Message::InitPartition { worker_id, kind, a, b, n_target }
            }
            1 => Message::InitDone { worker_id: d.u32()?, x0: d.vec_f32()? },
            2 => Message::RunUpdate {
                epoch: d.u32()?,
                gamma: d.f32()?,
                xbar: d.vec_f32()?,
            },
            3 => Message::UpdateDone { worker_id: d.u32()?, x: d.vec_f32()? },
            4 => Message::RunGrad { epoch: d.u32()?, x: d.vec_f32()? },
            5 => Message::GradDone { worker_id: d.u32()?, grad: d.vec_f32()? },
            6 => Message::WorkerError {
                worker_id: d.u32()?,
                message: d.string()?,
            },
            7 => Message::Shutdown,
            other => {
                return Err(DapcError::Parse(format!("unknown tag {other}")))
            }
        };
        d.finish()?;
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(m: Message) {
        let enc = m.encode();
        let dec = Message::decode(&enc).unwrap();
        assert_eq!(m, dec);
    }

    #[test]
    fn all_variants_roundtrip() {
        roundtrip(Message::InitPartition {
            worker_id: 3,
            kind: InitKindWire::Qr,
            a: Matrix::from_fn(4, 3, |i, j| (i * 3 + j) as f32 * 0.5),
            b: vec![1.0, -2.0, 3.0, 0.25],
            n_target: 3,
        });
        roundtrip(Message::InitDone { worker_id: 1, x0: vec![0.1, 0.2] });
        roundtrip(Message::RunUpdate {
            epoch: 9,
            gamma: 0.75,
            xbar: vec![5.0; 7],
        });
        roundtrip(Message::UpdateDone { worker_id: 0, x: vec![] });
        roundtrip(Message::RunGrad { epoch: 2, x: vec![1.0] });
        roundtrip(Message::GradDone { worker_id: 4, grad: vec![-1.5, 2.5] });
        roundtrip(Message::WorkerError {
            worker_id: 2,
            message: "qr failed: naïve".into(),
        });
        roundtrip(Message::Shutdown);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Message::decode(&[]).is_err());
        assert!(Message::decode(&[99]).is_err());
        // truncated InitDone
        let mut enc = Message::InitDone { worker_id: 1, x0: vec![1.0, 2.0] }.encode();
        enc.truncate(enc.len() - 2);
        assert!(Message::decode(&enc).is_err());
        // trailing bytes
        let mut enc2 = Message::Shutdown.encode();
        enc2.push(0);
        assert!(Message::decode(&enc2).is_err());
        // bad init kind
        let mut enc3 = Message::InitPartition {
            worker_id: 0,
            kind: InitKindWire::Qr,
            a: Matrix::zeros(1, 1),
            b: vec![0.0],
            n_target: 1,
        }
        .encode();
        enc3[5] = 9; // kind byte
        assert!(Message::decode(&enc3).is_err());
    }

    #[test]
    fn init_kind_conversion() {
        for k in [InitKind::Qr, InitKind::Classical, InitKind::Fat] {
            let w: InitKindWire = k.into();
            let back: InitKind = w.into();
            assert_eq!(k, back);
        }
    }
}
