//! Wall-clock timing utilities for the bench harness and solver reports.

use std::time::{Duration, Instant};

/// Simple stopwatch with named laps.
#[derive(Debug)]
pub struct StopWatch {
    start: Instant,
    last: Instant,
    laps: Vec<(String, Duration)>,
}

impl Default for StopWatch {
    fn default() -> Self {
        Self::new()
    }
}

impl StopWatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Self { start: now, last: now, laps: Vec::new() }
    }

    /// Record a lap since the previous lap (or start).
    pub fn lap(&mut self, name: impl Into<String>) -> Duration {
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        self.laps.push((name.into(), d));
        d
    }

    /// Total elapsed since construction.
    pub fn total(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn laps(&self) -> &[(String, Duration)] {
        &self.laps
    }

    /// Sum of laps matching a name.
    pub fn lap_total(&self, name: &str) -> Duration {
        self.laps
            .iter()
            .filter(|(n, _)| n == name)
            .map(|(_, d)| *d)
            .sum()
    }
}

/// Summary statistics over repeated timing samples (bench harness).
#[derive(Debug, Clone)]
pub struct TimingStats {
    pub samples: Vec<f64>, // seconds
}

impl TimingStats {
    pub fn from_secs(samples: Vec<f64>) -> Self {
        Self { samples }
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        // audit:allow(fixed-order-reduce): timing statistics — wall-clock
        // samples are inherently nondeterministic, no bitwise contract
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// Smallest sample.  Empty -> 0.0 (consistent with `mean`/`median`);
    /// a NaN sample propagates (PR-4 NaN policy: never launder a
    /// poisoned timing into a plausible number).
    pub fn min(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if self.samples.iter().any(|s| s.is_nan()) {
            return f64::NAN;
        }
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Largest sample.  Empty -> 0.0; NaN propagates.  Folding starts
    /// from the samples themselves, so all-negative sets report their
    /// true maximum instead of a spurious 0.0.
    pub fn max(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if self.samples.iter().any(|s| s.is_nan()) {
            return f64::NAN;
        }
        self.samples
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std_dev(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        // audit:allow(fixed-order-reduce): timing statistics — wall-clock
        // samples are inherently nondeterministic, no bitwise contract
        (self.samples.iter().map(|s| (s - m).powi(2)).sum::<f64>()
            / (self.samples.len() - 1) as f64)
            .sqrt()
    }

    /// Linear-interpolated percentile (p in [0, 100]).  Empty -> 0.0;
    /// a NaN sample propagates (total_cmp keeps the sort panic-free,
    /// but a poisoned sample set must not yield a plausible number).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if self.samples.iter().any(|s| s.is_nan()) {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        let rank = (p / 100.0) * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_laps_accumulate() {
        let mut sw = StopWatch::new();
        std::thread::sleep(Duration::from_millis(2));
        sw.lap("a");
        std::thread::sleep(Duration::from_millis(2));
        sw.lap("b");
        sw.lap("a");
        assert_eq!(sw.laps().len(), 3);
        assert!(sw.lap_total("a") >= Duration::from_millis(2));
        assert!(sw.total() >= Duration::from_millis(4));
    }

    #[test]
    fn stats_basic() {
        let s = TimingStats::from_secs(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.median() - 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.std_dev() - (2.5f64).sqrt()).abs() < 1e-12);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((s.percentile(100.0) - 5.0).abs() < 1e-12);
        assert!((s.percentile(25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stats_degenerate() {
        let e = TimingStats::from_secs(vec![]);
        assert_eq!(e.mean(), 0.0);
        assert_eq!(e.median(), 0.0);
        let one = TimingStats::from_secs(vec![7.0]);
        assert_eq!(one.median(), 7.0);
        assert_eq!(one.std_dev(), 0.0);
    }

    #[test]
    fn empty_min_max_consistent_with_mean() {
        let e = TimingStats::from_secs(vec![]);
        assert_eq!(e.min(), 0.0);
        assert_eq!(e.max(), 0.0);
    }

    #[test]
    fn all_negative_samples_report_true_max() {
        let s = TimingStats::from_secs(vec![-3.0, -1.0, -2.0]);
        assert_eq!(s.max(), -1.0);
        assert_eq!(s.min(), -3.0);
        assert_eq!(s.median(), -2.0);
    }

    #[test]
    fn nan_propagates_instead_of_panicking() {
        let s = TimingStats::from_secs(vec![1.0, f64::NAN, 3.0]);
        assert!(s.percentile(50.0).is_nan());
        assert!(s.median().is_nan());
        assert!(s.p50().is_nan());
        assert!(s.p99().is_nan());
        assert!(s.min().is_nan());
        assert!(s.max().is_nan());
        assert!(s.mean().is_nan());
    }
}
