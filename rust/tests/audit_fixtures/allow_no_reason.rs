// A reason-less `audit:allow` marker: suppression requires a
// `: reason`, so the finding below must survive (with a note telling
// the author why the marker did nothing).
pub fn mean(xs: &[f32]) -> f32 {
    // audit:allow(fixed-order-reduce)
    let s = xs.iter().sum::<f32>();
    s / xs.len().max(1) as f32
}
