//! Sparse-matrix substrate: COO/CSR storage, MatrixMarket I/O and the
//! synthetic Schenk_IBMNA-like dataset generator (the paper's evaluation
//! datasets are SuiteSparse `c-*` matrices; DESIGN.md §2 documents the
//! substitution).
//!
//! The paper's pipeline stores `A` compressed (CSR), slices row blocks per
//! partition and *densifies* them on the workers (`.toarray()` in the
//! paper's `create_submatrices`) — [`CsrMatrix::slice_rows_dense`] mirrors
//! that exactly.

mod coo;
mod csr;
pub mod generate;
pub mod matrix_market;

pub use coo::CooMatrix;
pub use csr::CsrMatrix;
