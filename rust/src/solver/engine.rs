//! Compute engines: who executes the worker math.
//!
//! [`NativeEngine`] runs the in-repo linalg (always available, the
//! reference); [`XlaEngine`] executes the AOT HLO artifacts through the
//! PJRT runtime — the production path where Layers 1/2 live.  Both expose
//! the same operations so solvers and the coordinator are engine-generic,
//! and the integration tests assert they agree numerically.

use crate::error::{DapcError, Result};
use crate::linalg::{blas, inverse, qr, triangular, Matrix};
use crate::partition::pad_to_bucket;
use crate::runtime::{Tensor, XlaExecutor};

/// Which worker initialization to run (Algorithm 1 steps 2-3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InitKind {
    /// Paper's decomposition: QR + backward substitution (eqs. (1)-(4)).
    Qr,
    /// Classical APC: Gram matrix + Gauss-Jordan inverse.
    Classical,
    /// Original-APC fat regime: QR of A^T, genuine projector.
    Fat,
}

impl InitKind {
    pub fn artifact_kind(&self) -> &'static str {
        match self {
            InitKind::Qr => "init_qr",
            InitKind::Classical => "init_classical",
            InitKind::Fat => "init_fat",
        }
    }
}

/// Worker-side init output: initial estimate + projector.
#[derive(Debug, Clone)]
pub struct WorkerInit {
    pub x0: Vec<f32>,
    pub projector: Matrix,
}

/// Engine-agnostic operations used by the solvers and the coordinator.
pub trait ComputeEngine {
    /// Initialize one partition (dense block `a`, rhs `b`).
    ///
    /// `n_target` is the solution dimension the consensus loop will run at
    /// (engines that pad to shape buckets return padded outputs of exactly
    /// this width).
    fn init(
        &self,
        kind: InitKind,
        a: &Matrix,
        b: &[f32],
        n_target: usize,
    ) -> Result<WorkerInit>;

    /// Eq. (6) for one partition: `x + gamma * P (xbar - x)`.
    fn update(
        &self,
        x: &[f32],
        xbar: &[f32],
        p: &Matrix,
        gamma: f32,
    ) -> Result<Vec<f32>>;

    /// Eq. (7): `eta * mean_j x_j + (1 - eta) * xbar`.
    fn average(&self, xs: &[Vec<f32>], xbar: &[f32], eta: f32) -> Result<Vec<f32>>;

    /// One fused epoch over all partitions; default = update-all + average.
    fn round(
        &self,
        xs: &[Vec<f32>],
        xbar: &[f32],
        ps: &[Matrix],
        gamma: f32,
        eta: f32,
    ) -> Result<(Vec<Vec<f32>>, Vec<f32>)> {
        let mut new_xs = Vec::with_capacity(xs.len());
        for (x, p) in xs.iter().zip(ps) {
            new_xs.push(self.update(x, xbar, p, gamma)?);
        }
        let new_xbar = self.average(&new_xs, xbar, eta)?;
        Ok((new_xs, new_xbar))
    }

    /// T fused epochs in one call when the engine supports it (the XLA
    /// engine runs the whole loop inside a single executable); `None`
    /// means the caller should iterate [`Self::round`].
    fn solve_loop(
        &self,
        _xs: &[Vec<f32>],
        _xbar: &[f32],
        _ps: &[Matrix],
        _gamma: f32,
        _eta: f32,
        _epochs: usize,
    ) -> Result<Option<(Vec<Vec<f32>>, Vec<f32>)>> {
        Ok(None)
    }

    /// DGD worker gradient `A^T (A x - b)`.
    fn dgd_grad(&self, a: &Matrix, x: &[f32], b: &[f32]) -> Result<Vec<f32>>;

    /// The (l_pad, n_pad) bucket this engine needs for a block of shape
    /// (rows, n), or `None` when exact shapes are fine.
    fn init_bucket(
        &self,
        _kind: InitKind,
        _rows: usize,
        _n: usize,
    ) -> Result<Option<(usize, usize)>> {
        Ok(None)
    }

    /// Engine label for reports.
    fn name(&self) -> &'static str;
}

// ---------------------------------------------------------------------------
// Native engine
// ---------------------------------------------------------------------------

/// Pure-Rust engine over `crate::linalg` — the correctness reference.
#[derive(Debug, Default, Clone)]
pub struct NativeEngine;

impl NativeEngine {
    pub fn new() -> Self {
        Self
    }
}

impl ComputeEngine for NativeEngine {
    fn init(
        &self,
        kind: InitKind,
        a: &Matrix,
        b: &[f32],
        n_target: usize,
    ) -> Result<WorkerInit> {
        let n = a.cols();
        if n != n_target {
            return Err(DapcError::Shape(format!(
                "native engine expects n_target == n ({n_target} != {n})"
            )));
        }
        match kind {
            InitKind::Qr => {
                // Paper eqs. (1)-(4): A = Q1 R, x0 = R^{-1} Q1^T b by
                // backward substitution, P = I - Q1^T Q1.
                let f = qr::householder_qr(a);
                let c = qr::qt_mul(&f, b);
                let x0 = triangular::back_substitute(&f.r, &c);
                let qtq = blas::gemm_tn(&f.q1, &f.q1);
                let mut p = Matrix::eye(n);
                for i in 0..n {
                    for j in 0..n {
                        p[(i, j)] -= qtq[(i, j)];
                    }
                }
                Ok(WorkerInit { x0, projector: p })
            }
            InitKind::Classical => {
                // x0 = (A^T A)^{-1} A^T b ; P = I - G^{-1} G (numeric),
                // in f64 like the paper's NumPy baseline — the normal
                // equations square kappa(A), which in f32 makes the
                // projector noise large enough to diverge (DESIGN.md §1).
                let (x0, p) = inverse::classical_init_f64(a, b)?;
                Ok(WorkerInit { x0, projector: p })
            }
            InitKind::Fat => {
                // A^T = Q R; x0 = Q R^{-T} b; P = I - Q Q^T.
                let at = a.transpose();
                let f = qr::householder_qr(&at);
                let c = triangular::forward_substitute(&f.r.transpose(), b);
                let mut x0 = vec![0.0f32; n];
                blas::gemv(&f.q1, &c, &mut x0);
                let qqt = blas::gemm(&f.q1, &f.q1.transpose());
                let mut p = Matrix::eye(n);
                for i in 0..n {
                    for j in 0..n {
                        p[(i, j)] -= qqt[(i, j)];
                    }
                }
                Ok(WorkerInit { x0, projector: p })
            }
        }
    }

    fn update(
        &self,
        x: &[f32],
        xbar: &[f32],
        p: &Matrix,
        gamma: f32,
    ) -> Result<Vec<f32>> {
        let n = x.len();
        let d: Vec<f32> = xbar.iter().zip(x).map(|(a, b)| a - b).collect();
        let mut pd = vec![0.0f32; n];
        blas::gemv(p, &d, &mut pd);
        Ok(x.iter().zip(&pd).map(|(xi, pi)| xi + gamma * pi).collect())
    }

    fn average(&self, xs: &[Vec<f32>], xbar: &[f32], eta: f32) -> Result<Vec<f32>> {
        let j = xs.len() as f64;
        let n = xbar.len();
        let mut out = vec![0.0f32; n];
        for i in 0..n {
            let mean: f64 =
                xs.iter().map(|x| x[i] as f64).sum::<f64>() / j;
            out[i] = (eta as f64 * mean + (1.0 - eta as f64) * xbar[i] as f64)
                as f32;
        }
        Ok(out)
    }

    fn dgd_grad(&self, a: &Matrix, x: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let mut ax = vec![0.0f32; a.rows()];
        blas::gemv(a, x, &mut ax);
        for (axi, bi) in ax.iter_mut().zip(b) {
            *axi -= bi;
        }
        let mut g = vec![0.0f32; a.cols()];
        blas::gemv_t(a, &ax, &mut g);
        Ok(g)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

// ---------------------------------------------------------------------------
// XLA engine
// ---------------------------------------------------------------------------

/// Engine executing AOT HLO artifacts through the PJRT runtime (the
/// Layer-1/2 production path).  Blocks are padded to manifest buckets;
/// padding is exact (DESIGN.md §3).
#[derive(Clone)]
pub struct XlaEngine {
    exec: XlaExecutor,
    /// Use the per-epoch fused `round_*` artifacts when available.
    pub fused_rounds: bool,
    /// Use the whole-loop `solve_*` artifacts when available.
    pub fused_loop: bool,
}

impl XlaEngine {
    pub fn new(exec: XlaExecutor) -> Self {
        Self { exec, fused_rounds: true, fused_loop: false }
    }

    pub fn executor(&self) -> &XlaExecutor {
        &self.exec
    }

    fn n_of(&self, xbar: &[f32]) -> usize {
        xbar.len()
    }
}

impl ComputeEngine for XlaEngine {
    fn init(
        &self,
        kind: InitKind,
        a: &Matrix,
        b: &[f32],
        n_target: usize,
    ) -> Result<WorkerInit> {
        let akind = kind.artifact_kind();
        // pad to the bucket whose n equals n_target
        let buckets = self.exec.init_buckets(akind)?;
        let (rows, n) = a.shape();
        let (l_pad, n_pad) = buckets
            .iter()
            .copied()
            .filter(|&(l, np)| np == n_target && l >= rows + (np - n))
            .min_by_key(|&(l, _)| l)
            .ok_or_else(|| {
                DapcError::Artifact(format!(
                    "no {akind} artifact with n={n_target} fitting {rows}x{n}; \
                     available buckets: {buckets:?} (rebuild with \
                     `make artifacts` and a matching shape manifest)"
                ))
            })?;
        let blk = pad_to_bucket(a, b, l_pad, n_pad)?;
        let name = format!("{akind}_l{l_pad}_n{n_pad}");
        let out = self.exec.execute(
            &name,
            vec![Tensor::from_matrix(&blk.a), Tensor::vec1(blk.b.clone())],
        )?;
        let [x0, p]: [Tensor; 2] = out.try_into().map_err(|_| {
            DapcError::Artifact(format!("{name}: expected 2 outputs"))
        })?;
        Ok(WorkerInit { x0: x0.into_f32()?, projector: p.to_matrix()? })
    }

    fn update(
        &self,
        x: &[f32],
        xbar: &[f32],
        p: &Matrix,
        gamma: f32,
    ) -> Result<Vec<f32>> {
        let n = self.n_of(xbar);
        let name = format!("update_n{n}");
        let out = self.exec.execute(
            &name,
            vec![
                Tensor::vec1(x.to_vec()),
                Tensor::vec1(xbar.to_vec()),
                Tensor::from_matrix(p),
                Tensor::scalar_f32(gamma),
            ],
        )?;
        out.into_iter()
            .next()
            .ok_or_else(|| DapcError::Artifact(format!("{name}: no output")))?
            .into_f32()
    }

    fn average(&self, xs: &[Vec<f32>], xbar: &[f32], eta: f32) -> Result<Vec<f32>> {
        let (j, n) = (xs.len(), self.n_of(xbar));
        let name = format!("average_j{j}_n{n}");
        if !self.exec.has_artifact(&name)? {
            // eq. (7) is a leader-side O(Jn) reduction; when no artifact
            // was AOT-built for this J we compute it natively — exactly
            // what the distributed leader does on its side of the wire.
            return NativeEngine::new().average(xs, xbar, eta);
        }
        let out = self.exec.execute(
            &name,
            vec![
                Tensor::from_rows(xs)?,
                Tensor::vec1(xbar.to_vec()),
                Tensor::scalar_f32(eta),
            ],
        )?;
        out.into_iter()
            .next()
            .ok_or_else(|| DapcError::Artifact(format!("{name}: no output")))?
            .into_f32()
    }

    fn round(
        &self,
        xs: &[Vec<f32>],
        xbar: &[f32],
        ps: &[Matrix],
        gamma: f32,
        eta: f32,
    ) -> Result<(Vec<Vec<f32>>, Vec<f32>)> {
        let (j, n) = (xs.len(), self.n_of(xbar));
        let name = format!("round_j{j}_n{n}");
        if !self.fused_rounds || !self.exec.has_artifact(&name)? {
            // fall back to per-op path
            let mut new_xs = Vec::with_capacity(xs.len());
            for (x, p) in xs.iter().zip(ps) {
                new_xs.push(self.update(x, xbar, p, gamma)?);
            }
            let new_xbar = self.average(&new_xs, xbar, eta)?;
            return Ok((new_xs, new_xbar));
        }
        let out = self.exec.execute(
            &name,
            vec![
                Tensor::from_rows(xs)?,
                Tensor::vec1(xbar.to_vec()),
                Tensor::from_matrices(ps)?,
                Tensor::scalar_f32(gamma),
                Tensor::scalar_f32(eta),
            ],
        )?;
        let [xs_t, xbar_t]: [Tensor; 2] = out.try_into().map_err(|_| {
            DapcError::Artifact(format!("{name}: expected 2 outputs"))
        })?;
        Ok((xs_t.into_rows()?, xbar_t.into_f32()?))
    }

    fn solve_loop(
        &self,
        xs: &[Vec<f32>],
        xbar: &[f32],
        ps: &[Matrix],
        gamma: f32,
        eta: f32,
        epochs: usize,
    ) -> Result<Option<(Vec<Vec<f32>>, Vec<f32>)>> {
        let (j, n) = (xs.len(), self.n_of(xbar));
        let name = format!("solve_j{j}_n{n}");
        if !self.fused_loop || !self.exec.has_artifact(&name)? {
            return Ok(None);
        }
        let out = self.exec.execute(
            &name,
            vec![
                Tensor::from_rows(xs)?,
                Tensor::vec1(xbar.to_vec()),
                Tensor::from_matrices(ps)?,
                Tensor::scalar_f32(gamma),
                Tensor::scalar_f32(eta),
                Tensor::I32Scalar(epochs as i32),
            ],
        )?;
        let [xs_t, xbar_t]: [Tensor; 2] = out.try_into().map_err(|_| {
            DapcError::Artifact(format!("{name}: expected 2 outputs"))
        })?;
        Ok(Some((xs_t.into_rows()?, xbar_t.into_f32()?)))
    }

    fn dgd_grad(&self, a: &Matrix, x: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let (rows, n) = a.shape();
        // pad to the nearest dgd_grad bucket: zero rows contribute nothing
        // to A^T (A x - b) (b padded with zeros), identity-extended columns
        // produce zero gradient entries which we truncate below.
        let buckets = self.exec.init_buckets("dgd_grad")?;
        let (l_pad, n_pad) =
            crate::partition::bucket::choose_bucket(rows, n, &buckets)
                .ok_or_else(|| {
                    DapcError::Artifact(format!(
                        "no dgd_grad artifact fits {rows}x{n}; buckets: \
                         {buckets:?}"
                    ))
                })?;
        let blk = pad_to_bucket(a, b, l_pad, n_pad)?;
        let mut x_pad = x.to_vec();
        x_pad.resize(n_pad, 0.0);
        let name = format!("dgd_grad_l{l_pad}_n{n_pad}");
        let out = self.exec.execute(
            &name,
            vec![
                Tensor::from_matrix(&blk.a),
                Tensor::vec1(x_pad),
                Tensor::vec1(blk.b.clone()),
            ],
        )?;
        let mut g = out
            .into_iter()
            .next()
            .ok_or_else(|| DapcError::Artifact(format!("{name}: no output")))?
            .into_f32()?;
        g.truncate(n);
        Ok(g)
    }

    fn init_bucket(
        &self,
        kind: InitKind,
        rows: usize,
        n: usize,
    ) -> Result<Option<(usize, usize)>> {
        let buckets = self.exec.init_buckets(kind.artifact_kind())?;
        Ok(crate::partition::bucket::choose_bucket(rows, n, &buckets))
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::bucket;
    use crate::rng::seeded;

    fn randm(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut g = seeded(seed);
        Matrix::from_fn(rows, cols, |_, _| g.normal_f32())
    }

    fn consistent(l: usize, n: usize, seed: u64) -> (Matrix, Vec<f32>, Vec<f32>) {
        let a = randm(l, n, seed);
        let mut g = seeded(seed + 1);
        let x: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
        let mut b = vec![0.0f32; l];
        blas::gemv(&a, &x, &mut b);
        (a, b, x)
    }

    #[test]
    fn native_init_qr_solves() {
        let (a, b, x_true) = consistent(48, 16, 1);
        let e = NativeEngine::new();
        let init = e.init(InitKind::Qr, &a, &b, 16).unwrap();
        for i in 0..16 {
            assert!((init.x0[i] - x_true[i]).abs() < 1e-2, "i={i}");
        }
        // tall regime: projector is rounding noise
        assert!(crate::linalg::norms::max_abs(init.projector.as_slice()) < 1e-3);
    }

    #[test]
    fn native_init_classical_solves() {
        let (a, b, x_true) = consistent(48, 16, 2);
        let e = NativeEngine::new();
        let init = e.init(InitKind::Classical, &a, &b, 16).unwrap();
        for i in 0..16 {
            assert!((init.x0[i] - x_true[i]).abs() < 5e-2, "i={i}");
        }
    }

    #[test]
    fn native_init_fat_min_norm() {
        let (a, b, _) = consistent(8, 24, 3);
        let e = NativeEngine::new();
        let init = e.init(InitKind::Fat, &a, &b, 24).unwrap();
        // residual ~ 0
        let mut ax = vec![0.0f32; 8];
        blas::gemv(&a, &init.x0, &mut ax);
        for i in 0..8 {
            assert!((ax[i] - b[i]).abs() < 1e-3);
        }
        // projector idempotent with trace = n - l
        let pp = blas::gemm(&init.projector, &init.projector);
        assert!(pp.max_abs_diff(&init.projector) < 1e-3);
        let tr: f32 = (0..24).map(|i| init.projector[(i, i)]).sum();
        assert!((tr - 16.0).abs() < 1e-2);
    }

    #[test]
    fn native_update_and_average_semantics() {
        let e = NativeEngine::new();
        let x = vec![1.0f32, 2.0];
        let xbar = vec![3.0f32, 4.0];
        let p = Matrix::eye(2);
        // gamma 0.5, P = I: x + 0.5 (xbar - x) = midpoint
        let up = e.update(&x, &xbar, &p, 0.5).unwrap();
        assert_eq!(up, vec![2.0, 3.0]);
        // eta = 1: plain mean
        let avg = e
            .average(&[vec![0.0, 0.0], vec![2.0, 4.0]], &xbar, 1.0)
            .unwrap();
        assert_eq!(avg, vec![1.0, 2.0]);
        // eta = 0: keep xbar
        let keep = e
            .average(&[vec![9.0, 9.0]], &xbar, 0.0)
            .unwrap();
        assert_eq!(keep, xbar);
    }

    #[test]
    fn native_round_consistent_with_parts() {
        let e = NativeEngine::new();
        let mut g = seeded(5);
        let n = 12;
        let xs: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..n).map(|_| g.normal_f32()).collect())
            .collect();
        let xbar: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
        let ps: Vec<Matrix> =
            (0..3).map(|i| randm(n, n, 40 + i)).collect();
        let (xs2, xbar2) = e.round(&xs, &xbar, &ps, 0.7, 0.4).unwrap();
        // manual
        let mut manual = Vec::new();
        for (x, p) in xs.iter().zip(&ps) {
            manual.push(e.update(x, &xbar, p, 0.7).unwrap());
        }
        let manual_avg = e.average(&manual, &xbar, 0.4).unwrap();
        assert_eq!(xs2, manual);
        assert_eq!(xbar2, manual_avg);
    }

    #[test]
    fn native_dgd_grad_zero_at_solution() {
        let (a, b, x_true) = consistent(20, 8, 7);
        let e = NativeEngine::new();
        let g = e.dgd_grad(&a, &x_true, &b).unwrap();
        assert!(crate::linalg::norms::max_abs(&g) < 1e-3);
    }

    #[test]
    fn bucket_helper_exposed() {
        // choose_bucket re-export sanity
        assert_eq!(
            bucket::choose_bucket(10, 4, &[(16, 4)]),
            Some((16, 4))
        );
    }
}
