//! MatrixMarket (.mtx) reader/writer.
//!
//! Supports the subset the SuiteSparse `c-*` datasets use: `matrix
//! coordinate {real|integer|pattern} {general|symmetric}` plus `array`
//! format for dense vectors (the paper reads both `A` and `b` with
//! `scipy.io.mmread`).  The data-type token is validated explicitly:
//! `complex` and unknown types are rejected with a clear parse error
//! instead of being silently read as real.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use crate::error::{DapcError, Result};

use super::{CooMatrix, CsrMatrix};

/// Parsed header of a MatrixMarket file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MmHeader {
    pub format: MmFormat,
    pub field: MmField,
    pub symmetric: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MmFormat {
    Coordinate,
    Array,
}

/// Data type of the stored values.  Validated explicitly: `complex` and
/// unknown tokens are rejected up front instead of being silently read
/// as real data (which would mis-parse every entry line after it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MmField {
    Real,
    Integer,
    /// Structure-only matrices: entries are `row col` with an implicit
    /// value of 1.0.
    Pattern,
}

fn parse_header(line: &str) -> Result<MmHeader> {
    let lower = line.to_ascii_lowercase();
    let toks: Vec<&str> = lower.split_whitespace().collect();
    if toks.len() < 4 || toks[0] != "%%matrixmarket" || toks[1] != "matrix" {
        return Err(DapcError::Parse(format!(
            "invalid MatrixMarket header: {line:?}"
        )));
    }
    let format = match toks[2] {
        "coordinate" => MmFormat::Coordinate,
        "array" => MmFormat::Array,
        other => {
            return Err(DapcError::Parse(format!(
                "unsupported MatrixMarket format {other:?}"
            )))
        }
    };
    let field = match toks[3] {
        // "double" is a long-accepted alias for real in the wild (and in
        // this reader's previous versions) — keep reading it
        "real" | "double" => MmField::Real,
        "integer" => MmField::Integer,
        "pattern" => MmField::Pattern,
        "complex" => {
            return Err(DapcError::Parse(
                "complex MatrixMarket matrices are not supported (the \
                 solver is real-valued; expected real, integer or pattern)"
                    .into(),
            ))
        }
        other => {
            return Err(DapcError::Parse(format!(
                "unknown MatrixMarket data type {other:?} (expected real, \
                 integer or pattern)"
            )))
        }
    };
    if format == MmFormat::Array && field == MmField::Pattern {
        return Err(DapcError::Parse(
            "pattern is only valid for coordinate format".into(),
        ));
    }
    let symmetric = match toks.get(4).copied().unwrap_or("general") {
        "general" => false,
        "symmetric" => true,
        other => {
            return Err(DapcError::Parse(format!(
                "unsupported MatrixMarket symmetry {other:?}"
            )))
        }
    };
    Ok(MmHeader { format, field, symmetric })
}

/// Read a sparse matrix from a MatrixMarket file.
pub fn read_matrix(path: &Path) -> Result<CsrMatrix> {
    let file = std::fs::File::open(path)?;
    read_matrix_from(BufReader::new(file))
}

/// Read a sparse matrix from any buffered reader (unit-testable).
pub fn read_matrix_from<R: BufRead>(reader: R) -> Result<CsrMatrix> {
    let mut lines = reader.lines();
    let header_line = lines
        .next()
        .ok_or_else(|| DapcError::Parse("empty MatrixMarket file".into()))??;
    let header = parse_header(&header_line)?;

    let mut data_lines = lines
        .filter_map(|l| l.ok())
        .filter(|l| !l.trim().is_empty() && !l.trim_start().starts_with('%'));

    let size_line = data_lines
        .next()
        .ok_or_else(|| DapcError::Parse("missing size line".into()))?;
    let dims: Vec<&str> = size_line.split_whitespace().collect();

    match header.format {
        MmFormat::Coordinate => {
            if dims.len() != 3 {
                return Err(DapcError::Parse(format!(
                    "bad coordinate size line: {size_line:?}"
                )));
            }
            let rows: usize = dims[0].parse().map_err(|_| bad_num(dims[0]))?;
            let cols: usize = dims[1].parse().map_err(|_| bad_num(dims[1]))?;
            let nnz: usize = dims[2].parse().map_err(|_| bad_num(dims[2]))?;
            let mut coo = CooMatrix::new(rows, cols);
            let mut count = 0usize;
            for line in data_lines {
                let t: Vec<&str> = line.split_whitespace().collect();
                if t.len() < 2 {
                    return Err(DapcError::Parse(format!("bad entry: {line:?}")));
                }
                let r: usize = t[0].parse().map_err(|_| bad_num(t[0]))?;
                let c: usize = t[1].parse().map_err(|_| bad_num(t[1]))?;
                let v: f32 = match header.field {
                    // pattern entries carry no value token
                    MmField::Pattern => 1.0,
                    MmField::Real | MmField::Integer => {
                        if t.len() < 3 {
                            return Err(DapcError::Parse(format!(
                                "missing value in {:?} entry: {line:?}",
                                header.field
                            )));
                        }
                        t[2].parse().map_err(|_| bad_num(t[2]))?
                    }
                };
                if r == 0 || c == 0 {
                    return Err(DapcError::Parse(
                        "MatrixMarket indices are 1-based; got 0".into(),
                    ));
                }
                coo.push(r - 1, c - 1, v)?;
                if header.symmetric && r != c {
                    coo.push(c - 1, r - 1, v)?;
                }
                count += 1;
            }
            if count != nnz {
                return Err(DapcError::Parse(format!(
                    "expected {nnz} entries, found {count}"
                )));
            }
            Ok(coo.to_csr())
        }
        MmFormat::Array => {
            if dims.len() != 2 {
                return Err(DapcError::Parse(format!(
                    "bad array size line: {size_line:?}"
                )));
            }
            let rows: usize = dims[0].parse().map_err(|_| bad_num(dims[0]))?;
            let cols: usize = dims[1].parse().map_err(|_| bad_num(dims[1]))?;
            let mut vals = Vec::with_capacity(rows * cols);
            for line in data_lines {
                for tok in line.split_whitespace() {
                    vals.push(tok.parse::<f32>().map_err(|_| bad_num(tok))?);
                }
            }
            if vals.len() != rows * cols {
                return Err(DapcError::Parse(format!(
                    "expected {} array values, found {}",
                    rows * cols,
                    vals.len()
                )));
            }
            // array format is column-major; transpose into row-major dense
            let mut coo = CooMatrix::new(rows, cols);
            for c in 0..cols {
                for r in 0..rows {
                    let v = vals[c * rows + r];
                    if v != 0.0 {
                        coo.push(r, c, v)?;
                    }
                }
            }
            Ok(coo.to_csr())
        }
    }
}

/// Read a dense vector (m x 1 matrix in either format).
pub fn read_vector(path: &Path) -> Result<Vec<f32>> {
    let m = read_matrix(path)?;
    if m.cols() != 1 {
        return Err(DapcError::Parse(format!(
            "expected a column vector, got {}x{}",
            m.rows(),
            m.cols()
        )));
    }
    let mut v = vec![0.0f32; m.rows()];
    for i in 0..m.rows() {
        v[i] = m.get(i, 0);
    }
    Ok(v)
}

fn bad_num(tok: &str) -> DapcError {
    DapcError::Parse(format!("invalid number {tok:?}"))
}

/// Write a CSR matrix in coordinate format.
pub fn write_matrix(path: &Path, m: &CsrMatrix) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(f, "% generated by dapc (synthetic Schenk_IBMNA-like dataset)")?;
    writeln!(f, "{} {} {}", m.rows(), m.cols(), m.nnz())?;
    for i in 0..m.rows() {
        let (idx, vals) = m.row(i);
        for (&j, &v) in idx.iter().zip(vals) {
            writeln!(f, "{} {} {}", i + 1, j + 1, v)?;
        }
    }
    Ok(())
}

/// Write a dense vector in array format.
pub fn write_vector(path: &Path, v: &[f32]) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "%%MatrixMarket matrix array real general")?;
    writeln!(f, "{} 1", v.len())?;
    for x in v {
        writeln!(f, "{x}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_coordinate_general() {
        let text = "%%MatrixMarket matrix coordinate real general\n\
                    % comment\n\
                    3 3 2\n\
                    1 1 2.5\n\
                    3 2 -1.0\n";
        let m = read_matrix_from(Cursor::new(text)).unwrap();
        assert_eq!(m.shape(), (3, 3));
        assert_eq!(m.get(0, 0), 2.5);
        assert_eq!(m.get(2, 1), -1.0);
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn parse_symmetric_mirrors_entries() {
        let text = "%%MatrixMarket matrix coordinate real symmetric\n\
                    2 2 2\n\
                    1 1 1.0\n\
                    2 1 5.0\n";
        let m = read_matrix_from(Cursor::new(text)).unwrap();
        assert_eq!(m.get(0, 1), 5.0);
        assert_eq!(m.get(1, 0), 5.0);
        assert_eq!(m.nnz(), 3);
    }

    #[test]
    fn parse_array_column_major() {
        let text = "%%MatrixMarket matrix array real general\n\
                    2 2\n1\n2\n3\n4\n";
        let m = read_matrix_from(Cursor::new(text)).unwrap();
        // column-major: [[1,3],[2,4]]
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(1, 0), 2.0);
        assert_eq!(m.get(0, 1), 3.0);
        assert_eq!(m.get(1, 1), 4.0);
    }

    #[test]
    fn parse_pattern_entries_without_values() {
        let text = "%%MatrixMarket matrix coordinate pattern general\n\
                    3 3 2\n\
                    1 1\n\
                    3 2\n";
        let m = read_matrix_from(Cursor::new(text)).unwrap();
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(2, 1), 1.0);
        assert_eq!(m.nnz(), 2);
        // integer data parses as real values
        let ints = "%%MatrixMarket matrix coordinate integer general\n\
                    2 2 1\n\
                    1 2 5\n";
        let m = read_matrix_from(Cursor::new(ints)).unwrap();
        assert_eq!(m.get(0, 1), 5.0);
        // the legacy "double" alias keeps parsing as real
        let dbl = "%%MatrixMarket matrix coordinate double general\n\
                   1 1 1\n\
                   1 1 2.5\n";
        let m = read_matrix_from(Cursor::new(dbl)).unwrap();
        assert_eq!(m.get(0, 0), 2.5);
    }

    #[test]
    fn data_type_token_validated_explicitly() {
        // complex: clear, dedicated rejection
        let err = read_matrix_from(Cursor::new(
            "%%MatrixMarket matrix coordinate complex general\n1 1 1\n1 1 2 3\n",
        ))
        .unwrap_err();
        assert!(err.to_string().contains("complex"), "{err}");
        // unknown type: no silent fall-through to real
        let err = read_matrix_from(Cursor::new(
            "%%MatrixMarket matrix coordinate quaternion general\n1 1 0\n",
        ))
        .unwrap_err();
        assert!(err.to_string().contains("unknown MatrixMarket"), "{err}");
        // real entry MISSING its value is now an error, not a silent 1.0
        let err = read_matrix_from(Cursor::new(
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 2\n",
        ))
        .unwrap_err();
        assert!(err.to_string().contains("missing value"), "{err}");
        // pattern arrays are contradictory
        assert!(read_matrix_from(Cursor::new(
            "%%MatrixMarket matrix array pattern general\n1 1\n"
        ))
        .is_err());
    }

    #[test]
    fn reject_malformed() {
        assert!(read_matrix_from(Cursor::new("garbage\n")).is_err());
        assert!(read_matrix_from(Cursor::new(
            "%%MatrixMarket matrix coordinate complex general\n1 1 0\n"
        ))
        .is_err());
        // nnz mismatch
        assert!(read_matrix_from(Cursor::new(
            "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n"
        ))
        .is_err());
        // 0-based index
        assert!(read_matrix_from(Cursor::new(
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n0 1 1.0\n"
        ))
        .is_err());
    }

    #[test]
    fn roundtrip_through_files() {
        let dir = std::env::temp_dir().join("dapc_mm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut coo = CooMatrix::new(4, 3);
        coo.push(0, 0, 1.25).unwrap();
        coo.push(3, 2, -0.5).unwrap();
        coo.push(1, 1, 7.0).unwrap();
        let m = coo.to_csr();
        let mp = dir.join("a.mtx");
        write_matrix(&mp, &m).unwrap();
        let back = read_matrix(&mp).unwrap();
        assert_eq!(back, m);

        let vp = dir.join("b.mtx");
        let v = vec![1.0f32, -2.0, 3.5];
        write_vector(&vp, &v).unwrap();
        assert_eq!(read_vector(&vp).unwrap(), v);
    }
}
