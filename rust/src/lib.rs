//! # DAPC — Distributed Accelerated Projection-Based Consensus Decomposition
//!
//! A production-grade reproduction of *"Distributed Accelerated
//! Projection-Based Consensus Decomposition"* (W. Maj, TASK Quarterly
//! 26(2), 2022; DOI 10.34808/yrfh-s352) as a three-layer Rust + JAX +
//! Pallas system:
//!
//! * **Layer 3 (this crate)** — the distributed coordinator: leader/worker
//!   consensus runtime, partitioning, scheduling, metrics and CLI.  Python
//!   is never on the request path.
//! * **Layer 2** (`python/compile/model.py`) — the per-worker compute
//!   graphs (QR init, consensus rounds) written in JAX and AOT-lowered to
//!   HLO text artifacts.
//! * **Layer 1** (`python/compile/kernels/`) — Pallas kernels for the
//!   consensus hot path, lowered inside the L2 graphs.
//!
//! ## Quickstart
//!
//! ```no_run
//! use dapc::prelude::*;
//!
//! // Generate a small consistent system and solve it with the paper's
//! // decomposed APC on the native engine.
//! let ds = dapc::sparse::generate::GeneratorConfig::small_demo(64, 4)
//!     .generate(42);
//! let opts = SolveOptions { epochs: 50, ..SolveOptions::default() };
//! let engine = NativeEngine::new();
//! let report = DapcSolver::new(opts)
//!     .solve(&engine, &ds.matrix, &ds.rhs, 4)
//!     .unwrap();
//! println!("MSE vs truth: {:.3e}", report.final_mse(&ds.x_true));
//! ```
//!
//! See `examples/` for end-to-end drivers and `benches/` for the
//! reproductions of the paper's Table 1 and Figure 2.

// The workspace-reuse APIs (`round_into`, `update_into`, ...) thread many
// caller-owned buffers through one call by design — that is what keeps the
// steady-state epoch loop allocation-free.  A params struct would only
// obscure the hot path.
#![allow(clippy::too_many_arguments)]

pub mod audit;
pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod linalg;
pub mod metrics;
pub mod obs;
pub mod parallel;
pub mod partition;
pub mod rng;
pub mod runtime;
pub mod service;
pub mod solver;
pub mod sparse;

pub use error::{DapcError, Result};

/// Common imports for downstream users.
pub mod prelude {
    pub use crate::error::{DapcError, Result};
    pub use crate::linalg::Matrix;
    pub use crate::partition::{PartitionPlan, PartitionRegime};
    pub use crate::parallel::ParallelEngine;
    pub use crate::service::{
        ServiceStats, SessionAlgorithm, SessionConfig, SessionManager,
        SolverSession,
    };
    pub use crate::solver::{
        ApcClassicalSolver, DapcSolver, DgdSolver, NativeEngine, SolveOptions,
        SolveReport, Solver,
    };
    pub use crate::sparse::CsrMatrix;
}
