//! Run configuration: hyper-parameters, engine/solver selection, paths.
//!
//! Loadable from a JSON file (`--config run.json`) and overridable from
//! the CLI; validated before a run starts.  JSON parsing is in-repo
//! ([`json::Json`]) since serde is unavailable offline.

pub mod envvars;
pub mod json;

use std::path::{Path, PathBuf};

use crate::error::{DapcError, Result};

pub use json::Json;

/// Which solver algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    /// The paper's decomposed APC (QR + backward substitution).
    DapcDecomposed,
    /// Classical APC (Gram inverse init) — Table 1 baseline.
    ApcClassical,
    /// Distributed gradient descent — Fig. 2 baseline.
    Dgd,
}

impl Algorithm {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "dapc" | "decomposed" | "dapc-decomposed" => Ok(Self::DapcDecomposed),
            "apc" | "classical" | "apc-classical" => Ok(Self::ApcClassical),
            "dgd" => Ok(Self::Dgd),
            other => Err(DapcError::Config(format!(
                "unknown algorithm {other:?} (expected dapc|apc|dgd)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::DapcDecomposed => "dapc-decomposed",
            Self::ApcClassical => "apc-classical",
            Self::Dgd => "dgd",
        }
    }
}

/// Which compute engine executes the worker math.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Native Rust linalg (always available).
    Native,
    /// AOT HLO artifacts on the PJRT CPU client (the paper's L1/L2 path).
    Xla,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "native" | "rust" => Ok(Self::Native),
            "xla" | "pjrt" => Ok(Self::Xla),
            other => Err(DapcError::Config(format!(
                "unknown engine {other:?} (expected native|xla)"
            ))),
        }
    }
}

/// Full run configuration (CLI `solve` command / config file).
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub algorithm: Algorithm,
    pub engine: EngineKind,
    /// Number of partitions J.
    pub partitions: usize,
    /// Worker threads for the native engine's parallel path: 1 = the
    /// sequential reference engine, 0 = one thread per hardware thread,
    /// N > 1 = a pool of N (`--threads`).
    pub threads: usize,
    /// Number of consensus epochs T.
    pub epochs: usize,
    /// Mixing weight eta in (0, 1].
    pub eta: f32,
    /// Projection step gamma in (0, 1].
    pub gamma: f32,
    /// DGD step size (only used by Algorithm::Dgd).
    pub dgd_step: f32,
    /// Artifact directory (manifest.json + *.hlo.txt).
    pub artifacts_dir: PathBuf,
    /// Optional dataset paths (MatrixMarket); synthetic when absent.
    pub matrix_path: Option<PathBuf>,
    pub rhs_path: Option<PathBuf>,
    /// Synthetic problem size when no dataset is given.
    pub synth_n: usize,
    /// RNG seed for synthetic data.
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            algorithm: Algorithm::DapcDecomposed,
            engine: EngineKind::Native,
            partitions: 2,
            threads: 1,
            epochs: 80,
            eta: 0.9,
            gamma: 0.9,
            dgd_step: 1e-3,
            artifacts_dir: PathBuf::from("artifacts"),
            matrix_path: None,
            rhs_path: None,
            synth_n: 128,
            seed: 42,
        }
    }
}

impl RunConfig {
    /// Validate hyper-parameter ranges (paper: eta, gamma in (0, 1)).
    pub fn validate(&self) -> Result<()> {
        if self.partitions == 0 {
            return Err(DapcError::Config("partitions must be >= 1".into()));
        }
        if !(self.eta > 0.0 && self.eta <= 1.0) {
            return Err(DapcError::Config(format!(
                "eta must be in (0, 1], got {}",
                self.eta
            )));
        }
        if !(self.gamma > 0.0 && self.gamma <= 1.0) {
            return Err(DapcError::Config(format!(
                "gamma must be in (0, 1], got {}",
                self.gamma
            )));
        }
        if self.matrix_path.is_some() != self.rhs_path.is_some() {
            return Err(DapcError::Config(
                "matrix and rhs paths must be given together".into(),
            ));
        }
        Ok(())
    }

    /// Load from a JSON config file; unknown keys are rejected to catch
    /// typos early.
    pub fn from_json_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&text)
    }

    /// Parse from a JSON string.
    pub fn from_json(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let obj = v
            .as_obj()
            .ok_or_else(|| DapcError::Config("config must be an object".into()))?;
        let mut cfg = Self::default();
        for (key, val) in obj {
            match key.as_str() {
                "algorithm" => {
                    cfg.algorithm = Algorithm::parse(val.as_str().ok_or_else(
                        || DapcError::Config("algorithm must be a string".into()),
                    )?)?
                }
                "engine" => {
                    cfg.engine = EngineKind::parse(val.as_str().ok_or_else(
                        || DapcError::Config("engine must be a string".into()),
                    )?)?
                }
                "partitions" => cfg.partitions = num(val, key)? as usize,
                "threads" => cfg.threads = num(val, key)? as usize,
                "epochs" => cfg.epochs = num(val, key)? as usize,
                "eta" => cfg.eta = num(val, key)? as f32,
                "gamma" => cfg.gamma = num(val, key)? as f32,
                "dgd_step" => cfg.dgd_step = num(val, key)? as f32,
                "artifacts_dir" => {
                    cfg.artifacts_dir = PathBuf::from(str_val(val, key)?)
                }
                "matrix_path" => {
                    cfg.matrix_path = Some(PathBuf::from(str_val(val, key)?))
                }
                "rhs_path" => {
                    cfg.rhs_path = Some(PathBuf::from(str_val(val, key)?))
                }
                "synth_n" => cfg.synth_n = num(val, key)? as usize,
                "seed" => cfg.seed = num(val, key)? as u64,
                other => {
                    return Err(DapcError::Config(format!(
                        "unknown config key {other:?}"
                    )))
                }
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

fn num(v: &Json, key: &str) -> Result<f64> {
    v.as_f64()
        .ok_or_else(|| DapcError::Config(format!("{key} must be a number")))
}

fn str_val<'a>(v: &'a Json, key: &str) -> Result<&'a str> {
    v.as_str()
        .ok_or_else(|| DapcError::Config(format!("{key} must be a string")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn parse_full_config() {
        let cfg = RunConfig::from_json(
            r#"{"algorithm": "apc", "engine": "xla", "partitions": 4,
                "epochs": 95, "eta": 0.8, "gamma": 0.75, "threads": 8,
                "artifacts_dir": "artifacts", "synth_n": 512, "seed": 7}"#,
        )
        .unwrap();
        assert_eq!(cfg.algorithm, Algorithm::ApcClassical);
        assert_eq!(cfg.engine, EngineKind::Xla);
        assert_eq!(cfg.partitions, 4);
        assert_eq!(cfg.threads, 8);
        assert_eq!(cfg.epochs, 95);
        assert!((cfg.eta - 0.8).abs() < 1e-6);
        assert_eq!(cfg.synth_n, 512);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(RunConfig::from_json(r#"{"eta": 1.5}"#).is_err());
        assert!(RunConfig::from_json(r#"{"gamma": 0.0}"#).is_err());
        assert!(RunConfig::from_json(r#"{"partitions": 0}"#).is_err());
        assert!(RunConfig::from_json(r#"{"unknown_key": 1}"#).is_err());
        assert!(RunConfig::from_json(r#"{"algorithm": "sgd"}"#).is_err());
        assert!(RunConfig::from_json(r#"{"matrix_path": "a.mtx"}"#).is_err());
        assert!(RunConfig::from_json(r#"[1]"#).is_err());
    }

    #[test]
    fn algorithm_and_engine_aliases() {
        assert_eq!(Algorithm::parse("DAPC").unwrap(), Algorithm::DapcDecomposed);
        assert_eq!(Algorithm::parse("classical").unwrap(), Algorithm::ApcClassical);
        assert_eq!(EngineKind::parse("rust").unwrap(), EngineKind::Native);
        assert_eq!(EngineKind::parse("PJRT").unwrap(), EngineKind::Xla);
    }
}
