//! Figure 2 bench: regenerates the convergence curves (MSE vs epochs) for
//! decomposed APC, classical APC and DGD on the c-27-like dataset and
//! prints the series (CSV to target/fig2_bench.csv, chart to stdout).
//!
//! `DAPC_FULL=1` uses the paper's exact n = 4563; default is 1/8 scale.

use std::path::Path;

use dapc::benchkit::{full_mode, quick_mode};
use dapc::metrics::ConvergenceTrace;
use dapc::prelude::*;
use dapc::sparse::generate::GeneratorConfig;

fn main() {
    let n = if full_mode() {
        4563
    } else if quick_mode() {
        128
    } else {
        570
    };
    let epochs = if quick_mode() { 20 } else { 95 };
    let j = 2;
    let engine = NativeEngine::new();
    let ds = GeneratorConfig::schenk_like(n).generate(27);
    println!(
        "=== Figure 2: n={n} (m={}), J={j}, T={epochs}, {:.2}% sparse ===",
        4 * n,
        ds.matrix.sparsity_pct()
    );
    let opts = SolveOptions {
        epochs,
        eta: 0.9,
        gamma: 0.9,
        dgd_step: 0.0,
        x_true: Some(ds.x_true.clone()),
        ..Default::default()
    };

    let mut d = DapcSolver::new(opts.clone())
        .solve(&engine, &ds.matrix, &ds.rhs, j)
        .expect("dapc")
        .trace
        .unwrap();
    d.label = "decomposed-apc".into();
    let mut c = ApcClassicalSolver::new(opts.clone())
        .solve(&engine, &ds.matrix, &ds.rhs, j)
        .expect("apc")
        .trace
        .unwrap();
    c.label = "classical-apc".into();
    let mut g = DgdSolver::new(opts.clone())
        .solve(&engine, &ds.matrix, &ds.rhs, j)
        .expect("dgd")
        .trace
        .unwrap();
    g.label = "dgd".into();

    // Extension series: the fat regime (original APC [7], l < n), where the
    // projectors are genuine and the consensus iteration visibly converges
    // over epochs (in the paper's tall regime P ~ 0 and the curve is flat
    // from epoch 0 — see EXPERIMENTS.md).
    let mut f = DapcSolver::new(SolveOptions { eta: 0.6, ..opts })
        .solve(&engine, &ds.matrix, &ds.rhs, 8) // l = m/8 = n/2 < n
        .expect("fat")
        .trace
        .unwrap();
    f.label = "decomposed-apc-fat(J=8)".into();

    std::fs::create_dir_all("target").ok();
    ConvergenceTrace::write_csv(
        Path::new("target/fig2_bench.csv"),
        &[&d, &c, &g, &f],
    )
    .expect("csv");
    println!("{}", ConvergenceTrace::ascii_chart(&[&d, &c, &g, &f], 72, 18));

    // the paper's qualitative claims, asserted:
    let (d0, c0) = (d.initial_mse().unwrap(), c.initial_mse().unwrap());
    let (df, cf, gf) = (
        d.final_mse().unwrap(),
        c.final_mse().unwrap(),
        g.final_mse().unwrap(),
    );
    println!("initial: decomposed {d0:.3e} vs classical {c0:.3e}");
    println!("final:   decomposed {df:.3e}, classical {cf:.3e}, dgd {gf:.3e}");
    println!(
        "claims: both APC variants converge to ~same minima: {}; \
         DGD slower at equal T: {}",
        (df - cf).abs() < cf.max(df) * 100.0,
        gf > df
    );
    println!("wrote target/fig2_bench.csv");
}
