//! The prepacked epoch path is a pure optimization: bit-for-bit
//! equivalent to the retained row-dot oracle.
//!
//! `round_batch_packed_into` / `update_batch_packed` stream the batched
//! consensus update through prepacked projector panels
//! (`blas::PrepackedPanels`) and the wide packed microkernel.  Because
//! every output element of that kernel reproduces `dot_wide`'s
//! lane-deterministic f64 accumulation order exactly, the packed path
//! must agree with the row-dot `round_batch_into`/`update_batch` oracle
//! to the last bit — single-RHS and batched, serial and pooled at any
//! worker count, on either dispatch backend, across every `n % 8`
//! (== `n % NR`) panel-remainder class.  CI runs this suite on all three
//! matrix legs (dispatched, `DAPC_FORCE_SCALAR`, `DAPC_KERNEL_TIER=fast`
//! — the epoch path pins tier-0, so the fast leg must not perturb it).

use dapc::linalg::blas;
use dapc::linalg::Matrix;
use dapc::rng::seeded;
use dapc::service::{SessionConfig, SolverSession};
use dapc::solver::{
    drive_apc, ApcVariant, ComputeEngine, InProcessBackend, NativeEngine,
    ParallelEngine, RoundWorkspace, SolveOptions,
};
use dapc::sparse::generate::{Dataset, GeneratorConfig};

fn randm(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut g = seeded(seed);
    Matrix::from_fn(rows, cols, |_, _| g.normal_f32())
}

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut g = seeded(seed);
    (0..n).map(|_| g.normal_f32()).collect()
}

/// One random batched-round problem: j partitions, k columns, width n.
struct Problem {
    ps: Vec<Matrix>,
    panels: Vec<blas::PrepackedPanels>,
    xs: Vec<Vec<Vec<f32>>>,
    xbars: Vec<Vec<f32>>,
}

impl Problem {
    fn new(j: usize, k: usize, n: usize, seed: u64) -> Self {
        let ps: Vec<Matrix> =
            (0..j).map(|i| randm(n, n, seed + 7 * i as u64)).collect();
        let panels = ps.iter().map(blas::PrepackedPanels::from_matrix).collect();
        let xs = (0..j)
            .map(|i| {
                (0..k)
                    .map(|c| randv(n, seed + 100 + (i * k + c) as u64))
                    .collect()
            })
            .collect();
        let xbars =
            (0..k).map(|c| randv(n, seed + 900 + c as u64)).collect();
        Self { ps, panels, xs, xbars }
    }

    fn round_row_dot<E: ComputeEngine>(
        &self,
        e: &E,
    ) -> (Vec<Vec<Vec<f32>>>, Vec<Vec<f32>>) {
        let (j, k) = (self.ps.len(), self.xbars.len());
        let n = self.ps[0].rows();
        let mut ws = RoundWorkspace::default();
        let mut out_xs = vec![vec![vec![0.0; n]; k]; j];
        let mut out_xbars = vec![vec![0.0; n]; k];
        e.round_batch_into(
            &self.xs,
            &self.xbars,
            &self.ps,
            0.7,
            0.6,
            &mut ws,
            &mut out_xs,
            &mut out_xbars,
        )
        .unwrap();
        (out_xs, out_xbars)
    }

    fn round_packed<E: ComputeEngine>(
        &self,
        e: &E,
    ) -> (Vec<Vec<Vec<f32>>>, Vec<Vec<f32>>) {
        let (j, k) = (self.ps.len(), self.xbars.len());
        let n = self.ps[0].rows();
        let mut ws = RoundWorkspace::default();
        let mut out_xs = vec![vec![vec![0.0; n]; k]; j];
        let mut out_xbars = vec![vec![0.0; n]; k];
        e.round_batch_packed_into(
            &self.xs,
            &self.xbars,
            &self.ps,
            &self.panels,
            0.7,
            0.6,
            &mut ws,
            &mut out_xs,
            &mut out_xbars,
        )
        .unwrap();
        (out_xs, out_xbars)
    }
}

#[test]
fn packed_round_matches_row_dot_in_every_remainder_class() {
    // n = 16..=23 walks every n % 8 class (NR == 8, so every panel
    // fringe width too); k covers single-RHS, a partial column panel
    // and a full one
    let e = NativeEngine::new();
    for k in [1usize, 3, 8] {
        for n in 16usize..=23 {
            let p = Problem::new(2, k, n, 5000 + (k * 100 + n) as u64);
            let (want_xs, want_xbars) = p.round_row_dot(&e);
            let (got_xs, got_xbars) = p.round_packed(&e);
            assert_eq!(want_xs, got_xs, "k={k} n={n}");
            assert_eq!(want_xbars, got_xbars, "k={k} n={n}");
        }
    }
}

#[test]
fn pooled_packed_round_matches_native_at_1_2_7_workers() {
    let native = NativeEngine::new();
    for (j, k, n, seed) in
        [(3usize, 4usize, 29usize, 61u64), (2, 8, 16, 62), (1, 1, 13, 63)]
    {
        let p = Problem::new(j, k, n, seed);
        let (want_xs, want_xbars) = p.round_packed(&native);
        // the native packed path itself is oracle-checked above; here the
        // pooled fan (partition x MR-aligned row chunk) must reproduce it
        let (rd_xs, rd_xbars) = p.round_row_dot(&native);
        assert_eq!(want_xs, rd_xs, "native packed vs row-dot j={j} n={n}");
        assert_eq!(want_xbars, rd_xbars, "native packed vs row-dot");
        for threads in [1usize, 2, 7] {
            let par = ParallelEngine::new(threads);
            let (got_xs, got_xbars) = p.round_packed(&par);
            assert_eq!(want_xs, got_xs, "threads={threads} j={j} n={n}");
            assert_eq!(want_xbars, got_xbars, "threads={threads} j={j} n={n}");
        }
    }
}

#[test]
fn packed_update_batch_matches_row_dot_update_batch() {
    let e = NativeEngine::new();
    let par = ParallelEngine::new(3);
    for (k, n) in [(1usize, 24usize), (3, 17), (8, 21)] {
        let p = randm(n, n, 7100 + (k * 100 + n) as u64);
        let panels = blas::PrepackedPanels::from_matrix(&p);
        let xs: Vec<Vec<f32>> =
            (0..k).map(|c| randv(n, 7200 + c as u64)).collect();
        let xbars: Vec<Vec<f32>> =
            (0..k).map(|c| randv(n, 7300 + c as u64)).collect();
        let want = e.update_batch(&xs, &xbars, &p, 0.8).unwrap();
        let got = e.update_batch_packed(&xs, &xbars, &panels, 0.8).unwrap();
        assert_eq!(want, got, "native k={k} n={n}");
        let pooled = par.update_batch_packed(&xs, &xbars, &panels, 0.8).unwrap();
        assert_eq!(want, pooled, "pooled k={k} n={n}");
    }
}

#[test]
fn packed_round_propagates_nan_like_row_dot() {
    // a NaN in one column's consensus average poisons exactly that
    // column on both paths; untouched columns stay bitwise identical
    let e = NativeEngine::new();
    let (j, k, n) = (2usize, 3usize, 13usize);
    let mut p = Problem::new(j, k, n, 8800);
    p.xbars[1][4] = f32::NAN;
    let (want_xs, want_xbars) = p.round_row_dot(&e);

    fn check<E: ComputeEngine>(
        engine: &E,
        p: &Problem,
        want_xs: &[Vec<Vec<f32>>],
        want_xbars: &[Vec<f32>],
    ) {
        let (got_xs, got_xbars) = p.round_packed(engine);
        for (i, (wp, gp)) in want_xs.iter().zip(&got_xs).enumerate() {
            for (c, (w, g)) in wp.iter().zip(gp).enumerate() {
                if c == 1 {
                    assert!(w.iter().all(|v| v.is_nan()), "i={i}");
                    assert!(g.iter().all(|v| v.is_nan()), "i={i}");
                } else {
                    assert_eq!(w, g, "i={i} c={c}");
                }
            }
        }
        for (c, (w, g)) in want_xbars.iter().zip(&got_xbars).enumerate() {
            if c == 1 {
                assert!(w.iter().all(|v| v.is_nan()));
                assert!(g.iter().all(|v| v.is_nan()));
            } else {
                assert_eq!(w, g, "c={c}");
            }
        }
    }

    check(&e, &p, &want_xs, &want_xbars);
    check(&ParallelEngine::new(2), &p, &want_xs, &want_xbars);
}

#[test]
fn warm_sessions_stay_bitwise_equal_to_cold_solves() {
    // the packed path is live inside every registered session; warm
    // serving must still reproduce the cold one-shot solve exactly, on
    // the serial and pooled engines and both APC variants
    fn check<E: ComputeEngine>(engine: &E, ds: &Dataset, tag: &str) {
        let opts = SolveOptions { epochs: 15, ..Default::default() };
        for variant in [ApcVariant::Decomposed, ApcVariant::Classical] {
            let mut cold_backend = InProcessBackend::new(engine, 3);
            let cold = drive_apc(
                &mut cold_backend,
                &ds.matrix,
                &ds.rhs,
                variant,
                &opts,
            )
            .unwrap();

            let mut warm_backend = InProcessBackend::new(engine, 3);
            let mut session = SolverSession::register(
                &mut warm_backend,
                ds.matrix.clone(),
                SessionConfig::apc(variant).options(opts.clone()),
            )
            .unwrap();
            let warm = session.solve(&ds.rhs).unwrap();
            assert_eq!(warm.xbar, cold.xbar, "{tag} {variant:?}");
            assert_eq!(warm.residual, cold.residual, "{tag} {variant:?}");
            // batched serving of the same rhs k=4 times: one packed
            // epoch loop, each column bitwise equal to the single solve
            let bs = vec![ds.rhs.clone(); 4];
            for r in session.solve_batch(&bs).unwrap() {
                assert_eq!(r.xbar, cold.xbar, "{tag} {variant:?} batched");
            }
        }
    }

    let ds = GeneratorConfig::small_demo(16, 3).generate(11);
    check(&NativeEngine::new(), &ds, "native");
    check(&ParallelEngine::new(3), &ds, "parallel(3)");
}
