//! Wire protocol between leader and workers.
//!
//! Hand-rolled binary framing (serde unavailable offline):
//!
//! ```text
//! frame   := u32 header (LE) | u32 payload_len (LE) | payload
//! header  := 0x4450_0000 | WIRE_VERSION   ("DP" magic + version)
//! payload := u8 tag | fields in declaration order
//! vec<f32>:= u64 len | f32 * len        (LE)
//! matrix  := u64 rows | u64 cols | f32 * rows*cols (row-major)
//! string  := u64 len | utf8 bytes
//! u64/f64 := 8 bytes (LE)
//! stats   := u64 count | (string | f64) * count
//! ```
//!
//! The frame header is added by stream transports (see
//! [`super::transport`]); it makes old/new peer mixes fail LOUDLY at the
//! first frame instead of mis-decoding each other's bytes.  Bump
//! [`WIRE_VERSION`] whenever the payload encoding changes.
//!
//! The protocol is deliberately small: projectors are computed worker-side
//! and never serialized; per-epoch traffic is one n-vector each way per
//! worker (the paper's communication pattern).  DGD initialization uses
//! [`InitKindWire::GradOnly`], which ships the block but skips the
//! worker-side factorization entirely.
//!
//! # Sessions (wire v3, multi-tenant since v5)
//!
//! The solve-service frames separate the RHS-independent registration
//! from per-RHS serving: [`Message::RegisterMatrix`] ships a block ONCE
//! (the worker factorizes and keeps `A_j`/`P_j`/seed state across
//! solves), then any number of [`Message::SolveRhs`] /
//! [`Message::SolveBatch`] frames stream right-hand sides through the
//! retained factorization.  Batched epochs run over
//! [`Message::RunUpdateBatch`] / [`Message::RunGradBatch`], carrying k
//! n-vectors per frame.  A worker that receives an RHS before a
//! registration rejects it loudly with a [`Message::WorkerError`].
//!
//! Since v5, EVERY session frame carries a `session_id` (which of the
//! worker's resident factorizations the frame addresses) and a
//! `request_id` (the leader-assigned id of the solve/registration the
//! frame belongs to, echoed verbatim in the reply) — one worker serves
//! MANY registered matrices concurrently, keyed by session id.
//! [`Message::EvictSession`] drops one resident factorization (acked by
//! [`Message::SessionEvicted`]); a session frame naming an id the worker
//! does not hold is rejected with a loud [`Message::WorkerError`].
//!
//! # Telemetry (wire v4)
//!
//! [`Message::StatsRequest`] asks a worker for a flattened snapshot of
//! its metrics registry (`obs::MetricsRegistry::snapshot_flat`); the
//! worker answers with [`Message::StatsReport`] carrying `(name, f64)`
//! pairs.  Telemetry frames never carry solver state — they are
//! read-only observation, so requesting stats can never perturb a
//! solve (the observability never-touch-numerics contract, see
//! `crate::obs`).
//!
//! # Service frames (wire v5)
//!
//! The multi-tenant solve server speaks client-facing frames over the
//! same encoding: [`Message::SubmitSolve`] carries full right-hand
//! sides (not partition slices) under a `(session_id, request_id)` pair
//! and is answered by [`Message::SolveResult`] (per-column solutions +
//! residuals), [`Message::Busy`] (bounded queue full — resubmit later),
//! or [`Message::Evicted`] (the named session is not registered on the
//! server).  [`Message::Credit`] grants flow-control admission credits
//! (quill-style): a client may keep `credits` requests in flight and
//! regains one credit per completed reply.

use crate::error::{DapcError, Result};
use crate::linalg::Matrix;
use crate::solver::InitKind;

/// Version of the payload encoding; carried in every stream frame header.
///
/// v1 was the unversioned PR-0 framing (`u32 len | payload`); v2 added the
/// magic/version header and `InitKindWire::GradOnly`; v3 added the
/// solve-service session frames (`RegisterMatrix`, `SolveRhs`,
/// `SolveBatch` and the batched round/gradient frames); v4 added the
/// telemetry frames (`StatsRequest`/`StatsReport`) and the f64 scalar
/// encoding they carry; v5 made sessions multi-tenant — every session
/// frame now carries `session_id` + `request_id` u64s, plus the
/// eviction (`EvictSession`/`SessionEvicted`) and service-surface
/// (`SubmitSolve`/`SolveResult`/`Busy`/`Evicted`/`Credit`) frames.
pub const WIRE_VERSION: u32 = 5;

/// Protocol messages (both directions).
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Leader -> worker: here is your partition; run init.
    InitPartition {
        worker_id: u32,
        kind: InitKindWire,
        a: Matrix,
        b: Vec<f32>,
        /// Padded solution width the consensus loop runs at.
        n_target: u32,
    },
    /// Worker -> leader: init finished, here is x_j(0) (empty for
    /// [`InitKindWire::GradOnly`] — DGD starts from x = 0).
    InitDone { worker_id: u32, x0: Vec<f32> },
    /// Leader -> worker: consensus epoch t with the current average.
    RunUpdate { epoch: u32, gamma: f32, xbar: Vec<f32> },
    /// Worker -> leader: updated estimate x_j(t+1).
    UpdateDone { worker_id: u32, x: Vec<f32> },
    /// Leader -> worker: DGD gradient request at the current iterate.
    RunGrad { epoch: u32, x: Vec<f32> },
    /// Worker -> leader: local gradient.
    GradDone { worker_id: u32, grad: Vec<f32> },
    /// Worker -> leader: failure (leader aborts the run).
    WorkerError { worker_id: u32, message: String },
    /// Leader -> worker: done, exit the loop.
    Shutdown,
    /// Leader -> worker (v3/v5): register this block under `session_id`
    /// for session service — factorize once, retain `A_j`/`P_j`/seed
    /// state across solves ([`InitKindWire::GradOnly`] stores the block
    /// only).  One worker holds MANY sessions keyed by id.
    RegisterMatrix {
        worker_id: u32,
        session_id: u64,
        request_id: u64,
        kind: InitKindWire,
        a: Matrix,
        /// Padded solution width the consensus loop runs at.
        n_target: u32,
    },
    /// Worker -> leader (v3/v5): registration finished; the
    /// factorization is resident under `session_id` and ready to serve
    /// right-hand sides.  `request_id` echoes the registration frame.
    MatrixRegistered { worker_id: u32, session_id: u64, request_id: u64 },
    /// Leader -> worker (v3/v5): seed ONE fresh rhs slice through the
    /// retained factorization of `session_id`.  Rejected loudly if that
    /// session is not registered on this worker.
    SolveRhs { session_id: u64, request_id: u64, b: Vec<f32> },
    /// Leader -> worker (v3/v5): seed k fresh rhs slices (one batched
    /// solve) into `session_id`.  Rejected loudly if unregistered.
    SolveBatch { session_id: u64, request_id: u64, bs: Vec<Vec<f32>> },
    /// Worker -> leader (v3/v5): per-column initial estimates `x_j(0)`
    /// (empty columns for gradient-only sessions — DGD starts at 0).
    RhsSeeded {
        worker_id: u32,
        session_id: u64,
        request_id: u64,
        x0s: Vec<Vec<f32>>,
    },
    /// Leader -> worker (v3/v5): one batched eq. (6) round at the
    /// current per-column averages, against `session_id`'s seeded state.
    RunUpdateBatch {
        session_id: u64,
        request_id: u64,
        epoch: u32,
        gamma: f32,
        xbars: Vec<Vec<f32>>,
    },
    /// Worker -> leader (v3/v5): updated estimates for every column.
    UpdateBatchDone {
        worker_id: u32,
        session_id: u64,
        request_id: u64,
        xs: Vec<Vec<f32>>,
    },
    /// Leader -> worker (v3/v5): one batched DGD gradient round.
    RunGradBatch {
        session_id: u64,
        request_id: u64,
        epoch: u32,
        xs: Vec<Vec<f32>>,
    },
    /// Worker -> leader (v3/v5): per-column local gradients.
    GradBatchDone {
        worker_id: u32,
        session_id: u64,
        request_id: u64,
        grads: Vec<Vec<f32>>,
    },
    /// Leader -> worker (v4): ship back a snapshot of your metrics
    /// registry.  Read-only; never perturbs a solve.
    StatsRequest,
    /// Worker -> leader (v4): flattened `(name, value)` metrics
    /// snapshot (counters/gauges verbatim, histograms exploded into
    /// `.count`/`.sum`/quantile entries by
    /// `obs::MetricsRegistry::snapshot_flat`).
    StatsReport { worker_id: u32, stats: Vec<(String, f64)> },
    /// Leader -> worker (v5): drop the resident factorization of
    /// `session_id` (LRU eviction under the resident-memory cap).  The
    /// session can be re-registered later; eviction only reclaims the
    /// worker-side bytes.
    EvictSession { session_id: u64 },
    /// Worker -> leader (v5): eviction ack — the named session's state
    /// is gone (acked even if the id was already absent, so eviction is
    /// idempotent).
    SessionEvicted { worker_id: u32, session_id: u64 },
    /// Client -> server (v5): solve k full right-hand sides (whole
    /// vectors, not partition slices) against registered `session_id`.
    SubmitSolve { session_id: u64, request_id: u64, bs: Vec<Vec<f32>> },
    /// Server -> client (v5): per-column solutions and residual norms
    /// for a completed [`Message::SubmitSolve`].
    SolveResult {
        session_id: u64,
        request_id: u64,
        xbars: Vec<Vec<f32>>,
        residuals: Vec<f32>,
    },
    /// Server -> client (v5): the bounded request queue is full —
    /// explicit backpressure; resubmit after a completed reply returns
    /// a credit.  `queue_depth` reports the configured bound.
    Busy { request_id: u64, queue_depth: u32 },
    /// Server -> client (v5): the named session is not registered on
    /// this server (never registered, or unregistered/closed).
    Evicted { session_id: u64, request_id: u64 },
    /// Server -> client (v5): flow-control admission grant — the client
    /// may keep `credits` requests in flight (quill-style CREDIT).
    Credit { credits: u32 },
}

/// Human label for each frame type, indexed by [`Message::kind_index`]
/// — the per-kind wire accounting metric names
/// (`wire.tx_frames.{label}` etc.) are built from these.
pub const KIND_LABELS: [&str; 26] = [
    "init_partition",
    "init_done",
    "run_update",
    "update_done",
    "run_grad",
    "grad_done",
    "worker_error",
    "shutdown",
    "register_matrix",
    "matrix_registered",
    "solve_rhs",
    "solve_batch",
    "rhs_seeded",
    "run_update_batch",
    "update_batch_done",
    "run_grad_batch",
    "grad_batch_done",
    "stats_request",
    "stats_report",
    "evict_session",
    "session_evicted",
    "submit_solve",
    "solve_result",
    "busy",
    "evicted",
    "credit",
];

/// InitKind twin that is wire-encodable, plus the gradient-only mode that
/// has no engine-side factorization at all.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InitKindWire {
    Qr = 0,
    Classical = 1,
    Fat = 2,
    /// Store the block for DGD gradients only: no QR, no Gram inverse,
    /// no projector — worker init is O(nnz) instead of O(l n^2).
    GradOnly = 3,
}

impl InitKindWire {
    /// The engine-side factorization this wire kind requests, or `None`
    /// for [`Self::GradOnly`] (the worker stores the block and returns).
    pub fn engine_kind(self) -> Option<InitKind> {
        match self {
            Self::Qr => Some(InitKind::Qr),
            Self::Classical => Some(InitKind::Classical),
            Self::Fat => Some(InitKind::Fat),
            Self::GradOnly => None,
        }
    }
}

impl From<InitKind> for InitKindWire {
    fn from(k: InitKind) -> Self {
        match k {
            InitKind::Qr => Self::Qr,
            InitKind::Classical => Self::Classical,
            InitKind::Fat => Self::Fat,
        }
    }
}

// --- encoding ---------------------------------------------------------------

struct Enc<'a> {
    buf: &'a mut Vec<u8>,
}

impl<'a> Enc<'a> {
    fn new(buf: &'a mut Vec<u8>, tag: u8) -> Self {
        buf.push(tag);
        Self { buf }
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn vec_f32(&mut self, v: &[f32]) {
        self.buf.extend_from_slice(&(v.len() as u64).to_le_bytes());
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// `u64 count | vec<f32> * count` — the v3 batched-column encoding.
    fn vec2_f32(&mut self, vs: &[Vec<f32>]) {
        self.buf.extend_from_slice(&(vs.len() as u64).to_le_bytes());
        for v in vs {
            self.vec_f32(v);
        }
    }

    fn matrix(&mut self, m: &Matrix) {
        self.buf.extend_from_slice(&(m.rows() as u64).to_le_bytes());
        self.buf.extend_from_slice(&(m.cols() as u64).to_le_bytes());
        for x in m.as_slice() {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn string(&mut self, s: &str) {
        self.buf.extend_from_slice(&(s.len() as u64).to_le_bytes());
        self.buf.extend_from_slice(s.as_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `u64 count | (string | f64) * count` — the v4 telemetry encoding.
    fn stats(&mut self, stats: &[(String, f64)]) {
        self.buf.extend_from_slice(&(stats.len() as u64).to_le_bytes());
        for (name, v) in stats {
            self.string(name);
            self.f64(*v);
        }
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(DapcError::Parse("truncated message".into()));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Bytes left in the payload — the upper bound every decoded length
    /// field must respect BEFORE any size arithmetic, so hostile lengths
    /// can neither overflow a multiplication nor over-allocate.
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn vec_f32(&mut self) -> Result<Vec<f32>> {
        let len = self.u64()? as usize;
        if len > self.remaining() / 4 {
            return Err(DapcError::Parse(format!(
                "vector length {len} exceeds remaining payload"
            )));
        }
        let bytes = self.take(len * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn vec2_f32(&mut self) -> Result<Vec<Vec<f32>>> {
        let count = self.u64()? as usize;
        // every counted column needs at least its u64 length prefix
        if count > self.remaining() / 8 {
            return Err(DapcError::Parse(format!(
                "batch count {count} exceeds remaining payload"
            )));
        }
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            out.push(self.vec_f32()?);
        }
        Ok(out)
    }

    fn matrix(&mut self) -> Result<Matrix> {
        let rows = self.u64()? as usize;
        let cols = self.u64()? as usize;
        let max_elems = self.remaining() / 4;
        let elems = match rows.checked_mul(cols) {
            Some(e) if e <= max_elems => e,
            _ => {
                return Err(DapcError::Parse(format!(
                    "matrix shape {rows}x{cols} exceeds remaining payload"
                )))
            }
        };
        let bytes = self.take(elems * 4)?;
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Matrix::from_vec(rows, cols, data))
    }

    fn string(&mut self) -> Result<String> {
        let len = self.u64()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| DapcError::Parse("invalid utf8 in message".into()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn stats(&mut self) -> Result<Vec<(String, f64)>> {
        let count = self.u64()? as usize;
        // every counted entry needs at least its u64 name-length prefix
        // plus the f64 value
        if count > self.remaining() / 16 {
            return Err(DapcError::Parse(format!(
                "stats count {count} exceeds remaining payload"
            )));
        }
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            let name = self.string()?;
            let v = self.f64()?;
            out.push((name, v));
        }
        Ok(out)
    }

    fn finish(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(DapcError::Parse("trailing bytes in message".into()));
        }
        Ok(())
    }
}

const VEC_HEADER: usize = 8; // u64 length prefix
const MAT_HEADER: usize = 16; // u64 rows + u64 cols
/// `session_id` + `request_id`, carried by every v5 session frame.
const SESSION_IDS: usize = 16;

/// Encoded size of a `vec2_f32` column batch.
fn vec2_len(vs: &[Vec<f32>]) -> usize {
    VEC_HEADER
        + vs.iter().map(|v| VEC_HEADER + 4 * v.len()).sum::<usize>()
}

impl Message {
    /// Append the tagged payload (no frame header) to `buf` — the
    /// transports' reused-send-buffer path.
    pub fn encode_into(&self, buf: &mut Vec<u8>) {
        match self {
            Message::InitPartition { worker_id, kind, a, b, n_target } => {
                let mut e = Enc::new(buf, 0);
                e.u32(*worker_id);
                e.buf.push(*kind as u8);
                e.matrix(a);
                e.vec_f32(b);
                e.u32(*n_target);
            }
            Message::InitDone { worker_id, x0 } => {
                let mut e = Enc::new(buf, 1);
                e.u32(*worker_id);
                e.vec_f32(x0);
            }
            Message::RunUpdate { epoch, gamma, xbar } => {
                let mut e = Enc::new(buf, 2);
                e.u32(*epoch);
                e.f32(*gamma);
                e.vec_f32(xbar);
            }
            Message::UpdateDone { worker_id, x } => {
                let mut e = Enc::new(buf, 3);
                e.u32(*worker_id);
                e.vec_f32(x);
            }
            Message::RunGrad { epoch, x } => {
                let mut e = Enc::new(buf, 4);
                e.u32(*epoch);
                e.vec_f32(x);
            }
            Message::GradDone { worker_id, grad } => {
                let mut e = Enc::new(buf, 5);
                e.u32(*worker_id);
                e.vec_f32(grad);
            }
            Message::WorkerError { worker_id, message } => {
                let mut e = Enc::new(buf, 6);
                e.u32(*worker_id);
                e.string(message);
            }
            Message::Shutdown => buf.push(7),
            Message::RegisterMatrix {
                worker_id,
                session_id,
                request_id,
                kind,
                a,
                n_target,
            } => {
                let mut e = Enc::new(buf, 8);
                e.u32(*worker_id);
                e.u64(*session_id);
                e.u64(*request_id);
                e.buf.push(*kind as u8);
                e.matrix(a);
                e.u32(*n_target);
            }
            Message::MatrixRegistered { worker_id, session_id, request_id } => {
                let mut e = Enc::new(buf, 9);
                e.u32(*worker_id);
                e.u64(*session_id);
                e.u64(*request_id);
            }
            Message::SolveRhs { session_id, request_id, b } => {
                let mut e = Enc::new(buf, 10);
                e.u64(*session_id);
                e.u64(*request_id);
                e.vec_f32(b);
            }
            Message::SolveBatch { session_id, request_id, bs } => {
                let mut e = Enc::new(buf, 11);
                e.u64(*session_id);
                e.u64(*request_id);
                e.vec2_f32(bs);
            }
            Message::RhsSeeded { worker_id, session_id, request_id, x0s } => {
                let mut e = Enc::new(buf, 12);
                e.u32(*worker_id);
                e.u64(*session_id);
                e.u64(*request_id);
                e.vec2_f32(x0s);
            }
            Message::RunUpdateBatch {
                session_id,
                request_id,
                epoch,
                gamma,
                xbars,
            } => {
                let mut e = Enc::new(buf, 13);
                e.u64(*session_id);
                e.u64(*request_id);
                e.u32(*epoch);
                e.f32(*gamma);
                e.vec2_f32(xbars);
            }
            Message::UpdateBatchDone {
                worker_id,
                session_id,
                request_id,
                xs,
            } => {
                let mut e = Enc::new(buf, 14);
                e.u32(*worker_id);
                e.u64(*session_id);
                e.u64(*request_id);
                e.vec2_f32(xs);
            }
            Message::RunGradBatch { session_id, request_id, epoch, xs } => {
                let mut e = Enc::new(buf, 15);
                e.u64(*session_id);
                e.u64(*request_id);
                e.u32(*epoch);
                e.vec2_f32(xs);
            }
            Message::GradBatchDone {
                worker_id,
                session_id,
                request_id,
                grads,
            } => {
                let mut e = Enc::new(buf, 16);
                e.u32(*worker_id);
                e.u64(*session_id);
                e.u64(*request_id);
                e.vec2_f32(grads);
            }
            Message::StatsRequest => buf.push(17),
            Message::StatsReport { worker_id, stats } => {
                let mut e = Enc::new(buf, 18);
                e.u32(*worker_id);
                e.stats(stats);
            }
            Message::EvictSession { session_id } => {
                let mut e = Enc::new(buf, 19);
                e.u64(*session_id);
            }
            Message::SessionEvicted { worker_id, session_id } => {
                let mut e = Enc::new(buf, 20);
                e.u32(*worker_id);
                e.u64(*session_id);
            }
            Message::SubmitSolve { session_id, request_id, bs } => {
                let mut e = Enc::new(buf, 21);
                e.u64(*session_id);
                e.u64(*request_id);
                e.vec2_f32(bs);
            }
            Message::SolveResult {
                session_id,
                request_id,
                xbars,
                residuals,
            } => {
                let mut e = Enc::new(buf, 22);
                e.u64(*session_id);
                e.u64(*request_id);
                e.vec2_f32(xbars);
                e.vec_f32(residuals);
            }
            Message::Busy { request_id, queue_depth } => {
                let mut e = Enc::new(buf, 23);
                e.u64(*request_id);
                e.u32(*queue_depth);
            }
            Message::Evicted { session_id, request_id } => {
                let mut e = Enc::new(buf, 24);
                e.u64(*session_id);
                e.u64(*request_id);
            }
            Message::Credit { credits } => {
                let mut e = Enc::new(buf, 25);
                e.u32(*credits);
            }
        }
    }

    /// Dense index of this frame's type (identical to its wire tag);
    /// indexes [`KIND_LABELS`] for per-kind frame/byte accounting.
    pub fn kind_index(&self) -> usize {
        match self {
            Message::InitPartition { .. } => 0,
            Message::InitDone { .. } => 1,
            Message::RunUpdate { .. } => 2,
            Message::UpdateDone { .. } => 3,
            Message::RunGrad { .. } => 4,
            Message::GradDone { .. } => 5,
            Message::WorkerError { .. } => 6,
            Message::Shutdown => 7,
            Message::RegisterMatrix { .. } => 8,
            Message::MatrixRegistered { .. } => 9,
            Message::SolveRhs { .. } => 10,
            Message::SolveBatch { .. } => 11,
            Message::RhsSeeded { .. } => 12,
            Message::RunUpdateBatch { .. } => 13,
            Message::UpdateBatchDone { .. } => 14,
            Message::RunGradBatch { .. } => 15,
            Message::GradBatchDone { .. } => 16,
            Message::StatsRequest => 17,
            Message::StatsReport { .. } => 18,
            Message::EvictSession { .. } => 19,
            Message::SessionEvicted { .. } => 20,
            Message::SubmitSolve { .. } => 21,
            Message::SolveResult { .. } => 22,
            Message::Busy { .. } => 23,
            Message::Evicted { .. } => 24,
            Message::Credit { .. } => 25,
        }
    }

    /// Accounting label for this frame's type.
    pub fn kind_label(&self) -> &'static str {
        KIND_LABELS[self.kind_index()]
    }

    /// Encode to a fresh tagged payload (no frame header).
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(self.encoded_len());
        self.encode_into(&mut buf);
        buf
    }

    /// Exact payload size [`Self::encode`] produces, without encoding —
    /// used for wire-byte accounting on in-process transports.
    pub fn encoded_len(&self) -> usize {
        match self {
            Message::InitPartition { a, b, .. } => {
                1 + 4
                    + 1
                    + MAT_HEADER
                    + 4 * a.rows() * a.cols()
                    + VEC_HEADER
                    + 4 * b.len()
                    + 4
            }
            Message::InitDone { x0, .. } => 1 + 4 + VEC_HEADER + 4 * x0.len(),
            Message::RunUpdate { xbar, .. } => {
                1 + 4 + 4 + VEC_HEADER + 4 * xbar.len()
            }
            Message::UpdateDone { x, .. } => 1 + 4 + VEC_HEADER + 4 * x.len(),
            Message::RunGrad { x, .. } => 1 + 4 + VEC_HEADER + 4 * x.len(),
            Message::GradDone { grad, .. } => {
                1 + 4 + VEC_HEADER + 4 * grad.len()
            }
            Message::WorkerError { message, .. } => {
                1 + 4 + VEC_HEADER + message.len()
            }
            Message::Shutdown => 1,
            Message::RegisterMatrix { a, .. } => {
                1 + 4
                    + SESSION_IDS
                    + 1
                    + MAT_HEADER
                    + 4 * a.rows() * a.cols()
                    + 4
            }
            Message::MatrixRegistered { .. } => 1 + 4 + SESSION_IDS,
            Message::SolveRhs { b, .. } => {
                1 + SESSION_IDS + VEC_HEADER + 4 * b.len()
            }
            Message::SolveBatch { bs, .. } => 1 + SESSION_IDS + vec2_len(bs),
            Message::RhsSeeded { x0s, .. } => {
                1 + 4 + SESSION_IDS + vec2_len(x0s)
            }
            Message::RunUpdateBatch { xbars, .. } => {
                1 + SESSION_IDS + 4 + 4 + vec2_len(xbars)
            }
            Message::UpdateBatchDone { xs, .. } => {
                1 + 4 + SESSION_IDS + vec2_len(xs)
            }
            Message::RunGradBatch { xs, .. } => {
                1 + SESSION_IDS + 4 + vec2_len(xs)
            }
            Message::GradBatchDone { grads, .. } => {
                1 + 4 + SESSION_IDS + vec2_len(grads)
            }
            Message::StatsRequest => 1,
            Message::StatsReport { stats, .. } => {
                1 + 4
                    + VEC_HEADER
                    + stats
                        .iter()
                        .map(|(name, _)| VEC_HEADER + name.len() + 8)
                        .sum::<usize>()
            }
            Message::EvictSession { .. } => 1 + 8,
            Message::SessionEvicted { .. } => 1 + 4 + 8,
            Message::SubmitSolve { bs, .. } => 1 + SESSION_IDS + vec2_len(bs),
            Message::SolveResult { xbars, residuals, .. } => {
                1 + SESSION_IDS
                    + vec2_len(xbars)
                    + VEC_HEADER
                    + 4 * residuals.len()
            }
            Message::Busy { .. } => 1 + 8 + 4,
            Message::Evicted { .. } => 1 + SESSION_IDS,
            Message::Credit { .. } => 1 + 4,
        }
    }

    /// Decode from a tagged payload.
    pub fn decode(buf: &[u8]) -> Result<Message> {
        let mut d = Dec { buf, pos: 0 };
        let tag = d.u8()?;
        let msg = match tag {
            0 => {
                let worker_id = d.u32()?;
                let kind = decode_kind(d.u8()?)?;
                let a = d.matrix()?;
                let b = d.vec_f32()?;
                let n_target = d.u32()?;
                Message::InitPartition { worker_id, kind, a, b, n_target }
            }
            1 => Message::InitDone { worker_id: d.u32()?, x0: d.vec_f32()? },
            2 => Message::RunUpdate {
                epoch: d.u32()?,
                gamma: d.f32()?,
                xbar: d.vec_f32()?,
            },
            3 => Message::UpdateDone { worker_id: d.u32()?, x: d.vec_f32()? },
            4 => Message::RunGrad { epoch: d.u32()?, x: d.vec_f32()? },
            5 => Message::GradDone { worker_id: d.u32()?, grad: d.vec_f32()? },
            6 => Message::WorkerError {
                worker_id: d.u32()?,
                message: d.string()?,
            },
            7 => Message::Shutdown,
            8 => {
                let worker_id = d.u32()?;
                let session_id = d.u64()?;
                let request_id = d.u64()?;
                let kind = decode_kind(d.u8()?)?;
                let a = d.matrix()?;
                let n_target = d.u32()?;
                Message::RegisterMatrix {
                    worker_id,
                    session_id,
                    request_id,
                    kind,
                    a,
                    n_target,
                }
            }
            9 => Message::MatrixRegistered {
                worker_id: d.u32()?,
                session_id: d.u64()?,
                request_id: d.u64()?,
            },
            10 => Message::SolveRhs {
                session_id: d.u64()?,
                request_id: d.u64()?,
                b: d.vec_f32()?,
            },
            11 => Message::SolveBatch {
                session_id: d.u64()?,
                request_id: d.u64()?,
                bs: d.vec2_f32()?,
            },
            12 => Message::RhsSeeded {
                worker_id: d.u32()?,
                session_id: d.u64()?,
                request_id: d.u64()?,
                x0s: d.vec2_f32()?,
            },
            13 => Message::RunUpdateBatch {
                session_id: d.u64()?,
                request_id: d.u64()?,
                epoch: d.u32()?,
                gamma: d.f32()?,
                xbars: d.vec2_f32()?,
            },
            14 => Message::UpdateBatchDone {
                worker_id: d.u32()?,
                session_id: d.u64()?,
                request_id: d.u64()?,
                xs: d.vec2_f32()?,
            },
            15 => Message::RunGradBatch {
                session_id: d.u64()?,
                request_id: d.u64()?,
                epoch: d.u32()?,
                xs: d.vec2_f32()?,
            },
            16 => Message::GradBatchDone {
                worker_id: d.u32()?,
                session_id: d.u64()?,
                request_id: d.u64()?,
                grads: d.vec2_f32()?,
            },
            17 => Message::StatsRequest,
            18 => Message::StatsReport {
                worker_id: d.u32()?,
                stats: d.stats()?,
            },
            19 => Message::EvictSession { session_id: d.u64()? },
            20 => Message::SessionEvicted {
                worker_id: d.u32()?,
                session_id: d.u64()?,
            },
            21 => Message::SubmitSolve {
                session_id: d.u64()?,
                request_id: d.u64()?,
                bs: d.vec2_f32()?,
            },
            22 => Message::SolveResult {
                session_id: d.u64()?,
                request_id: d.u64()?,
                xbars: d.vec2_f32()?,
                residuals: d.vec_f32()?,
            },
            23 => Message::Busy {
                request_id: d.u64()?,
                queue_depth: d.u32()?,
            },
            24 => Message::Evicted {
                session_id: d.u64()?,
                request_id: d.u64()?,
            },
            25 => Message::Credit { credits: d.u32()? },
            other => {
                return Err(DapcError::Parse(format!("unknown tag {other}")))
            }
        };
        d.finish()?;
        Ok(msg)
    }
}

fn decode_kind(byte: u8) -> Result<InitKindWire> {
    match byte {
        0 => Ok(InitKindWire::Qr),
        1 => Ok(InitKindWire::Classical),
        2 => Ok(InitKindWire::Fat),
        3 => Ok(InitKindWire::GradOnly),
        k => Err(DapcError::Parse(format!("bad init kind {k}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn variants() -> Vec<Message> {
        vec![
            Message::InitPartition {
                worker_id: 3,
                kind: InitKindWire::Qr,
                a: Matrix::from_fn(4, 3, |i, j| (i * 3 + j) as f32 * 0.5),
                b: vec![1.0, -2.0, 3.0, 0.25],
                n_target: 3,
            },
            Message::InitPartition {
                worker_id: 1,
                kind: InitKindWire::GradOnly,
                a: Matrix::from_fn(2, 2, |i, j| (i + j) as f32),
                b: vec![1.0, 2.0],
                n_target: 2,
            },
            Message::InitDone { worker_id: 1, x0: vec![0.1, 0.2] },
            Message::RunUpdate { epoch: 9, gamma: 0.75, xbar: vec![5.0; 7] },
            Message::UpdateDone { worker_id: 0, x: vec![] },
            Message::RunGrad { epoch: 2, x: vec![1.0] },
            Message::GradDone { worker_id: 4, grad: vec![-1.5, 2.5] },
            Message::WorkerError {
                worker_id: 2,
                message: "qr failed: naïve".into(),
            },
            Message::Shutdown,
            Message::RegisterMatrix {
                worker_id: 7,
                session_id: 11,
                request_id: 900,
                kind: InitKindWire::Qr,
                a: Matrix::from_fn(3, 2, |i, j| (i + 2 * j) as f32),
                n_target: 2,
            },
            Message::MatrixRegistered {
                worker_id: 7,
                session_id: 11,
                request_id: 900,
            },
            Message::SolveRhs {
                session_id: 11,
                request_id: 901,
                b: vec![0.5, -1.5, 2.0],
            },
            Message::SolveBatch {
                session_id: u64::MAX,
                request_id: 902,
                bs: vec![vec![1.0, 2.0], vec![], vec![3.0]],
            },
            Message::RhsSeeded {
                worker_id: 1,
                session_id: 11,
                request_id: 901,
                x0s: vec![vec![0.25, 0.5], vec![]],
            },
            Message::RunUpdateBatch {
                session_id: 11,
                request_id: 902,
                epoch: 4,
                gamma: 0.9,
                xbars: vec![vec![1.0; 3], vec![2.0; 3]],
            },
            Message::UpdateBatchDone {
                worker_id: 3,
                session_id: 11,
                request_id: 902,
                xs: vec![vec![0.0; 3], vec![-1.0; 3]],
            },
            Message::RunGradBatch {
                session_id: 12,
                request_id: 903,
                epoch: 6,
                xs: vec![vec![1.0, 2.0]],
            },
            Message::GradBatchDone {
                worker_id: 0,
                session_id: 12,
                request_id: 903,
                grads: vec![vec![-0.5, 0.5]],
            },
            Message::StatsRequest,
            Message::StatsReport {
                worker_id: 5,
                stats: vec![
                    ("worker.update_ns.count".into(), 128.0),
                    ("worker.update_ns.p99".into(), 4095.0),
                    ("".into(), -1.5),
                ],
            },
            Message::StatsReport { worker_id: 0, stats: vec![] },
            Message::EvictSession { session_id: 11 },
            Message::SessionEvicted { worker_id: 2, session_id: 11 },
            Message::SubmitSolve {
                session_id: 11,
                request_id: 7_000_000_000,
                bs: vec![vec![1.0, 2.0, 3.0], vec![-1.0, 0.0, 1.0]],
            },
            Message::SolveResult {
                session_id: 11,
                request_id: 7_000_000_000,
                xbars: vec![vec![0.5, 0.25], vec![]],
                residuals: vec![1e-6, 0.0],
            },
            Message::Busy { request_id: 904, queue_depth: 32 },
            Message::Evicted { session_id: 13, request_id: 905 },
            Message::Credit { credits: 8 },
        ]
    }

    #[test]
    fn all_variants_roundtrip() {
        for m in variants() {
            let enc = m.encode();
            let dec = Message::decode(&enc).unwrap();
            assert_eq!(m, dec);
        }
    }

    #[test]
    fn encoded_len_is_exact() {
        for m in variants() {
            assert_eq!(m.encoded_len(), m.encode().len(), "{m:?}");
        }
    }

    #[test]
    fn encode_into_appends() {
        let m = Message::RunGrad { epoch: 2, x: vec![1.0] };
        let mut buf = vec![0xAA, 0xBB];
        m.encode_into(&mut buf);
        assert_eq!(&buf[..2], &[0xAA, 0xBB]);
        assert_eq!(Message::decode(&buf[2..]).unwrap(), m);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Message::decode(&[]).is_err());
        assert!(Message::decode(&[99]).is_err());
        // truncated InitDone
        let mut enc = Message::InitDone { worker_id: 1, x0: vec![1.0, 2.0] }.encode();
        enc.truncate(enc.len() - 2);
        assert!(Message::decode(&enc).is_err());
        // trailing bytes
        let mut enc2 = Message::Shutdown.encode();
        enc2.push(0);
        assert!(Message::decode(&enc2).is_err());
        // bad init kind
        let mut enc3 = Message::InitPartition {
            worker_id: 0,
            kind: InitKindWire::Qr,
            a: Matrix::zeros(1, 1),
            b: vec![0.0],
            n_target: 1,
        }
        .encode();
        enc3[5] = 9; // kind byte
        assert!(Message::decode(&enc3).is_err());
    }

    #[test]
    fn hostile_batch_count_rejected() {
        // a SolveBatch whose count claims more columns than the payload
        // could hold must fail cleanly, not over-allocate
        let mut enc = Message::SolveBatch {
            session_id: 1,
            request_id: 2,
            bs: vec![vec![1.0]],
        }
        .encode();
        // the u64 count sits after tag (1) + session_id (8) + request_id (8)
        enc[17..25].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(Message::decode(&enc).is_err());

        // hostile inner vector length: must error, not wrap the
        // length * 4 multiplication into a tiny read
        let mut enc = Message::SolveRhs {
            session_id: 1,
            request_id: 2,
            b: vec![1.0, 2.0],
        }
        .encode();
        enc[17..25].copy_from_slice(&(u64::MAX / 2).to_le_bytes());
        assert!(Message::decode(&enc).is_err());

        // hostile matrix dims (rows * cols overflows usize)
        let mut enc = Message::RegisterMatrix {
            worker_id: 0,
            session_id: 1,
            request_id: 2,
            kind: InitKindWire::Qr,
            a: Matrix::zeros(1, 1),
            n_target: 1,
        }
        .encode();
        // rows u64 sits after tag (1) + worker_id (4) + session_id (8)
        // + request_id (8) + kind (1)
        enc[22..30].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(Message::decode(&enc).is_err());

        // hostile stats count: claims more entries than the payload
        // could hold — must fail cleanly, not over-allocate
        let mut enc = Message::StatsReport {
            worker_id: 0,
            stats: vec![("a".into(), 1.0)],
        }
        .encode();
        // count u64 sits after tag (1) + worker_id (4)
        enc[5..13].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(Message::decode(&enc).is_err());

        // hostile SubmitSolve column count (the service ingress frame)
        let mut enc = Message::SubmitSolve {
            session_id: 1,
            request_id: 2,
            bs: vec![vec![1.0]],
        }
        .encode();
        enc[17..25].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(Message::decode(&enc).is_err());
    }

    #[test]
    fn kind_index_matches_wire_tag_and_labels() {
        assert_eq!(KIND_LABELS.len(), 26);
        for m in variants() {
            let idx = m.kind_index();
            assert_eq!(m.encode()[0] as usize, idx, "{m:?}");
            assert_eq!(m.kind_label(), KIND_LABELS[idx]);
        }
        assert_eq!(Message::StatsRequest.kind_label(), "stats_request");
        assert_eq!(
            Message::Credit { credits: 1 }.kind_label(),
            "credit"
        );
    }

    #[test]
    fn session_ids_roundtrip_at_u64_extremes() {
        // session/request ids are opaque u64s: the full range must
        // survive the wire, including the sentinel-looking extremes
        for (sid, rid) in [(0u64, 0u64), (u64::MAX, u64::MAX), (1, u64::MAX)] {
            let m = Message::SolveRhs {
                session_id: sid,
                request_id: rid,
                b: vec![1.0],
            };
            assert_eq!(Message::decode(&m.encode()).unwrap(), m);
        }
    }

    #[test]
    fn init_kind_conversion() {
        for k in [InitKind::Qr, InitKind::Classical, InitKind::Fat] {
            let w: InitKindWire = k.into();
            assert_eq!(w.engine_kind(), Some(k));
        }
        assert_eq!(InitKindWire::GradOnly.engine_kind(), None);
    }
}
