// Seeded violations: a typed float sum and a float-seeded fold outside
// linalg/ — both reduce in iterator order instead of the fixed 8-lane
// tree.
pub fn total(xs: &[f32]) -> f32 {
    xs.iter().sum::<f32>()
}

pub fn total64(xs: &[f64]) -> f64 {
    xs.iter().fold(0.0f64, |acc, &v| acc + v)
}

pub fn count(xs: &[f64]) -> usize {
    // integer-seeded fold: deliberately NOT a violation
    xs.iter().fold(0usize, |acc, _| acc + 1)
}
