import os
import sys

# Make `compile` importable when pytest is launched from the repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(1234)
