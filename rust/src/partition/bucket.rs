//! Shape bucketing: pad a partition block up to the nearest AOT artifact
//! shape.  Row padding appends zero rows (QR of `[A; 0]` has the same `R`
//! and `Q1^T [b; 0]`); column padding extends block-diagonally with an
//! identity whose solution entries stay exactly zero through every
//! consensus epoch — both exact, see DESIGN.md §3 and the proofs in the
//! tests below.

use crate::error::{DapcError, Result};
use crate::linalg::Matrix;

/// A block padded to an artifact bucket shape.
#[derive(Debug, Clone)]
pub struct BucketedBlock {
    /// Padded (l_pad x n_pad) dense block.
    pub a: Matrix,
    /// Padded rhs, length l_pad.
    pub b: Vec<f32>,
    /// Original (unpadded) rows.
    pub rows: usize,
    /// Original (unpadded) columns = true solution length.
    pub n: usize,
}

impl BucketedBlock {
    /// Strip the padding from a padded solution vector.
    pub fn unpad_solution(&self, x: &[f32]) -> Vec<f32> {
        x[..self.n].to_vec()
    }
}

/// Pad `(a, b)` up to `(l_pad, n_pad)`.
///
/// * extra rows: zeros (and zero rhs entries);
/// * extra columns: block-diagonal identity rows so the padded system is
///   still full rank with padded-solution entries exactly 0.
pub fn pad_to_bucket(
    a: &Matrix,
    b: &[f32],
    l_pad: usize,
    n_pad: usize,
) -> Result<BucketedBlock> {
    let (rows, n) = a.shape();
    if b.len() != rows {
        return Err(DapcError::Shape(format!(
            "rhs length {} != rows {}",
            b.len(),
            rows
        )));
    }
    if n_pad < n || l_pad < rows + (n_pad - n) {
        return Err(DapcError::Shape(format!(
            "bucket ({l_pad}, {n_pad}) too small for block ({rows}, {n}); \
             need l_pad >= rows + (n_pad - n)"
        )));
    }
    let k = n_pad - n;
    // block-diagonal identity extension, then zero rows up to l_pad
    let ext = a.pad_block_identity(k);
    let padded = ext.pad_rows(l_pad);
    let mut rhs = b.to_vec();
    rhs.resize(l_pad, 0.0); // identity rows get b = 0 => x_pad = 0
    Ok(BucketedBlock { a: padded, b: rhs, rows, n })
}

/// Choose the smallest bucket from `available` (sorted or not) that fits
/// `(rows, n)`; returns `(l_pad, n_pad)`.
///
/// "Smallest" is the least padded *area* `l_pad * n_pad` — the size of
/// the dense padded block, which governs its memory, packing and
/// transfer cost and is the first-order proxy for the init work (exact
/// QR flops are `area * n_pad`, so area slightly under-weights width;
/// the bucket sets we ship are coarse enough that the orderings agree).
/// The previous lexicographic `(n_pad, l_pad)` order could pick a
/// narrow, very tall tower over a slightly wider bucket with far fewer
/// padded rows, multiplying the padded QR work.  Ties break on
/// `(n_pad, l_pad)` so equal-area choices stay deterministic.
pub fn choose_bucket(
    rows: usize,
    n: usize,
    available: &[(usize, usize)],
) -> Option<(usize, usize)> {
    available
        .iter()
        .copied()
        .filter(|&(l_pad, n_pad)| {
            n_pad >= n && l_pad >= rows + (n_pad - n)
        })
        .min_by_key(|&(l_pad, n_pad)| (l_pad * n_pad, n_pad, l_pad))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::{householder_qr, qt_mul};
    use crate::linalg::triangular::back_substitute;
    use crate::rng::seeded;

    fn randm(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut g = seeded(seed);
        Matrix::from_fn(rows, cols, |_, _| g.normal_f32())
    }

    #[test]
    fn row_padding_preserves_qr_solution() {
        let a = randm(20, 8, 1);
        let mut g = seeded(2);
        let x_true: Vec<f32> = (0..8).map(|_| g.normal_f32()).collect();
        let mut b = vec![0.0f32; 20];
        crate::linalg::blas::gemv(&a, &x_true, &mut b);

        let blk = pad_to_bucket(&a, &b, 32, 8).unwrap();
        let f = householder_qr(&blk.a);
        let x = back_substitute(&f.r, &qt_mul(&f, &blk.b));
        let x = blk.unpad_solution(&x);
        for i in 0..8 {
            assert!((x[i] - x_true[i]).abs() < 1e-3, "i={i}");
        }
    }

    #[test]
    fn column_padding_preserves_solution_with_zero_tail() {
        let a = randm(24, 6, 3);
        let mut g = seeded(4);
        let x_true: Vec<f32> = (0..6).map(|_| g.normal_f32()).collect();
        let mut b = vec![0.0f32; 24];
        crate::linalg::blas::gemv(&a, &x_true, &mut b);

        // pad 6 -> 10 columns, 24 -> 40 rows
        let blk = pad_to_bucket(&a, &b, 40, 10).unwrap();
        assert_eq!(blk.a.shape(), (40, 10));
        let f = householder_qr(&blk.a);
        let x = back_substitute(&f.r, &qt_mul(&f, &blk.b));
        // padded entries must be exactly ~0
        for i in 6..10 {
            assert!(x[i].abs() < 1e-5, "pad entry {i} = {}", x[i]);
        }
        let x = blk.unpad_solution(&x);
        for i in 0..6 {
            assert!((x[i] - x_true[i]).abs() < 1e-3, "i={i}");
        }
    }

    #[test]
    fn bucket_too_small_rejected() {
        let a = randm(10, 4, 5);
        let b = vec![0.0; 10];
        assert!(pad_to_bucket(&a, &b, 9, 4).is_err()); // fewer rows
        assert!(pad_to_bucket(&a, &b, 10, 3).is_err()); // fewer cols
        // needs l_pad >= rows + (n_pad - n): 10 + 2 = 12 > 11
        assert!(pad_to_bucket(&a, &b, 11, 6).is_err());
        assert!(pad_to_bucket(&a, &b, 12, 6).is_ok());
    }

    #[test]
    fn rhs_length_checked() {
        let a = randm(10, 4, 6);
        assert!(pad_to_bucket(&a, &[0.0; 9], 12, 4).is_err());
    }

    #[test]
    fn choose_bucket_smallest_fit() {
        let avail = [(64, 32), (256, 128), (768, 512)];
        assert_eq!(choose_bucket(50, 20, &avail), Some((64, 32)));
        // 60 rows, n=32: 60 + 0 = 60 <= 64 ✓
        assert_eq!(choose_bucket(60, 32, &avail), Some((64, 32)));
        // 63 rows, n=20: 63 + 12 = 75 > 64 -> next bucket
        assert_eq!(choose_bucket(63, 20, &avail), Some((256, 128)));
        assert_eq!(choose_bucket(1000, 20, &avail), None);
    }

    #[test]
    fn choose_bucket_prefers_smaller_padded_area_over_narrower_width() {
        // both buckets fit a 20x16 block.  The old lexicographic
        // (n_pad, l_pad) order picked the narrow 4096x32 tower (area
        // 131072 — 16x the padded QR work) purely because it is
        // narrower; area selection takes 128x64 (area 8192).
        let avail = [(4096, 32), (128, 64)];
        assert_eq!(choose_bucket(20, 16, &avail), Some((128, 64)));
        // when the narrower bucket is ALSO the smaller area it still wins
        assert_eq!(
            choose_bucket(20, 16, &[(64, 32), (128, 64)]),
            Some((64, 32))
        );
        // equal areas: deterministic (n_pad, l_pad) tie-break
        assert_eq!(
            choose_bucket(20, 16, &[(128, 64), (256, 32)]),
            Some((256, 32))
        );
    }
}
