//! Multi-tenant solve server: many client connections multiplexed onto
//! ONE [`SessionManager`] behind a bounded request queue.
//!
//! # Topology
//!
//! ```text
//!   client 0 ──(Transport)── conn thread 0 ──┐
//!   client 1 ──(Transport)── conn thread 1 ──┼─► bounded queue ─► solve
//!   client 2 ──(Transport)── conn thread 2 ──┘   (depth Q)        loop
//!                                                             (SessionManager)
//! ```
//!
//! Each accepted connection gets its own thread that owns its
//! [`Transport`]; the calling thread runs the solve loop, draining the
//! shared queue into [`SessionManager::solve_batch`].  One solve loop —
//! the backend (and its workers) stays single-owner, so interleaved
//! cross-session streams remain bit-identical to isolated sessions.
//!
//! # Backpressure (wire v5)
//!
//! Admission is credit-granted, quill-style: the server greets every
//! connection with `Credit { credits: window }`; each `SubmitSolve`
//! spends one credit and each completed reply (`SolveResult`,
//! `Evicted`, `WorkerError`) is followed by `Credit { credits: 1 }`
//! refunding it.  The queue itself is a bounded channel of depth
//! `queue_depth`: a `SubmitSolve` that arrives while the queue is full
//! is rejected IMMEDIATELY with `Busy { request_id, queue_depth }` —
//! never silently dropped, never unboundedly buffered.  A `Busy` reply
//! refunds the admission credit implicitly (no `Credit` frame follows);
//! the client resubmits later.
//!
//! Replies echo the request's `session_id`/`request_id`, so a client
//! may hold several requests in flight (up to its credit window) and
//! match replies by id.  `SubmitSolve` naming a session the manager
//! does not hold is answered with `Evicted { session_id, request_id }`
//! — the one reply that means "re-register, then retry".
//!
//! The queue occupancy is mirrored to the `service.queue_depth` gauge
//! and rejections count into `service.busy_rejections`.

use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::mpsc;

use crate::coordinator::message::Message;
use crate::coordinator::transport::Transport;
use crate::error::{DapcError, Result};
use crate::obs::{self, Counter, Gauge};
use crate::solver::{RequestId, SessionBackend, SessionId};

use super::SessionManager;

/// Sentinel `worker_id` on server-origin `WorkerError` frames (the
/// solve server is not a worker; real worker ids are small).
pub const SERVER_ERROR_ID: u32 = u32::MAX;

/// Knobs for [`serve_connections`].
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Bounded request-queue depth shared by ALL connections (must be
    /// >= 1).  A `SubmitSolve` arriving while the queue holds this many
    /// pending requests is rejected with `Busy`.
    pub queue_depth: usize,
    /// Admission credits granted to each connection at accept time.
    pub credit_window: u32,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self { queue_depth: 8, credit_window: 4 }
    }
}

/// What one serve run did, summed over all connections.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeReport {
    /// Requests answered with `SolveResult`.
    pub served: u64,
    /// Requests rejected with `Busy` (queue full).
    pub busy: u64,
    /// Requests answered with `Evicted` (unknown session id).
    pub evicted: u64,
    /// Requests answered with `WorkerError` (solve failed).
    pub failed: u64,
}

/// One queued request plus the channel its reply travels back on.
/// (The request id stays with the connection thread, which matches the
/// reply back to the frame it answers.)
struct Job {
    sid: SessionId,
    bs: Vec<Vec<f32>>,
    reply: mpsc::Sender<Reply>,
}

enum Reply {
    Solved { xbars: Vec<Vec<f32>>, residuals: Vec<f32> },
    UnknownSession,
    Failed(String),
}

/// Per-connection counters folded into the [`ServeReport`].
#[derive(Default)]
struct ConnTally {
    busy: u64,
}

/// Serve `conns` until every client disconnects or sends `Shutdown`.
///
/// The calling thread becomes the solve loop; one thread is spawned per
/// connection.  Returns the aggregate [`ServeReport`].  Individual
/// solve failures are reported to the offending client as
/// `WorkerError` frames and do NOT stop the server; transport failures
/// on a connection end that connection and surface here.
pub fn serve_connections<B, T>(
    manager: &mut SessionManager<'_, B>,
    conns: Vec<T>,
    opts: &ServeOptions,
) -> Result<ServeReport>
where
    B: SessionBackend + ?Sized,
    T: Transport,
{
    if opts.queue_depth == 0 {
        return Err(DapcError::Config(
            "solve server queue depth must be >= 1".into(),
        ));
    }
    let (tx, rx) = mpsc::sync_channel::<Job>(opts.queue_depth);
    let depth = AtomicI64::new(0);
    let depth_gauge = obs::gauge("service.queue_depth");
    let busy_counter = obs::counter("service.busy_rejections");

    let mut report = ServeReport::default();
    let mut conn_err: Option<DapcError> = None;
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(conns.len());
        for conn in conns {
            let tx = tx.clone();
            let (depth, gauge, busy) = (&depth, &depth_gauge, &busy_counter);
            handles.push(s.spawn(move || {
                handle_connection(conn, tx, opts, depth, gauge, busy)
            }));
        }
        // the solve loop's recv() ends exactly when every connection
        // thread has finished and dropped its queue sender
        drop(tx);
        while let Ok(job) = rx.recv() {
            depth.fetch_sub(1, Ordering::Relaxed);
            depth_gauge.set(depth.load(Ordering::Relaxed).max(0) as f64);
            let reply = if !manager.contains(job.sid) {
                report.evicted += 1;
                Reply::UnknownSession
            } else {
                match manager.solve_batch(job.sid, &job.bs) {
                    Ok(reports) => {
                        report.served += 1;
                        Reply::Solved {
                            xbars: reports
                                .iter()
                                .map(|r| r.xbar.clone())
                                .collect(),
                            residuals: reports
                                .iter()
                                .map(|r| r.residual.unwrap_or(0.0) as f32)
                                .collect(),
                        }
                    }
                    Err(e) => {
                        report.failed += 1;
                        Reply::Failed(e.to_string())
                    }
                }
            };
            // a send failure means the connection died mid-request; the
            // connection thread reports that itself
            let _ = job.reply.send(reply);
        }
        for h in handles {
            match h.join() {
                Ok(Ok(tally)) => report.busy += tally.busy,
                Ok(Err(e)) => conn_err = Some(e),
                Err(_) => {
                    conn_err = Some(DapcError::Coordinator(
                        "solve-server connection thread panicked".into(),
                    ));
                }
            }
        }
    });
    match conn_err {
        Some(e) => Err(e),
        None => Ok(report),
    }
}

/// One connection's receive loop: admit `SubmitSolve` frames into the
/// bounded queue (or reject with `Busy`), relay replies, refund
/// credits.  Ends on `Shutdown` or peer hangup.
fn handle_connection<T: Transport>(
    mut conn: T,
    queue: mpsc::SyncSender<Job>,
    opts: &ServeOptions,
    depth: &AtomicI64,
    depth_gauge: &Gauge,
    busy_counter: &Counter,
) -> Result<ConnTally> {
    conn.send(&Message::Credit { credits: opts.credit_window })?;
    let mut tally = ConnTally::default();
    loop {
        let msg = match conn.recv() {
            Ok(m) => m,
            // peer hangup is a normal way to end a connection
            Err(_) => break,
        };
        match msg {
            Message::SubmitSolve { session_id, request_id, bs } => {
                let (rtx, rrx) = mpsc::channel();
                let job = Job { sid: session_id, bs, reply: rtx };
                depth.fetch_add(1, Ordering::Relaxed);
                depth_gauge
                    .set(depth.load(Ordering::Relaxed).max(0) as f64);
                match queue.try_send(job) {
                    Ok(()) => {
                        let reply = rrx.recv().map_err(|_| {
                            DapcError::Coordinator(
                                "solve loop hung up before replying".into(),
                            )
                        })?;
                        let frame = match reply {
                            Reply::Solved { xbars, residuals } => {
                                Message::SolveResult {
                                    session_id,
                                    request_id,
                                    xbars,
                                    residuals,
                                }
                            }
                            Reply::UnknownSession => {
                                Message::Evicted { session_id, request_id }
                            }
                            Reply::Failed(message) => Message::WorkerError {
                                worker_id: SERVER_ERROR_ID,
                                message,
                            },
                        };
                        conn.send(&frame)?;
                        conn.send(&Message::Credit { credits: 1 })?;
                    }
                    Err(mpsc::TrySendError::Full(_)) => {
                        depth.fetch_sub(1, Ordering::Relaxed);
                        tally.busy += 1;
                        busy_counter.inc();
                        // Busy refunds the admission credit implicitly
                        conn.send(&Message::Busy {
                            request_id,
                            queue_depth: opts.queue_depth as u32,
                        })?;
                    }
                    Err(mpsc::TrySendError::Disconnected(_)) => {
                        return Err(DapcError::Coordinator(
                            "solve loop shut down mid-connection".into(),
                        ));
                    }
                }
            }
            Message::Shutdown => break,
            other => {
                // per-frame protocol error; the connection survives
                conn.send(&Message::WorkerError {
                    worker_id: SERVER_ERROR_ID,
                    message: format!(
                        "solve server got unexpected {} frame: this \
                         endpoint speaks SubmitSolve/Shutdown only",
                        other.kind_label()
                    ),
                })?;
            }
        }
    }
    Ok(tally)
}

/// One reply to a [`SolveClient::submit`] call.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientReply {
    /// Per-column solutions and residuals, in submission order.
    Solved { xbars: Vec<Vec<f32>>, residuals: Vec<f32> },
    /// The server's queue was full; resubmit later.
    Busy { queue_depth: u32 },
    /// The named session is not registered on the server.
    Evicted,
    /// The solve itself failed (bad column length, backend error, ...).
    Failed(String),
}

/// Client half of the solve-server protocol: credit bookkeeping plus
/// request-id allocation over any [`Transport`].
///
/// This is the strictly-serial client (one request in flight): it is
/// what `dapc serve` uses for its smoke traffic and what the
/// equivalence suites drive.  The wire protocol itself allows up to
/// `credit_window` pipelined requests per connection.
pub struct SolveClient<'t, T: Transport> {
    conn: &'t mut T,
    credits: u32,
    next_rid: RequestId,
}

impl<'t, T: Transport> SolveClient<'t, T> {
    /// Perform the connection handshake: wait for the server's opening
    /// credit grant.
    pub fn connect(conn: &'t mut T) -> Result<Self> {
        match conn.recv()? {
            Message::Credit { credits } => {
                Ok(Self { conn, credits, next_rid: 0 })
            }
            other => Err(DapcError::Coordinator(format!(
                "solve server greeting must be Credit, got {}",
                other.kind_label()
            ))),
        }
    }

    /// Admission credits currently held.
    pub fn credits(&self) -> u32 {
        self.credits
    }

    /// Submit one column-blocked batch to session `sid` and wait for
    /// the reply.
    pub fn submit(
        &mut self,
        sid: SessionId,
        bs: &[Vec<f32>],
    ) -> Result<ClientReply> {
        if self.credits == 0 {
            return Err(DapcError::Coordinator(
                "no admission credits left: wait for a Credit grant \
                 before submitting"
                    .into(),
            ));
        }
        self.next_rid += 1;
        let rid = self.next_rid;
        self.conn.send(&Message::SubmitSolve {
            session_id: sid,
            request_id: rid,
            bs: bs.to_vec(),
        })?;
        self.credits -= 1;
        match self.conn.recv()? {
            Message::Busy { request_id, queue_depth } => {
                Self::check_ids(sid, rid, sid, request_id)?;
                // Busy refunds the credit; no Credit frame follows
                self.credits += 1;
                Ok(ClientReply::Busy { queue_depth })
            }
            Message::SolveResult {
                session_id,
                request_id,
                xbars,
                residuals,
            } => {
                Self::check_ids(sid, rid, session_id, request_id)?;
                self.take_credit()?;
                Ok(ClientReply::Solved { xbars, residuals })
            }
            Message::Evicted { session_id, request_id } => {
                Self::check_ids(sid, rid, session_id, request_id)?;
                self.take_credit()?;
                Ok(ClientReply::Evicted)
            }
            Message::WorkerError { message, .. } => {
                self.take_credit()?;
                Ok(ClientReply::Failed(message))
            }
            other => Err(DapcError::Coordinator(format!(
                "solve server sent unexpected {} frame mid-request",
                other.kind_label()
            ))),
        }
    }

    /// Resubmit through transient `Busy` replies, up to `retries`
    /// attempts total.
    pub fn submit_with_retry(
        &mut self,
        sid: SessionId,
        bs: &[Vec<f32>],
        retries: usize,
    ) -> Result<ClientReply> {
        let mut last = self.submit(sid, bs)?;
        for _ in 1..retries.max(1) {
            match last {
                ClientReply::Busy { .. } => last = self.submit(sid, bs)?,
                other => return Ok(other),
            }
        }
        Ok(last)
    }

    fn check_ids(
        want_sid: SessionId,
        want_rid: RequestId,
        got_sid: SessionId,
        got_rid: RequestId,
    ) -> Result<()> {
        if want_sid != got_sid || want_rid != got_rid {
            return Err(DapcError::Coordinator(format!(
                "solve server reply desync: expected session \
                 {want_sid} request {want_rid}, got session {got_sid} \
                 request {got_rid}"
            )));
        }
        Ok(())
    }

    fn take_credit(&mut self) -> Result<()> {
        match self.conn.recv()? {
            Message::Credit { credits } => {
                self.credits += credits;
                Ok(())
            }
            other => Err(DapcError::Coordinator(format!(
                "expected a Credit refund after the reply, got {}",
                other.kind_label()
            ))),
        }
    }

    /// End the connection (the server's handler thread exits).
    pub fn shutdown(self) -> Result<()> {
        self.conn.send(&Message::Shutdown)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::transport::channel_pair;
    use crate::service::{SessionConfig, SolverSession};
    use crate::solver::{ApcVariant, InProcessBackend, NativeEngine};
    use crate::sparse::generate::GeneratorConfig;

    fn cfg(epochs: usize) -> SessionConfig {
        SessionConfig::apc(ApcVariant::Decomposed).epochs(epochs)
    }

    #[test]
    fn interleaved_connections_match_isolated_sessions() {
        let ds1 = GeneratorConfig::small_demo(16, 2).generate(61);
        let ds2 = GeneratorConfig::small_demo(20, 2).generate(62);
        let e = NativeEngine::new();

        // isolated references on fresh backends
        let mut ib1 = InProcessBackend::new(&e, 2);
        let r1 = SolverSession::register(&mut ib1, ds1.matrix.clone(), cfg(10))
            .unwrap()
            .solve(&ds1.rhs)
            .unwrap();
        let mut ib2 = InProcessBackend::new(&e, 2);
        let r2 = SolverSession::register(&mut ib2, ds2.matrix.clone(), cfg(10))
            .unwrap()
            .solve(&ds2.rhs)
            .unwrap();

        let mut backend = InProcessBackend::new(&e, 2);
        let mut mgr = SessionManager::new(&mut backend);
        let s1 = mgr.register(ds1.matrix.clone(), cfg(10)).unwrap();
        let s2 = mgr.register(ds2.matrix.clone(), cfg(10)).unwrap();

        // two clients, each hammering BOTH sessions over one connection
        let (srv_a, mut cli_a) = channel_pair();
        let (srv_b, mut cli_b) = channel_pair();
        let reqs = [(s1, ds1.rhs.clone()), (s2, ds2.rhs.clone())];
        let run_client = |conn: &mut crate::coordinator::transport::ChannelTransport,
                          reqs: &[(u64, Vec<f32>)]| {
            let mut client = SolveClient::connect(conn).unwrap();
            let mut got = Vec::new();
            for (sid, b) in reqs {
                match client.submit(*sid, &[b.clone()]).unwrap() {
                    ClientReply::Solved { mut xbars, .. } => {
                        got.push(xbars.pop().unwrap())
                    }
                    other => panic!("expected Solved, got {other:?}"),
                }
            }
            assert_eq!(client.credits(), 4, "all credits refunded");
            client.shutdown().unwrap();
            got
        };
        let (got_a, got_b) = std::thread::scope(|s| {
            let ha = s.spawn(|| run_client(&mut cli_a, &reqs));
            let hb = s.spawn(|| run_client(&mut cli_b, &reqs));
            let report = serve_connections(
                &mut mgr,
                vec![srv_a, srv_b],
                &ServeOptions::default(),
            )
            .unwrap();
            assert_eq!(report.served, 4);
            assert_eq!(report.busy + report.evicted + report.failed, 0);
            (ha.join().unwrap(), hb.join().unwrap())
        });
        for got in [got_a, got_b] {
            assert_eq!(got[0], r1.xbar, "session 1 diverged under serving");
            assert_eq!(got[1], r2.xbar, "session 2 diverged under serving");
        }
    }

    #[test]
    fn unknown_session_and_bad_rhs_reported_per_request() {
        let ds = GeneratorConfig::small_demo(14, 2).generate(63);
        let e = NativeEngine::new();
        let mut backend = InProcessBackend::new(&e, 2);
        let mut mgr = SessionManager::new(&mut backend);
        let sid = mgr.register(ds.matrix.clone(), cfg(6)).unwrap();

        let (srv, mut cli) = channel_pair();
        std::thread::scope(|s| {
            let h = s.spawn(move || {
                let mut client = SolveClient::connect(&mut cli).unwrap();
                // unknown session id => Evicted
                assert_eq!(
                    client.submit(sid + 999, &[ds.rhs.clone()]).unwrap(),
                    ClientReply::Evicted
                );
                // wrong column length => per-request failure
                match client.submit(sid, &[vec![1.0f32; 3]]).unwrap() {
                    ClientReply::Failed(msg) => {
                        assert!(msg.contains("length"), "{msg}")
                    }
                    other => panic!("expected Failed, got {other:?}"),
                }
                // the connection survived both: a real solve still works
                match client.submit(sid, &[ds.rhs.clone()]).unwrap() {
                    ClientReply::Solved { .. } => {}
                    other => panic!("expected Solved, got {other:?}"),
                }
                client.shutdown().unwrap();
            });
            let report = serve_connections(
                &mut mgr,
                vec![srv],
                &ServeOptions::default(),
            )
            .unwrap();
            assert_eq!(report.served, 1);
            assert_eq!(report.evicted, 1);
            assert_eq!(report.failed, 1);
            h.join().unwrap();
        });
    }

    #[test]
    fn full_queue_rejects_with_busy_and_refunds_credit() {
        // drive handle_connection directly against a queue we stuffed
        // full, so the Busy path is deterministic
        let (tx, _rx) = mpsc::sync_channel::<Job>(1);
        let (dead_tx, _dead_rx) = mpsc::channel();
        tx.try_send(Job { sid: 1, bs: vec![], reply: dead_tx }).unwrap();

        let (srv, mut cli) = channel_pair();
        let opts = ServeOptions { queue_depth: 1, credit_window: 2 };
        let depth = AtomicI64::new(1);
        let gauge = obs::gauge("service.queue_depth");
        let busy = obs::counter("service.busy_rejections");
        std::thread::scope(|s| {
            let h = s.spawn(move || {
                handle_connection(srv, tx, &opts, &depth, &gauge, &busy)
            });
            let mut client = SolveClient::connect(&mut cli).unwrap();
            assert_eq!(client.credits(), 2);
            match client.submit(7, &[vec![0.0f32; 4]]).unwrap() {
                ClientReply::Busy { queue_depth } => {
                    assert_eq!(queue_depth, 1)
                }
                other => panic!("expected Busy, got {other:?}"),
            }
            assert_eq!(client.credits(), 2, "Busy refunds the credit");
            client.shutdown().unwrap();
            let tally = h.join().unwrap().unwrap();
            assert_eq!(tally.busy, 1);
        });
    }

    #[test]
    fn zero_queue_depth_rejected() {
        let e = NativeEngine::new();
        let mut backend = InProcessBackend::new(&e, 2);
        let mut mgr = SessionManager::new(&mut backend);
        let err = serve_connections(
            &mut mgr,
            Vec::<crate::coordinator::transport::ChannelTransport>::new(),
            &ServeOptions { queue_depth: 0, credit_window: 1 },
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("queue depth"), "{err}");
    }
}
