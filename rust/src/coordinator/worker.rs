//! Worker loop: receives a partition, initializes locally (QR/inverse +
//! projector, or nothing at all for gradient-only DGD service), then
//! serves consensus-update or gradient requests until shutdown.  The
//! projector `P_j` and the dense block `A_j` never leave the worker —
//! only n-length vectors cross the transport.
//!
//! Sessions (wire v3, multi-tenant since v5): a `RegisterMatrix` frame
//! factorizes ONCE and keeps the seed state resident under its
//! `session_id`; any number of `SolveRhs`/`SolveBatch` frames then
//! re-seed estimates for fresh right-hand sides at O(l n + n^2) each.  A
//! worker holds MANY sessions at once (`WorkerSessions`), routes every
//! session frame by its id and echoes `session_id`/`request_id` in the
//! reply so the leader can detect cross-session desync.  `EvictSession`
//! drops one session's resident state (idempotently — absent ids still
//! ack) and a later `RegisterMatrix` under the same id transparently
//! re-factorizes.  An RHS frame naming an unknown session is rejected
//! loudly with a `WorkerError` — it would otherwise silently serve stale
//! state.
//!
//! Wire-v4 telemetry: every engine call is timed into the process-global
//! `worker.*` histograms (instrumentation wraps the engine, never enters
//! it — see `crate::obs`), and a `StatsRequest` frame ships the
//! flattened registry back as a `StatsReport` so a remote leader can
//! print a cluster-wide view.

use std::sync::Arc;

use crate::error::Result;
use crate::linalg::{blas, Matrix};
use crate::obs::{self, Counter, Histogram};
use crate::solver::{ComputeEngine, SeedFactors};

use super::message::Message;
use super::transport::Transport;

/// Worker-side metric handles, fetched from the global registry once at
/// loop start so per-frame recording never takes the registry lock.
struct WorkerObs {
    /// Frames handled (any type).
    frames: Arc<Counter>,
    /// Factorization time (`InitPartition` init or `RegisterMatrix`).
    register_ns: Arc<Histogram>,
    /// Per-`SolveRhs`/`SolveBatch` warm seeding time.
    seed_ns: Arc<Histogram>,
    /// Per-round consensus update time (single and batched).
    update_ns: Arc<Histogram>,
    /// Per-round gradient time (single and batched).
    grad_ns: Arc<Histogram>,
}

impl WorkerObs {
    fn new() -> Self {
        Self {
            frames: obs::counter("worker.frames"),
            register_ns: obs::histogram("worker.register_ns"),
            seed_ns: obs::histogram("worker.seed_ns"),
            update_ns: obs::histogram("worker.update_ns"),
            grad_ns: obs::histogram("worker.grad_ns"),
        }
    }
}

/// Run the worker protocol until `Shutdown`.  Errors are reported to the
/// leader as `WorkerError` before returning.
pub fn run_worker<E: ComputeEngine, T: Transport>(
    engine: &E,
    transport: &mut T,
) -> Result<()> {
    let mut state = WorkerSessions::new();
    let mut my_id: u32 = u32::MAX;
    let wobs = WorkerObs::new();
    loop {
        let msg = transport.recv()?;
        wobs.frames.inc();
        let outcome = handle(engine, &mut state, &mut my_id, msg, &wobs);
        match outcome {
            Ok(Some(reply)) => transport.send(&reply)?,
            Ok(None) => return Ok(()), // shutdown
            Err(e) => {
                transport.send(&Message::WorkerError {
                    worker_id: my_id,
                    message: e.to_string(),
                })?;
                return Err(e);
            }
        }
    }
}

/// All solver state one worker connection holds: the one-shot
/// `InitPartition` slot plus MANY registered sessions keyed by
/// `session_id` (wire v5 multi-tenant service).  BTreeMap for the audit
/// no-hashmap rule and deterministic iteration.
struct WorkerSessions {
    /// `InitPartition` state (cold one-shot solves) — disjoint from the
    /// session map; the two protocols never share estimates.
    one_shot: Option<WorkerState>,
    /// Resident registered sessions: projector + seed factorization +
    /// prepacked panels each, evictable via `EvictSession`.
    sessions: std::collections::BTreeMap<u64, WorkerState>,
}

impl WorkerSessions {
    fn new() -> Self {
        Self { one_shot: None, sessions: std::collections::BTreeMap::new() }
    }
}

struct WorkerState {
    x: Vec<f32>,
    /// `None` after a `GradOnly` init: the worker serves gradients only
    /// and never paid for a factorization.
    projector: Option<Matrix>,
    a: Matrix,
    b: Vec<f32>,
    /// Retained seed factorization (v3 sessions; `None` for one-shot
    /// inits and gradient-only registrations).
    seed: Option<SeedFactors>,
    /// Prepacked projector panels retained alongside the factorization:
    /// registered sessions stream their batched epochs through the
    /// packed wide-gemm update instead of the row-dot sweep.
    panels: Option<blas::PrepackedPanels>,
    /// Per-column batch estimates (v3 batched solves).
    xs: Vec<Vec<f32>>,
    /// Per-column rhs slices (v3 gradient service).
    bs: Vec<Vec<f32>>,
}

impl WorkerState {
    fn one_shot(
        x: Vec<f32>,
        projector: Option<Matrix>,
        a: Matrix,
        b: Vec<f32>,
    ) -> Self {
        Self {
            x,
            projector,
            a,
            b,
            seed: None,
            panels: None,
            xs: Vec::new(),
            bs: Vec::new(),
        }
    }

    fn registered(
        projector: Option<Matrix>,
        seed: Option<SeedFactors>,
        panels: Option<blas::PrepackedPanels>,
        a: Matrix,
    ) -> Self {
        Self {
            x: Vec::new(),
            projector,
            a,
            b: Vec::new(),
            seed,
            panels,
            xs: Vec::new(),
            bs: Vec::new(),
        }
    }
}

fn handle<E: ComputeEngine>(
    engine: &E,
    state: &mut WorkerSessions,
    my_id: &mut u32,
    msg: Message,
    wobs: &WorkerObs,
) -> Result<Option<Message>> {
    match msg {
        Message::InitPartition { worker_id, kind, a, b, n_target } => {
            *my_id = worker_id;
            match kind.engine_kind() {
                Some(engine_kind) => {
                    let t0 = obs::now();
                    let init =
                        engine.init(engine_kind, &a, &b, n_target as usize)?;
                    obs::record_since(&wobs.register_ns, t0);
                    let x0 = init.x0.clone();
                    state.one_shot = Some(WorkerState::one_shot(
                        init.x0,
                        Some(init.projector),
                        a,
                        b,
                    ));
                    Ok(Some(Message::InitDone { worker_id, x0 }))
                }
                None => {
                    // GradOnly: store the block, skip the O(l n^2)
                    // factorization entirely; DGD starts from x = 0 so
                    // there is no estimate to return either
                    state.one_shot =
                        Some(WorkerState::one_shot(Vec::new(), None, a, b));
                    Ok(Some(Message::InitDone { worker_id, x0: Vec::new() }))
                }
            }
        }
        Message::RegisterMatrix {
            worker_id,
            session_id,
            request_id,
            kind,
            a,
            n_target,
        } => {
            *my_id = worker_id;
            let st = match kind.engine_kind() {
                Some(engine_kind) => {
                    // factorize once — the panel-blocked QR; a pooled
                    // engine fans the trailing updates across its
                    // threads, so a worker's cold registration scales
                    // with --threads.  Projector + prepacked panels +
                    // seed state stay resident for every rhs this
                    // session will stream.
                    let t0 = obs::now();
                    let fac =
                        engine.factorize(engine_kind, &a, n_target as usize)?;
                    obs::record_since(&wobs.register_ns, t0);
                    WorkerState::registered(
                        Some(fac.projector),
                        Some(fac.seed),
                        Some(fac.panels),
                        a,
                    )
                }
                // gradient-only session: the block alone is resident
                None => WorkerState::registered(None, None, None, a),
            };
            // replaces any state this id already held (re-registration
            // after eviction lands here)
            state.sessions.insert(session_id, st);
            Ok(Some(Message::MatrixRegistered {
                worker_id,
                session_id,
                request_id,
            }))
        }
        Message::EvictSession { session_id } => {
            // idempotent: evicting an absent id still acks, so a leader
            // retrying an eviction can never wedge
            state.sessions.remove(&session_id);
            Ok(Some(Message::SessionEvicted {
                worker_id: *my_id,
                session_id,
            }))
        }
        Message::SolveRhs { session_id, request_id, b } => {
            let st = session_state(state, session_id, "SolveRhs")?;
            let t0 = obs::now();
            let x0s = seed_columns(engine, st, vec![b])?;
            obs::record_since(&wobs.seed_ns, t0);
            Ok(Some(Message::RhsSeeded {
                worker_id: *my_id,
                session_id,
                request_id,
                x0s,
            }))
        }
        Message::SolveBatch { session_id, request_id, bs } => {
            let st = session_state(state, session_id, "SolveBatch")?;
            let t0 = obs::now();
            let x0s = seed_columns(engine, st, bs)?;
            obs::record_since(&wobs.seed_ns, t0);
            Ok(Some(Message::RhsSeeded {
                worker_id: *my_id,
                session_id,
                request_id,
                x0s,
            }))
        }
        Message::RunUpdateBatch {
            session_id,
            request_id,
            epoch: _,
            gamma,
            xbars,
        } => {
            let st = session_state(state, session_id, "RunUpdateBatch")?;
            let p = st.projector.as_ref().ok_or_else(|| {
                crate::error::DapcError::Coordinator(
                    "RunUpdateBatch on a grad-only worker: no projector \
                     was initialized"
                        .into(),
                )
            })?;
            if st.xs.len() != xbars.len() {
                return Err(crate::error::DapcError::Coordinator(format!(
                    "batch width mismatch: {} seeded columns vs {} \
                     averages (SolveBatch before RunUpdateBatch?)",
                    st.xs.len(),
                    xbars.len()
                )));
            }
            // registered sessions carry prepacked panels and take the
            // packed wide-gemm sweep — bit-identical to the row-dot
            // update, so the wire protocol is unchanged
            let t0 = obs::now();
            st.xs = match &st.panels {
                Some(panels) => {
                    engine.update_batch_packed(&st.xs, &xbars, panels, gamma)?
                }
                None => engine.update_batch(&st.xs, &xbars, p, gamma)?,
            };
            obs::record_since(&wobs.update_ns, t0);
            Ok(Some(Message::UpdateBatchDone {
                worker_id: *my_id,
                session_id,
                request_id,
                xs: st.xs.clone(),
            }))
        }
        Message::RunGradBatch { session_id, request_id, epoch: _, xs } => {
            let st = session_state(state, session_id, "RunGradBatch")?;
            if st.bs.len() != xs.len() {
                return Err(crate::error::DapcError::Coordinator(format!(
                    "batch width mismatch: {} stored rhs vs {} iterates \
                     (SolveBatch before RunGradBatch?)",
                    st.bs.len(),
                    xs.len()
                )));
            }
            let t0 = obs::now();
            let mut grads = Vec::with_capacity(xs.len());
            for (x, bcol) in xs.iter().zip(&st.bs) {
                grads.push(engine.dgd_grad(&st.a, x, bcol)?);
            }
            obs::record_since(&wobs.grad_ns, t0);
            Ok(Some(Message::GradBatchDone {
                worker_id: *my_id,
                session_id,
                request_id,
                grads,
            }))
        }
        Message::RunUpdate { epoch: _, gamma, xbar } => {
            let st = state.one_shot.as_mut().ok_or_else(|| {
                crate::error::DapcError::Coordinator(
                    "RunUpdate before InitPartition".into(),
                )
            })?;
            let p = st.projector.as_ref().ok_or_else(|| {
                crate::error::DapcError::Coordinator(
                    "RunUpdate on a grad-only (GradOnly/DGD) worker: no \
                     projector was initialized"
                        .into(),
                )
            })?;
            let t0 = obs::now();
            st.x = engine.update(&st.x, &xbar, p, gamma)?;
            obs::record_since(&wobs.update_ns, t0);
            Ok(Some(Message::UpdateDone { worker_id: *my_id, x: st.x.clone() }))
        }
        Message::RunGrad { epoch: _, x } => {
            let st = state.one_shot.as_ref().ok_or_else(|| {
                crate::error::DapcError::Coordinator(
                    "RunGrad before InitPartition".into(),
                )
            })?;
            let t0 = obs::now();
            let grad = engine.dgd_grad(&st.a, &x, &st.b)?;
            obs::record_since(&wobs.grad_ns, t0);
            Ok(Some(Message::GradDone { worker_id: *my_id, grad }))
        }
        Message::StatsRequest => {
            // read-only: a flattened snapshot of this process's registry.
            // NOTE in-process clusters share one registry, so the
            // snapshot overlaps with the leader's own metrics; the
            // per-worker split is exact across process boundaries (TCP).
            Ok(Some(Message::StatsReport {
                worker_id: *my_id,
                stats: obs::global().snapshot_flat(),
            }))
        }
        Message::Shutdown => Ok(None),
        other => Err(crate::error::DapcError::Coordinator(format!(
            "worker received unexpected message {other:?}"
        ))),
    }
}

/// The named session's state, or a loud error naming the offending frame
/// when no `RegisterMatrix` created (or an `EvictSession` removed) that
/// id — one-shot `InitPartition` state does NOT qualify: it retains no
/// seed factorization to serve from.
fn session_state<'s>(
    state: &'s mut WorkerSessions,
    session_id: u64,
    frame: &str,
) -> Result<&'s mut WorkerState> {
    state.sessions.get_mut(&session_id).ok_or_else(|| {
        crate::error::DapcError::Coordinator(format!(
            "session {session_id}: {frame} before RegisterMatrix: register \
             a matrix into the session before streaming right-hand sides"
        ))
    })
}

/// Seed k rhs columns through the retained factorization (or store them
/// for gradient service), returning the per-column `x_j(0)` replies.
fn seed_columns<E: ComputeEngine>(
    engine: &E,
    st: &mut WorkerState,
    bs: Vec<Vec<f32>>,
) -> Result<Vec<Vec<f32>>> {
    match &st.seed {
        Some(seed) => {
            let mut x0s = Vec::with_capacity(bs.len());
            for b in &bs {
                x0s.push(engine.seed(seed, &st.a, b)?);
            }
            st.xs = x0s.clone();
            if let Some(first) = x0s.first() {
                st.x = first.clone();
            }
            st.bs = bs;
            Ok(x0s)
        }
        None => {
            // gradient-only session: nothing to factor-solve, DGD
            // starts at 0 — store the columns for gradient rounds
            if let Some(first) = bs.first() {
                st.b = first.clone();
            }
            let k = bs.len();
            st.bs = bs;
            st.xs.clear();
            Ok(vec![Vec::new(); k])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::message::InitKindWire;
    use crate::coordinator::transport::{channel_pair, Transport};
    use crate::rng::seeded;
    use crate::solver::NativeEngine;

    fn consistent(l: usize, n: usize, seed: u64) -> (Matrix, Vec<f32>, Vec<f32>) {
        let mut g = seeded(seed);
        let a = Matrix::from_fn(l, n, |_, _| g.normal_f32());
        let x: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
        let mut b = vec![0.0f32; l];
        crate::linalg::blas::gemv(&a, &x, &mut b);
        (a, b, x)
    }

    #[test]
    fn init_then_update_protocol() {
        let (mut leader, mut worker_side) = channel_pair();
        let handle = std::thread::spawn(move || {
            let engine = NativeEngine::new();
            run_worker(&engine, &mut worker_side)
        });

        let (a, b, x_true) = consistent(24, 8, 3);
        leader
            .send(&Message::InitPartition {
                worker_id: 5,
                kind: InitKindWire::Qr,
                a,
                b,
                n_target: 8,
            })
            .unwrap();
        let Message::InitDone { worker_id, x0 } = leader.recv().unwrap() else {
            panic!("expected InitDone");
        };
        assert_eq!(worker_id, 5);
        for i in 0..8 {
            assert!((x0[i] - x_true[i]).abs() < 1e-2);
        }

        // consensus step with xbar = x0 is a fixed point
        leader
            .send(&Message::RunUpdate { epoch: 0, gamma: 0.9, xbar: x0.clone() })
            .unwrap();
        let Message::UpdateDone { x, .. } = leader.recv().unwrap() else {
            panic!("expected UpdateDone");
        };
        for i in 0..8 {
            assert!((x[i] - x0[i]).abs() < 1e-4);
        }

        leader.send(&Message::Shutdown).unwrap();
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn update_before_init_reports_error() {
        let (mut leader, mut worker_side) = channel_pair();
        let handle = std::thread::spawn(move || {
            let engine = NativeEngine::new();
            let _ = run_worker(&engine, &mut worker_side);
        });
        leader
            .send(&Message::RunUpdate { epoch: 0, gamma: 0.5, xbar: vec![0.0] })
            .unwrap();
        match leader.recv().unwrap() {
            Message::WorkerError { message, .. } => {
                assert!(message.contains("before InitPartition"), "{message}");
            }
            other => panic!("expected WorkerError, got {other:?}"),
        }
        handle.join().unwrap();
    }

    #[test]
    fn grad_protocol() {
        let (mut leader, mut worker_side) = channel_pair();
        let handle = std::thread::spawn(move || {
            let engine = NativeEngine::new();
            run_worker(&engine, &mut worker_side)
        });
        let (a, b, x_true) = consistent(16, 4, 9);
        leader
            .send(&Message::InitPartition {
                worker_id: 0,
                kind: InitKindWire::Qr,
                a,
                b,
                n_target: 4,
            })
            .unwrap();
        let _ = leader.recv().unwrap();
        // gradient at the true solution is ~0
        leader
            .send(&Message::RunGrad { epoch: 0, x: x_true })
            .unwrap();
        let Message::GradDone { grad, .. } = leader.recv().unwrap() else {
            panic!("expected GradDone");
        };
        assert!(crate::linalg::norms::max_abs(&grad) < 1e-3);
        leader.send(&Message::Shutdown).unwrap();
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn rhs_before_register_rejected_loudly() {
        // the session contract: streaming an rhs into a worker that
        // never registered a matrix is a protocol error, reported as a
        // WorkerError — even if a one-shot InitPartition happened first
        let (mut leader, mut worker_side) = channel_pair();
        let handle = std::thread::spawn(move || {
            let engine = NativeEngine::new();
            let _ = run_worker(&engine, &mut worker_side);
        });
        leader
            .send(&Message::SolveRhs {
                session_id: 7,
                request_id: 1,
                b: vec![1.0, 2.0],
            })
            .unwrap();
        match leader.recv().unwrap() {
            Message::WorkerError { message, .. } => {
                assert!(
                    message.contains("SolveRhs before RegisterMatrix"),
                    "{message}"
                );
                assert!(message.contains("session 7"), "{message}");
            }
            other => panic!("expected WorkerError, got {other:?}"),
        }
        handle.join().unwrap();

        // one-shot init state does not make rhs streaming legal either
        let (mut leader, mut worker_side) = channel_pair();
        let handle = std::thread::spawn(move || {
            let engine = NativeEngine::new();
            let _ = run_worker(&engine, &mut worker_side);
        });
        let (a, b, _) = consistent(16, 4, 30);
        leader
            .send(&Message::InitPartition {
                worker_id: 0,
                kind: InitKindWire::Qr,
                a,
                b: b.clone(),
                n_target: 4,
            })
            .unwrap();
        let _ = leader.recv().unwrap();
        leader
            .send(&Message::SolveBatch {
                session_id: 7,
                request_id: 2,
                bs: vec![b],
            })
            .unwrap();
        match leader.recv().unwrap() {
            Message::WorkerError { message, .. } => {
                assert!(
                    message.contains("SolveBatch before RegisterMatrix"),
                    "{message}"
                );
            }
            other => panic!("expected WorkerError, got {other:?}"),
        }
        handle.join().unwrap();
    }

    #[test]
    fn register_then_stream_rhs_reuses_factorization() {
        let (mut leader, mut worker_side) = channel_pair();
        let handle = std::thread::spawn(move || {
            let engine = NativeEngine::new();
            run_worker(&engine, &mut worker_side)
        });

        let (a, b, _) = consistent(24, 8, 31);
        leader
            .send(&Message::RegisterMatrix {
                worker_id: 4,
                session_id: 11,
                request_id: 1,
                kind: InitKindWire::Qr,
                a: a.clone(),
                n_target: 8,
            })
            .unwrap();
        let Message::MatrixRegistered { worker_id, session_id, request_id } =
            leader.recv().unwrap()
        else {
            panic!("expected MatrixRegistered");
        };
        assert_eq!(worker_id, 4);
        assert_eq!(session_id, 11);
        assert_eq!(request_id, 1);

        // stream several rhs: each warm seed must equal a cold init
        let engine = NativeEngine::new();
        for seed in 0..3u64 {
            let mut g = seeded(600 + seed);
            let b2: Vec<f32> = (0..24).map(|_| g.normal_f32()).collect();
            leader
                .send(&Message::SolveRhs {
                    session_id: 11,
                    request_id: 2 + seed,
                    b: b2.clone(),
                })
                .unwrap();
            let Message::RhsSeeded { session_id, request_id, x0s, .. } =
                leader.recv().unwrap()
            else {
                panic!("expected RhsSeeded");
            };
            assert_eq!(session_id, 11);
            assert_eq!(request_id, 2 + seed);
            let cold = engine
                .init(crate::solver::InitKind::Qr, &a, &b2, 8)
                .unwrap();
            assert_eq!(x0s, vec![cold.x0], "seed {seed}");
        }

        // a batched epoch over k = 2 columns
        leader
            .send(&Message::SolveBatch {
                session_id: 11,
                request_id: 9,
                bs: vec![b.clone(), b.clone()],
            })
            .unwrap();
        let Message::RhsSeeded { x0s, .. } = leader.recv().unwrap() else {
            panic!("expected RhsSeeded");
        };
        assert_eq!(x0s.len(), 2);
        leader
            .send(&Message::RunUpdateBatch {
                session_id: 11,
                request_id: 9,
                epoch: 0,
                gamma: 0.9,
                xbars: x0s.clone(),
            })
            .unwrap();
        let Message::UpdateBatchDone { session_id, request_id, xs, .. } =
            leader.recv().unwrap()
        else {
            panic!("expected UpdateBatchDone");
        };
        assert_eq!(session_id, 11);
        assert_eq!(request_id, 9);
        assert_eq!(xs.len(), 2);

        leader.send(&Message::Shutdown).unwrap();
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn two_sessions_resident_and_eviction_is_idempotent() {
        // one worker holds two registered sessions at once; frames route
        // by session_id, eviction drops exactly one, and re-registration
        // after eviction reproduces the original warm seed bit-for-bit
        let (mut leader, mut worker_side) = channel_pair();
        let handle = std::thread::spawn(move || {
            let engine = NativeEngine::new();
            let _ = run_worker(&engine, &mut worker_side);
        });

        let (a1, b1, _) = consistent(24, 8, 41);
        let (a2, b2, _) = consistent(20, 8, 42);
        for (sid, a) in [(1u64, &a1), (2u64, &a2)] {
            leader
                .send(&Message::RegisterMatrix {
                    worker_id: 0,
                    session_id: sid,
                    request_id: sid,
                    kind: InitKindWire::Qr,
                    a: a.clone(),
                    n_target: 8,
                })
                .unwrap();
            let Message::MatrixRegistered { session_id, .. } =
                leader.recv().unwrap()
            else {
                panic!("expected MatrixRegistered");
            };
            assert_eq!(session_id, sid);
        }

        // interleave seeds across the two sessions; each must match the
        // cold init against ITS OWN matrix
        let engine = NativeEngine::new();
        let mut warm1 = Vec::new();
        for (sid, a, b) in [(1u64, &a1, &b1), (2u64, &a2, &b2)] {
            leader
                .send(&Message::SolveRhs {
                    session_id: sid,
                    request_id: 10 + sid,
                    b: b.clone(),
                })
                .unwrap();
            let Message::RhsSeeded { session_id, x0s, .. } =
                leader.recv().unwrap()
            else {
                panic!("expected RhsSeeded");
            };
            assert_eq!(session_id, sid);
            let cold =
                engine.init(crate::solver::InitKind::Qr, a, b, 8).unwrap();
            assert_eq!(x0s, vec![cold.x0]);
            if sid == 1 {
                warm1 = x0s;
            }
        }

        // evict session 1 twice: second ack proves idempotency
        for _ in 0..2 {
            leader.send(&Message::EvictSession { session_id: 1 }).unwrap();
            let Message::SessionEvicted { session_id, .. } =
                leader.recv().unwrap()
            else {
                panic!("expected SessionEvicted");
            };
            assert_eq!(session_id, 1);
        }

        // session 1 is gone, session 2 still serves
        leader
            .send(&Message::SolveRhs {
                session_id: 1,
                request_id: 20,
                b: b1.clone(),
            })
            .unwrap();
        match leader.recv().unwrap() {
            Message::WorkerError { message, .. } => {
                assert!(message.contains("session 1"), "{message}");
            }
            other => panic!("expected WorkerError, got {other:?}"),
        }
        handle.join().unwrap();

        // a fresh worker re-registering session 1 reproduces the warm
        // seed bit-for-bit (eviction lost nothing but time)
        let (mut leader, mut worker_side) = channel_pair();
        let handle = std::thread::spawn(move || {
            let engine = NativeEngine::new();
            run_worker(&engine, &mut worker_side)
        });
        leader
            .send(&Message::RegisterMatrix {
                worker_id: 0,
                session_id: 1,
                request_id: 30,
                kind: InitKindWire::Qr,
                a: a1.clone(),
                n_target: 8,
            })
            .unwrap();
        let _ = leader.recv().unwrap();
        leader
            .send(&Message::SolveRhs {
                session_id: 1,
                request_id: 31,
                b: b1.clone(),
            })
            .unwrap();
        let Message::RhsSeeded { x0s, .. } = leader.recv().unwrap() else {
            panic!("expected RhsSeeded");
        };
        assert_eq!(x0s, warm1);
        leader.send(&Message::Shutdown).unwrap();
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn stats_request_returns_registry_snapshot() {
        // hold the obs test lock: the report reads the process-global
        // registry, and other tests may toggle the enabled switch
        let _g = crate::obs::test_lock();
        crate::obs::set_enabled(true);

        let (mut leader, mut worker_side) = channel_pair();
        let handle = std::thread::spawn(move || {
            let engine = NativeEngine::new();
            run_worker(&engine, &mut worker_side)
        });

        let (a, b, _) = consistent(24, 8, 77);
        leader
            .send(&Message::RegisterMatrix {
                worker_id: 9,
                session_id: 3,
                request_id: 1,
                kind: InitKindWire::Qr,
                a,
                n_target: 8,
            })
            .unwrap();
        let _ = leader.recv().unwrap();
        leader
            .send(&Message::SolveRhs { session_id: 3, request_id: 2, b })
            .unwrap();
        let _ = leader.recv().unwrap();

        leader.send(&Message::StatsRequest).unwrap();
        let Message::StatsReport { worker_id, stats } = leader.recv().unwrap()
        else {
            panic!("expected StatsReport");
        };
        assert_eq!(worker_id, 9);
        let get = |key: &str| {
            stats
                .iter()
                .find(|(k, _)| k == key)
                .map(|(_, v)| *v)
                .unwrap_or_else(|| panic!("missing stat {key:?}"))
        };
        assert!(get("worker.register_ns.count") >= 1.0);
        assert!(get("worker.seed_ns.count") >= 1.0);
        assert!(get("worker.frames") >= 2.0);

        leader.send(&Message::Shutdown).unwrap();
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn grad_only_init_skips_factorization() {
        // timing-independent proof that GradOnly does no init work: the
        // worker returns an EMPTY x0 (a factorizing init always returns
        // an n_target-length estimate) and holds no projector, so a
        // consensus update is impossible while gradients still work.
        let (mut leader, mut worker_side) = channel_pair();
        let handle = std::thread::spawn(move || {
            let engine = NativeEngine::new();
            let _ = run_worker(&engine, &mut worker_side);
        });
        let (a, b, x_true) = consistent(16, 4, 10);
        leader
            .send(&Message::InitPartition {
                worker_id: 2,
                kind: InitKindWire::GradOnly,
                a,
                b,
                n_target: 4,
            })
            .unwrap();
        let Message::InitDone { worker_id, x0 } = leader.recv().unwrap() else {
            panic!("expected InitDone");
        };
        assert_eq!(worker_id, 2);
        assert!(x0.is_empty(), "GradOnly must not compute an initial solve");

        // gradients are served from the stored block
        leader
            .send(&Message::RunGrad { epoch: 0, x: x_true })
            .unwrap();
        let Message::GradDone { grad, .. } = leader.recv().unwrap() else {
            panic!("expected GradDone");
        };
        assert!(crate::linalg::norms::max_abs(&grad) < 1e-3);

        // no projector exists -> consensus updates are rejected loudly
        leader
            .send(&Message::RunUpdate {
                epoch: 0,
                gamma: 0.5,
                xbar: vec![0.0; 4],
            })
            .unwrap();
        match leader.recv().unwrap() {
            Message::WorkerError { message, .. } => {
                assert!(message.contains("grad-only"), "{message}");
            }
            other => panic!("expected WorkerError, got {other:?}"),
        }
        handle.join().unwrap();
    }
}
