//! Table 1 bench: total execution time of classical vs decomposed APC on
//! the paper's five matrix shapes, reporting the acceleration factor.
//!
//! Default: 1/8-scale shapes (relative shape preserved); `DAPC_FULL=1`
//! runs the exact published sizes.  `DAPC_QUICK=1` restricts to the two
//! smallest rows for CI smoke runs.

use dapc::benchkit::{black_box, full_mode, quick_mode, Bench};
use dapc::metrics::TableBuilder;
use dapc::prelude::*;
use dapc::sparse::generate::GeneratorConfig;

const TABLE1: [(usize, usize, usize); 5] = [
    (9308, 2327, 80),
    (15188, 3797, 70),
    (18252, 4563, 95),
    (21284, 5321, 85),
    (37084, 9271, 175),
];

fn main() {
    let scale = if full_mode() { 1 } else { 8 };
    let rows: &[(usize, usize, usize)] =
        if quick_mode() { &TABLE1[..2] } else { &TABLE1 };
    let j = 2;
    let engine = NativeEngine::new();
    let bench = Bench::default();

    println!("=== Table 1: classical vs decomposed APC (scale 1/{scale}, J={j}) ===");
    let mut table = TableBuilder::new(&[
        "A matrix shape",
        "T",
        "Classical APC",
        "Decomposed APC",
        "Acceleration",
    ]);
    let mut paper = [1.24, 1.49, 1.52, 1.68, 1.79].iter();

    for &(mi, ni, t) in rows {
        let (m, n) = (mi / scale, ni / scale);
        let ds = GeneratorConfig::table1(m, n).generate(n as u64);
        let opts = SolveOptions { epochs: t, ..Default::default() };

        let rc = bench.run_once(&format!("classical ({m}x{n}) T={t}"), || {
            let r = ApcClassicalSolver::new(opts.clone())
                .solve(&engine, &ds.matrix, &ds.rhs, j)
                .expect("solve");
            assert!(r.final_mse(&ds.x_true) < 1e-2);
            black_box(r.xbar.len());
        });
        let rd = bench.run_once(&format!("decomposed ({m}x{n}) T={t}"), || {
            let r = DapcSolver::new(opts.clone())
                .solve(&engine, &ds.matrix, &ds.rhs, j)
                .expect("solve");
            assert!(r.final_mse(&ds.x_true) < 1e-2);
            black_box(r.xbar.len());
        });
        let (tc, td) = (rc.stats.mean(), rd.stats.mean());
        table.row(&[
            format!("({m} x {n})"),
            format!("{t}"),
            format!("{tc:.2}s"),
            format!("{td:.2}s"),
            format!("{:.2} (paper {:.2})", tc / td, paper.next().unwrap()),
        ]);
    }
    println!("\n{}", table.render());
}
