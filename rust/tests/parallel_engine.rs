//! Property and determinism tests for the parallel execution subsystem:
//! `ParallelEngine` must agree with the sequential `NativeEngine` on
//! every operation (within 1e-6; in practice bit-exactly) across random
//! shapes — including J=1, ragged last partitions and index ranges that
//! do not divide evenly into chunks — and must be deterministic across
//! thread counts.

use dapc::linalg::{norms, Matrix};
use dapc::parallel::ParallelEngine;
use dapc::rng::seeded;
use dapc::solver::{
    ComputeEngine, DapcSolver, DgdSolver, NativeEngine, RoundWorkspace,
    SolveOptions, Solver,
};
use dapc::sparse::generate::GeneratorConfig;

fn randm(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut g = seeded(seed);
    Matrix::from_fn(rows, cols, |_, _| g.normal_f32())
}

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut g = seeded(seed);
    (0..n).map(|_| g.normal_f32()).collect()
}

/// Random (J, n) cases: J=1, odd n, n smaller and larger than typical
/// chunk sizes, J not dividing n.
fn round_cases() -> Vec<(usize, usize)> {
    vec![(1, 1), (1, 17), (2, 7), (3, 31), (4, 64), (5, 37), (8, 129)]
}

#[test]
fn prop_round_matches_native_across_shapes() {
    let native = NativeEngine::new();
    for (case, &(j, n)) in round_cases().iter().enumerate() {
        let seed = 1000 + case as u64 * 10;
        let par = ParallelEngine::new(1 + case % 5);
        let xs: Vec<Vec<f32>> =
            (0..j).map(|i| randv(n, seed + i as u64)).collect();
        let xbar = randv(n, seed + 100);
        let ps: Vec<Matrix> =
            (0..j).map(|i| randm(n, n, seed + 200 + i as u64)).collect();

        let (nx, nb) = native.round(&xs, &xbar, &ps, 0.8, 0.7).unwrap();
        let (px, pb) = par.round(&xs, &xbar, &ps, 0.8, 0.7).unwrap();
        for (a, b) in nx.iter().zip(&px) {
            assert!(norms::mae(a, b) < 1e-6, "round x (j={j}, n={n})");
        }
        assert!(norms::mae(&nb, &pb) < 1e-6, "round xbar (j={j}, n={n})");
    }
}

#[test]
fn prop_average_matches_native_across_shapes() {
    let native = NativeEngine::new();
    for (case, &(j, n)) in round_cases().iter().enumerate() {
        let seed = 2000 + case as u64 * 10;
        let par = ParallelEngine::new(2 + case % 4);
        let xs: Vec<Vec<f32>> =
            (0..j).map(|i| randv(n, seed + i as u64)).collect();
        let xbar = randv(n, seed + 100);
        let na = native.average(&xs, &xbar, 0.65).unwrap();
        let pa = par.average(&xs, &xbar, 0.65).unwrap();
        assert!(norms::mae(&na, &pa) < 1e-6, "average (j={j}, n={n})");
    }
}

#[test]
fn prop_dgd_grad_matches_native_across_shapes() {
    let native = NativeEngine::new();
    for (case, &(l, n)) in
        [(1usize, 1usize), (5, 3), (23, 9), (64, 33), (101, 29)]
            .iter()
            .enumerate()
    {
        let seed = 3000 + case as u64 * 10;
        let par = ParallelEngine::new(1 + case % 4);
        let a = randm(l, n, seed);
        let x = randv(n, seed + 1);
        let b = randv(l, seed + 2);
        let ng = native.dgd_grad(&a, &x, &b).unwrap();
        let pg = par.dgd_grad(&a, &x, &b).unwrap();
        assert!(norms::mae(&ng, &pg) < 1e-6, "dgd_grad ({l}x{n})");
    }
}

#[test]
fn determinism_same_seed_identical_across_thread_counts() {
    // same inputs, thread counts 1/2/3/8: identical bits out
    let (j, n) = (5, 53);
    let xs: Vec<Vec<f32>> = (0..j).map(|i| randv(n, 40 + i as u64)).collect();
    let xbar = randv(n, 90);
    let ps: Vec<Matrix> =
        (0..j).map(|i| randm(n, n, 60 + i as u64)).collect();

    let reference = ParallelEngine::new(1)
        .round(&xs, &xbar, &ps, 0.9, 0.8)
        .unwrap();
    for threads in [2usize, 3, 8] {
        let got = ParallelEngine::new(threads)
            .round(&xs, &xbar, &ps, 0.9, 0.8)
            .unwrap();
        assert_eq!(reference.0, got.0, "xs diverged at {threads} threads");
        assert_eq!(reference.1, got.1, "xbar diverged at {threads} threads");
    }
}

#[test]
fn full_solve_matches_native_with_ragged_last_partition() {
    // m = 4n + remainder rows so the last partition absorbs a ragged tail
    let n = 48;
    let mut cfg = GeneratorConfig::small_demo(n, 3);
    cfg.m_total = 4 * n + 7;
    let ds = cfg.generate(7);
    let opts = SolveOptions { epochs: 25, ..Default::default() };

    let native_report = DapcSolver::new(opts.clone())
        .solve(&NativeEngine::new(), &ds.matrix, &ds.rhs, 3)
        .unwrap();
    for threads in [1usize, 4] {
        let par_report = DapcSolver::new(opts.clone())
            .solve(&ParallelEngine::new(threads), &ds.matrix, &ds.rhs, 3)
            .unwrap();
        assert_eq!(par_report.engine, "parallel");
        let diff = norms::mse(&native_report.xbar, &par_report.xbar);
        assert!(diff < 1e-12, "solve diverged at {threads} threads: {diff:e}");
    }
    // and it actually solves the system
    assert!(native_report.final_mse(&ds.x_true) < 1e-6);
}

#[test]
fn full_dgd_solve_matches_native() {
    let ds = GeneratorConfig::small_demo(24, 2).generate(11);
    let opts = SolveOptions {
        epochs: 60,
        dgd_step: 0.0,
        ..Default::default()
    };
    let n_report = DgdSolver::new(opts.clone())
        .solve(&NativeEngine::new(), &ds.matrix, &ds.rhs, 2)
        .unwrap();
    let p_report = DgdSolver::new(opts)
        .solve(&ParallelEngine::new(3), &ds.matrix, &ds.rhs, 2)
        .unwrap();
    assert!(norms::mse(&n_report.xbar, &p_report.xbar) < 1e-12);
    // dgd now reports a residual through the spmv_into path
    assert!(n_report.residual.is_some());
}

#[test]
fn round_into_is_reusable_and_matches_round_on_parallel_engine() {
    let par = ParallelEngine::new(3);
    let (j, n) = (4, 33);
    let mut xs: Vec<Vec<f32>> =
        (0..j).map(|i| randv(n, 500 + i as u64)).collect();
    let mut xbar = randv(n, 600);
    let ps: Vec<Matrix> =
        (0..j).map(|i| randm(n, n, 700 + i as u64)).collect();

    let mut ws = RoundWorkspace::for_shape(j, n);
    let mut next_xs: Vec<Vec<f32>> = vec![vec![0.0; n]; j];
    let mut next_xbar = vec![0.0f32; n];
    for _ in 0..5 {
        let (want_xs, want_xbar) =
            par.round(&xs, &xbar, &ps, 0.7, 0.6).unwrap();
        par.round_into(
            &xs, &xbar, &ps, 0.7, 0.6, &mut ws, &mut next_xs, &mut next_xbar,
        )
        .unwrap();
        assert_eq!(want_xs, next_xs);
        assert_eq!(want_xbar, next_xbar);
        std::mem::swap(&mut xs, &mut next_xs);
        std::mem::swap(&mut xbar, &mut next_xbar);
    }
}

#[test]
fn parallel_engine_in_local_cluster() {
    // engines are built inside worker threads; share-nothing pools
    let ds = GeneratorConfig::small_demo(16, 2).generate(21);
    let mut cluster =
        dapc::coordinator::LocalCluster::spawn(2, || ParallelEngine::new(2))
            .unwrap();
    let report = cluster
        .leader
        .solve_apc(
            &ds.matrix,
            &ds.rhs,
            dapc::solver::ApcVariant::Decomposed,
            &SolveOptions { epochs: 20, ..Default::default() },
        )
        .unwrap();
    assert!(report.final_mse(&ds.x_true) < 1e-6);
}
