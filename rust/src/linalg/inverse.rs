//! Gauss-Jordan elimination with partial pivoting — the O(n^3) inversion
//! [18] whose cost the paper's decomposition eliminates.  Used by the
//! native-engine classical-APC baseline and by the init-method ablation.

use super::{blas, Matrix};
use crate::error::{DapcError, Result};

/// Invert a square matrix via Gauss-Jordan with partial pivoting.
///
/// Returns an error on (numerically) singular input.
pub fn gauss_jordan_inverse(a: &Matrix) -> Result<Matrix> {
    let n = a.rows();
    if a.cols() != n {
        return Err(DapcError::Shape(format!(
            "inverse requires square matrix, got {}x{}",
            a.rows(),
            a.cols()
        )));
    }
    // augmented [A | I], eliminated in place
    let mut aug = Matrix::zeros(n, 2 * n);
    for i in 0..n {
        aug.row_mut(i)[..n].copy_from_slice(a.row(i));
        aug[(i, n + i)] = 1.0;
    }

    for k in 0..n {
        // partial pivot
        let mut piv_row = k;
        let mut piv_val = aug[(k, k)].abs();
        for i in k + 1..n {
            let v = aug[(i, k)].abs();
            if v > piv_val {
                piv_val = v;
                piv_row = i;
            }
        }
        if piv_val < 1e-12 {
            return Err(DapcError::Numeric(format!(
                "singular matrix at pivot {k} (|pivot| = {piv_val:e})"
            )));
        }
        if piv_row != k {
            // swap rows k and piv_row
            let (lo, hi) = (k.min(piv_row), k.max(piv_row));
            let cols = 2 * n;
            let data = aug.as_mut_slice();
            let (a_part, b_part) = data.split_at_mut(hi * cols);
            a_part[lo * cols..lo * cols + cols]
                .swap_with_slice(&mut b_part[..cols]);
        }
        let piv = aug[(k, k)];
        let inv_piv = 1.0 / piv;
        // columns < k are already eliminated (exact zeros in row k), so
        // all row operations can start at column k (§Perf, ~25% saved).
        for v in aug.row_mut(k)[k..].iter_mut() {
            *v *= inv_piv;
        }
        // eliminate column k from all other rows
        let pivot_row = aug.row(k)[k..].to_vec();
        for i in 0..n {
            if i == k {
                continue;
            }
            let factor = aug[(i, k)];
            if factor != 0.0 {
                blas::axpy(-factor, &pivot_row, &mut aug.row_mut(i)[k..]);
                aug[(i, k)] = 0.0; // kill rounding residue
            }
        }
    }

    let mut inv = Matrix::zeros(n, n);
    for i in 0..n {
        inv.row_mut(i).copy_from_slice(&aug.row(i)[n..]);
    }
    Ok(inv)
}

/// Moore-Penrose pseudoinverse of a tall full-column-rank matrix via the
/// normal equations: `A^+ = (A^T A)^{-1} A^T` (classical-APC init path).
pub fn pinv_tall(a: &Matrix) -> Result<Matrix> {
    let g = blas::gram(a);
    let ginv = gauss_jordan_inverse(&g)?;
    Ok(blas::gemm(&ginv, &a.transpose()))
}

/// f64 classical-APC init: `x0 = (A^T A)^{-1} A^T b` and the *numerically
/// evaluated* projector `P = I - (A^T A)^{-1}(A^T A)`, all in double
/// precision.
///
/// The paper's classical baseline runs on NumPy float64; doing the normal
/// equations in f32 squares the condition number into territory where the
/// projector noise exceeds 1 and the consensus iteration diverges (see
/// DESIGN.md §1). Computing in f64 and casting the results back matches
/// the reference implementation's numerics.
pub fn classical_init_f64(a: &Matrix, b: &[f32]) -> Result<(Vec<f32>, Matrix)> {
    let (ginv, p) = classical_factorize_f64(a)?;
    let x0 = classical_seed_f64(a, &ginv, b)?;
    Ok((x0, p))
}

/// The right-hand-side-independent half of [`classical_init_f64`]: the
/// f64 Gram inverse `(A^T A)^{-1}` (flat row-major, retained by warm
/// solver sessions) and the numerically evaluated projector
/// `P = I - (A^T A)^{-1}(A^T A)`.  Neither depends on `b`, so a session
/// pays this O(l n^2 + n^3) cost exactly once per registered matrix.
pub fn classical_factorize_f64(a: &Matrix) -> Result<(Vec<f64>, Matrix)> {
    let (l, n) = a.shape();
    // G = A^T A in f64
    let mut g = vec![0.0f64; n * n];
    for r in 0..l {
        let row = a.row(r);
        for i in 0..n {
            let ri = row[i] as f64;
            if ri != 0.0 {
                for j in i..n {
                    g[i * n + j] += ri * row[j] as f64;
                }
            }
        }
    }
    for i in 0..n {
        for j in 0..i {
            g[i * n + j] = g[j * n + i];
        }
    }
    let ginv = gauss_jordan_inverse_f64(&g, n)?;
    // P = I - Ginv G (numeric noise at f64 scale)
    let mut p = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0f64;
            for k in 0..n {
                s += ginv[i * n + k] * g[k * n + j];
            }
            let id = if i == j { 1.0 } else { 0.0 };
            p[(i, j)] = (id - s) as f32;
        }
    }
    Ok((ginv, p))
}

/// The per-RHS half of [`classical_init_f64`]: `x0 = Ginv (A^T b)` in
/// f64 from a retained Gram inverse.  Performs exactly the arithmetic of
/// the combined init, so a warm re-seed is bit-identical to a cold one.
pub fn classical_seed_f64(
    a: &Matrix,
    ginv: &[f64],
    b: &[f32],
) -> Result<Vec<f32>> {
    let (l, n) = a.shape();
    if b.len() != l {
        return Err(DapcError::Shape(format!(
            "rhs length {} != rows {l}",
            b.len()
        )));
    }
    if ginv.len() != n * n {
        return Err(DapcError::Shape(format!(
            "gram inverse has {} entries, expected {n}x{n}",
            ginv.len()
        )));
    }
    let mut atb = vec![0.0f64; n];
    for r in 0..l {
        let row = a.row(r);
        let br = b[r] as f64;
        if br != 0.0 {
            for i in 0..n {
                atb[i] += row[i] as f64 * br;
            }
        }
    }
    let mut x0 = vec![0.0f32; n];
    for i in 0..n {
        let mut s = 0.0f64;
        for j in 0..n {
            s += ginv[i * n + j] * atb[j];
        }
        x0[i] = s as f32;
    }
    Ok(x0)
}

/// Gauss-Jordan inverse over a flat row-major f64 buffer.
fn gauss_jordan_inverse_f64(a: &[f64], n: usize) -> Result<Vec<f64>> {
    let cols = 2 * n;
    let mut aug = vec![0.0f64; n * cols];
    for i in 0..n {
        aug[i * cols..i * cols + n].copy_from_slice(&a[i * n..(i + 1) * n]);
        aug[i * cols + n + i] = 1.0;
    }
    for k in 0..n {
        let mut piv_row = k;
        let mut piv_val = aug[k * cols + k].abs();
        for i in k + 1..n {
            let v = aug[i * cols + k].abs();
            if v > piv_val {
                piv_val = v;
                piv_row = i;
            }
        }
        if piv_val < 1e-300 {
            return Err(DapcError::Numeric(format!(
                "singular matrix at pivot {k}"
            )));
        }
        if piv_row != k {
            for c in 0..cols {
                aug.swap(k * cols + c, piv_row * cols + c);
            }
        }
        let inv_piv = 1.0 / aug[k * cols + k];
        // left-half columns < k of row k are exactly zero (eliminated in
        // earlier steps), so row operations can start at column k — this
        // trims ~25% of the elimination work (§Perf).
        for c in k..cols {
            aug[k * cols + c] *= inv_piv;
        }
        for i in 0..n {
            if i == k {
                continue;
            }
            let f = aug[i * cols + k];
            if f != 0.0 {
                for c in k..cols {
                    aug[i * cols + c] -= f * aug[k * cols + c];
                }
                aug[i * cols + k] = 0.0;
            }
        }
    }
    let mut inv = vec![0.0f64; n * n];
    for i in 0..n {
        inv[i * n..(i + 1) * n]
            .copy_from_slice(&aug[i * cols + n..i * cols + 2 * n]);
    }
    Ok(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::gemm;
    use crate::rng::seeded;

    fn randm(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut g = seeded(seed);
        Matrix::from_fn(rows, cols, |_, _| g.normal_f32())
    }

    #[test]
    fn inverse_of_identity() {
        let inv = gauss_jordan_inverse(&Matrix::eye(8)).unwrap();
        assert!(inv.max_abs_diff(&Matrix::eye(8)) < 1e-7);
    }

    #[test]
    fn inverse_well_conditioned() {
        for &n in &[1usize, 2, 8, 32, 64] {
            let mut a = randm(n, n, n as u64);
            for i in 0..n {
                a[(i, i)] += n as f32; // diagonally dominant
            }
            let inv = gauss_jordan_inverse(&a).unwrap();
            let prod = gemm(&inv, &a);
            assert!(prod.max_abs_diff(&Matrix::eye(n)) < 5e-3, "n={n}");
        }
    }

    #[test]
    fn pivoting_required_case() {
        // [[0,1],[1,0]] breaks non-pivoting elimination
        let a = Matrix::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        let inv = gauss_jordan_inverse(&a).unwrap();
        assert!(inv.max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn singular_matrix_errors() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        assert!(gauss_jordan_inverse(&a).is_err());
        let z = Matrix::zeros(3, 3);
        assert!(gauss_jordan_inverse(&z).is_err());
    }

    #[test]
    fn non_square_errors() {
        let a = Matrix::zeros(3, 4);
        assert!(gauss_jordan_inverse(&a).is_err());
    }

    #[test]
    fn pinv_solves_consistent_system() {
        let a = randm(24, 8, 3);
        let mut g = seeded(4);
        let x_true: Vec<f32> = (0..8).map(|_| g.normal_f32()).collect();
        let mut b = vec![0.0f32; 24];
        crate::linalg::blas::gemv(&a, &x_true, &mut b);
        let pinv = pinv_tall(&a).unwrap();
        let mut x = vec![0.0f32; 8];
        crate::linalg::blas::gemv(&pinv, &b, &mut x);
        for i in 0..8 {
            assert!((x[i] - x_true[i]).abs() < 1e-2, "i={i}");
        }
    }

    #[test]
    fn classical_init_f64_solves_and_projector_tiny() {
        let a = randm(48, 16, 21);
        let mut g = seeded(22);
        let x_true: Vec<f32> = (0..16).map(|_| g.normal_f32()).collect();
        let mut b = vec![0.0f32; 48];
        crate::linalg::blas::gemv(&a, &x_true, &mut b);
        let (x0, p) = classical_init_f64(&a, &b).unwrap();
        for i in 0..16 {
            assert!((x0[i] - x_true[i]).abs() < 1e-3, "i={i}");
        }
        // f64 projector noise is far below f32 QR noise
        assert!(crate::linalg::norms::max_abs(p.as_slice()) < 1e-6);
        // rhs length check
        assert!(classical_init_f64(&a, &b[..10]).is_err());
    }

    #[test]
    fn classical_factorize_seed_split_bitwise_matches_init() {
        let a = randm(40, 12, 31);
        let mut g = seeded(32);
        let b: Vec<f32> = (0..40).map(|_| g.normal_f32()).collect();
        let (x0, p) = classical_init_f64(&a, &b).unwrap();
        let (ginv, p2) = classical_factorize_f64(&a).unwrap();
        let x02 = classical_seed_f64(&a, &ginv, &b).unwrap();
        assert_eq!(x0, x02);
        assert_eq!(p.as_slice(), p2.as_slice());
        // bad shapes are rejected, not UB
        assert!(classical_seed_f64(&a, &ginv, &b[..5]).is_err());
        assert!(classical_seed_f64(&a, &ginv[..7], &b).is_err());
    }

    #[test]
    fn property_inverse_roundtrip() {
        let mut g = seeded(77);
        for case in 0..15 {
            let n = g.gen_range(1, 32);
            let mut a = randm(n, n, 2000 + case);
            for i in 0..n {
                a[(i, i)] += n as f32 + 1.0;
            }
            let inv = gauss_jordan_inverse(&a).unwrap();
            let left = gemm(&inv, &a);
            let right = gemm(&a, &inv);
            assert!(left.max_abs_diff(&Matrix::eye(n)) < 1e-2, "case {case}");
            assert!(right.max_abs_diff(&Matrix::eye(n)) < 1e-2, "case {case}");
        }
    }
}
