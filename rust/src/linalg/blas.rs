//! Blocked BLAS-like primitives for the native engine.
//!
//! gemm uses i-k-j loop order with a register-blocked microkernel over the
//! contiguous row-major layout; gemv accumulates per-row dot products.  The
//! perf pass (EXPERIMENTS.md §Perf) tunes `MC`/`KC` against the end-to-end
//! solver benches.

use super::Matrix;

/// Cache-block sizes (rows of A / depth) for gemm.  Tuned in the perf pass.
const MC: usize = 64;
const KC: usize = 256;

/// `y += alpha * x` (axpy).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Dot product with f64 accumulation.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = 0.0f64;
    // 4-way unroll keeps the dependency chain short; LLVM vectorizes this.
    let chunks = x.len() / 4;
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for c in 0..chunks {
        let i = c * 4;
        a0 += x[i] as f64 * y[i] as f64;
        a1 += x[i + 1] as f64 * y[i + 1] as f64;
        a2 += x[i + 2] as f64 * y[i + 2] as f64;
        a3 += x[i + 3] as f64 * y[i + 3] as f64;
    }
    for i in chunks * 4..x.len() {
        acc += x[i] as f64 * y[i] as f64;
    }
    acc + a0 + a1 + a2 + a3
}

/// `y = A x` for row-major A (rows x cols), x of length cols.
pub fn gemv(a: &Matrix, x: &[f32], y: &mut [f32]) {
    assert_eq!(a.cols(), x.len());
    assert_eq!(a.rows(), y.len());
    for i in 0..a.rows() {
        y[i] = dot(a.row(i), x) as f32;
    }
}

/// `y = A^T x` for row-major A, x of length rows (avoids materializing A^T).
pub fn gemv_t(a: &Matrix, x: &[f32], y: &mut [f32]) {
    assert_eq!(a.rows(), x.len());
    assert_eq!(a.cols(), y.len());
    y.fill(0.0);
    for i in 0..a.rows() {
        axpy(x[i], a.row(i), y);
    }
}

/// `C = A B` (blocked, row-major).
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows());
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    for i0 in (0..m).step_by(MC) {
        let i1 = (i0 + MC).min(m);
        for k0 in (0..k).step_by(KC) {
            let k1 = (k0 + KC).min(k);
            for i in i0..i1 {
                let crow = c.row_mut(i);
                // borrow of a.row(i) is fine: a and c are distinct
                for kk in k0..k1 {
                    let aik = a[(i, kk)];
                    if aik != 0.0 {
                        axpy(aik, &b.row(kk)[..n], crow);
                    }
                }
            }
        }
    }
    c
}

/// `C = A^T B` without materializing the transpose.
pub fn gemm_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows());
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    for kk in 0..k {
        let arow = a.row(kk);
        let brow = b.row(kk);
        for i in 0..m {
            let aik = arow[i];
            if aik != 0.0 {
                axpy(aik, brow, c.row_mut(i));
            }
        }
    }
    c
}

/// Gram matrix `A^T A` exploiting symmetry (classical-APC init cost).
pub fn gram(a: &Matrix) -> Matrix {
    let n = a.cols();
    let mut g = Matrix::zeros(n, n);
    for r in 0..a.rows() {
        let row = a.row(r);
        for i in 0..n {
            let ri = row[i];
            if ri != 0.0 {
                // only the upper triangle
                for j in i..n {
                    g[(i, j)] += ri * row[j];
                }
            }
        }
    }
    for i in 0..n {
        for j in 0..i {
            g[(i, j)] = g[(j, i)];
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::seeded;

    fn randm(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut g = seeded(seed);
        Matrix::from_fn(rows, cols, |_, _| g.normal_f32())
    }

    fn naive_gemm(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0f64;
                for k in 0..a.cols() {
                    s += a[(i, k)] as f64 * b[(k, j)] as f64;
                }
                c[(i, j)] = s as f32;
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive() {
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 33, 9), (70, 300, 40)] {
            let a = randm(m, k, 1);
            let b = randm(k, n, 2);
            let c = gemm(&a, &b);
            assert!(c.max_abs_diff(&naive_gemm(&a, &b)) < 1e-3, "({m},{k},{n})");
        }
    }

    #[test]
    fn gemm_tn_matches_explicit_transpose() {
        let a = randm(20, 12, 3);
        let b = randm(20, 7, 4);
        let c = gemm_tn(&a, &b);
        let want = gemm(&a.transpose(), &b);
        assert!(c.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn gram_matches_gemm() {
        let a = randm(30, 10, 5);
        let g = gram(&a);
        let want = gemm(&a.transpose(), &a);
        assert!(g.max_abs_diff(&want) < 1e-3);
        // symmetric
        assert!(g.max_abs_diff(&g.transpose()) < 1e-9);
    }

    #[test]
    fn gemv_both_orientations() {
        let a = randm(9, 13, 6);
        let x: Vec<f32> = (0..13).map(|i| i as f32 * 0.1).collect();
        let mut y = vec![0.0; 9];
        gemv(&a, &x, &mut y);
        let xv = Matrix::from_vec(13, 1, x.clone());
        let want = gemm(&a, &xv);
        for i in 0..9 {
            assert!((y[i] - want[(i, 0)]).abs() < 1e-4);
        }

        let z: Vec<f32> = (0..9).map(|i| 1.0 - i as f32 * 0.2).collect();
        let mut w = vec![0.0; 13];
        gemv_t(&a, &z, &mut w);
        let zv = Matrix::from_vec(9, 1, z);
        let want_t = gemm(&a.transpose(), &zv);
        for i in 0..13 {
            assert!((w[i] - want_t[(i, 0)]).abs() < 1e-4);
        }
    }

    #[test]
    fn dot_f64_accumulation_stability() {
        // catastrophic in pure f32: 1e8 + tiny values
        let x = vec![1.0f32; 4096];
        let mut y = vec![1e-4f32; 4096];
        y[0] = 1e8;
        let d = dot(&x, &y);
        assert!((d - (1e8 + 4095.0 * 1e-4)).abs() / 1e8 < 1e-9);
    }

    #[test]
    fn axpy_basic() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [1.0f32, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
    }
}
