//! Multi-process-style distributed run: workers serve the DAPC protocol
//! over real TCP sockets, the leader connects and drives Algorithm 1
//! through the unified consensus driver (`solver::drive_apc`) over a
//! `ClusterBackend` — the analog of the paper's Dask SSHCluster
//! deployment, on the exact same epoch loop the single-process solvers
//! use.
//!
//! This example hosts the workers in-process threads for self-containment;
//! the identical code path runs across machines via the CLI:
//!
//! ```sh
//! dapc worker --listen 10.0.0.2:7001        # on each worker host
//! dapc solve --workers 10.0.0.2:7001,...    # on the leader
//! ```

use std::net::TcpListener;

use dapc::coordinator::cluster::{connect_tcp_workers, serve_tcp_worker};
use dapc::prelude::*;
use dapc::solver::{drive_apc, ApcVariant};
use dapc::sparse::generate::GeneratorConfig;

fn main() -> Result<()> {
    let j = 4;
    // reserve a port per worker
    let addrs: Vec<std::net::SocketAddr> = (0..j)
        .map(|_| {
            let l = TcpListener::bind("127.0.0.1:0").expect("bind");
            let a = l.local_addr().unwrap();
            drop(l);
            a
        })
        .collect();

    // spawn workers (each would be `dapc worker --listen ...` in production)
    let handles: Vec<_> = addrs
        .iter()
        .map(|&addr| {
            std::thread::spawn(move || {
                serve_tcp_worker(&NativeEngine::new(), addr)
            })
        })
        .collect();
    std::thread::sleep(std::time::Duration::from_millis(150));

    let ds = GeneratorConfig::schenk_like(512).generate(7);
    println!(
        "dataset {}x{}, J={j} TCP workers on {:?}",
        ds.matrix.rows(),
        ds.matrix.cols(),
        addrs
    );

    let addr_strings: Vec<String> = addrs.iter().map(|a| a.to_string()).collect();
    let mut leader = connect_tcp_workers(&addr_strings)?;
    // the same drive_apc the in-process solvers run — only the backend
    // (where each round executes) differs
    let report = drive_apc(
        leader.backend_mut(),
        &ds.matrix,
        &ds.rhs,
        ApcVariant::Decomposed,
        &SolveOptions { epochs: 60, ..Default::default() },
    )?;
    let (sent, received) = leader.wire_bytes();
    leader.shutdown();
    for h in handles {
        h.join().expect("worker thread")?;
    }

    println!("{}", report.summary());
    println!(
        "wire traffic: {:.2} MiB out, {:.2} MiB in ({} epochs)",
        sent as f64 / (1024.0 * 1024.0),
        received as f64 / (1024.0 * 1024.0),
        report.epochs,
    );
    println!("MSE vs known solution: {:.3e}", report.final_mse(&ds.x_true));
    assert!(report.final_mse(&ds.x_true) < 1e-5);
    println!("distributed_tcp OK");
    Ok(())
}
