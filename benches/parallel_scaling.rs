//! Parallel-engine scaling bench: decomposed-APC solve wall time,
//! sequential `NativeEngine` vs `ParallelEngine`, on a Table-1-shaped
//! system at J ∈ {2, 4, 8} partitions.
//!
//! Shapes follow the paper's smallest Table-1 row (9308 x 2327):
//! `DAPC_QUICK=1` runs 1/8 scale (CI smoke), default 1/4, `DAPC_FULL=1`
//! the exact published shape.  Both engines run through the unified
//! consensus driver (`drive_apc` over an `InProcessBackend` — the same
//! loop the distributed cluster uses).  Besides wall times the bench
//! verifies the two engines produce *identical* solutions (the parallel
//! engine is deterministic by construction) and writes machine-readable
//! results to `BENCH_parallel_scaling.json`.

use dapc::benchkit::{full_mode, quick_mode, Bench, JsonReport};
use dapc::linalg::norms;
use dapc::metrics::TableBuilder;
use dapc::parallel::default_threads;
use dapc::prelude::*;
use dapc::solver::{drive_apc, ApcVariant, InProcessBackend};
use dapc::sparse::generate::GeneratorConfig;

fn main() {
    let (scale, epochs) = if full_mode() {
        (1, 80)
    } else if quick_mode() {
        (8, 15)
    } else {
        (4, 40)
    };
    let (m, n) = (9308 / scale, 2327 / scale);
    let shape = format!("{m}x{n}");
    let ds = GeneratorConfig::table1(m, n).generate(2327);
    let bench = Bench::default();
    let mut report = JsonReport::new("parallel_scaling");

    let mut thread_counts = vec![2usize, 4];
    let avail = default_threads();
    if avail > 4 {
        thread_counts.push(avail);
    }

    println!(
        "=== parallel scaling: decomposed APC, {shape}, T={epochs}, \
         J in {{2,4,8}}, threads {thread_counts:?} (avail {avail}) ==="
    );
    let mut headers: Vec<String> = vec!["J".into(), "sequential".into()];
    for &t in &thread_counts {
        headers.push(format!("{t} threads"));
    }
    headers.push("best speedup".into());
    let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut table = TableBuilder::new(&header_refs);

    for &j in &[2usize, 4, 8] {
        let opts = SolveOptions { epochs, ..Default::default() };
        let seq_engine = NativeEngine::new();
        let mut seq_xbar: Vec<f32> = Vec::new();
        let rs = bench.run_once(&format!("sequential   J={j}"), || {
            let mut backend = InProcessBackend::new(&seq_engine, j);
            let r = drive_apc(
                &mut backend,
                &ds.matrix,
                &ds.rhs,
                ApcVariant::Decomposed,
                &opts,
            )
            .expect("sequential solve");
            seq_xbar = r.xbar;
        });
        report.add(
            &rs,
            &[("threads", 1.0), ("j", j as f64), ("epochs", epochs as f64)],
            &[("shape", shape.as_str()), ("engine", "native")],
        );

        let mut row = vec![format!("{j}"), format!("{:.3}s", rs.stats.mean())];
        let mut best_speedup = 0.0f64;
        for &t in &thread_counts {
            let engine = ParallelEngine::new(t);
            let mut par_xbar: Vec<f32> = Vec::new();
            let rp = bench.run_once(&format!("parallel t={t} J={j}"), || {
                let mut backend = InProcessBackend::new(&engine, j);
                let r = drive_apc(
                    &mut backend,
                    &ds.matrix,
                    &ds.rhs,
                    ApcVariant::Decomposed,
                    &opts,
                )
                .expect("parallel solve");
                par_xbar = r.xbar;
            });
            // the parallel engine runs the same kernels in the same
            // order as the reference; anything above f32-ULP noise on a
            // handful of elements means a real divergence
            let drift = norms::mse(&seq_xbar, &par_xbar);
            assert!(
                drift < 1e-12,
                "parallel engine diverged from sequential at J={j}, \
                 t={t}: mse {drift:e}"
            );
            let speedup = rs.stats.mean() / rp.stats.mean();
            best_speedup = best_speedup.max(speedup);
            println!("  -> J={j} threads={t}: speedup {speedup:.2}x");
            report.add(
                &rp,
                &[
                    ("threads", t as f64),
                    ("j", j as f64),
                    ("epochs", epochs as f64),
                    ("speedup_vs_sequential", speedup),
                ],
                &[("shape", shape.as_str()), ("engine", "parallel")],
            );
            row.push(format!("{:.3}s ({speedup:.2}x)", rp.stats.mean()));
        }
        row.push(format!("{best_speedup:.2}x"));
        table.row(&row);
    }

    println!("\n{}", table.render());
    match report.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
}
