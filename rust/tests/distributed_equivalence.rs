//! Backend equivalence: the distributed `ClusterBackend` (over a local
//! channel cluster) must produce BIT-IDENTICAL results to the in-process
//! backend for every algorithm, partition count and regime.
//!
//! This is the contract that makes the unified driver safe: eq. (7) runs
//! as a fixed-order f64 reduction on both sides of the topology split
//! (engine kernel in-process, driver-side mixing over the streamed
//! accumulator for the cluster), so `assert_eq!` on the f32 outputs —
//! not a tolerance — is the right check.

use dapc::coordinator::LocalCluster;
use dapc::linalg::Matrix;
use dapc::rng::seeded;
use dapc::service::{
    SessionAlgorithm, SessionConfig, SessionManager, SolverSession,
};
use dapc::solver::{
    drive_apc, drive_dgd, ApcVariant, InProcessBackend, NativeEngine,
    SessionBackend, SolveOptions, SolveReport,
};
use dapc::sparse::CsrMatrix;

/// A consistent system `A x = b` with a few exact zeros so the CSR is
/// genuinely sparse-ish.
fn consistent_system(m: usize, n: usize, seed: u64) -> (CsrMatrix, Vec<f32>) {
    let mut g = seeded(seed);
    let dense = Matrix::from_fn(m, n, |i, j| {
        if (i + j) % 7 == 0 {
            0.0
        } else {
            g.normal_f32()
        }
    });
    let x: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
    let mut b = vec![0.0f32; m];
    dapc::linalg::blas::gemv(&dense, &x, &mut b);
    (CsrMatrix::from_dense(&dense), b)
}

fn in_process_apc(
    a: &CsrMatrix,
    b: &[f32],
    j: usize,
    variant: ApcVariant,
    opts: &SolveOptions,
) -> SolveReport {
    let engine = NativeEngine::new();
    let mut backend = InProcessBackend::new(&engine, j);
    drive_apc(&mut backend, a, b, variant, opts).expect("in-process solve")
}

fn cluster_apc(
    a: &CsrMatrix,
    b: &[f32],
    j: usize,
    variant: ApcVariant,
    opts: &SolveOptions,
) -> SolveReport {
    let mut cluster =
        LocalCluster::spawn(j, NativeEngine::new).expect("cluster");
    drive_apc(cluster.leader.backend_mut(), a, b, variant, opts)
        .expect("cluster solve")
}

fn assert_apc_equivalent(m: usize, n: usize, j: usize, seed: u64) {
    let (a, b) = consistent_system(m, n, seed);
    for variant in [ApcVariant::Decomposed, ApcVariant::Classical] {
        let opts = SolveOptions {
            epochs: 25,
            collect_x_parts: true,
            ..Default::default()
        };
        let local = in_process_apc(&a, &b, j, variant, &opts);
        let dist = cluster_apc(&a, &b, j, variant, &opts);
        assert_eq!(
            local.xbar, dist.xbar,
            "xbar diverged: {m}x{n} J={j} {variant:?}"
        );
        assert_eq!(
            local.x_parts, dist.x_parts,
            "x_parts diverged: {m}x{n} J={j} {variant:?}"
        );
        assert_eq!(local.algorithm, dist.algorithm);
        // residual is computed leader-side from identical xbar
        assert_eq!(local.residual, dist.residual);
    }
}

#[test]
fn apc_bit_identical_even_split() {
    // m divisible by every J: uniform blocks
    assert_apc_equivalent(96, 10, 1, 1);
    assert_apc_equivalent(96, 10, 3, 2);
    assert_apc_equivalent(96, 10, 4, 3);
}

#[test]
fn apc_bit_identical_ragged_partitions() {
    // m = 103: the last block absorbs the remainder (28 rows at J=4,
    // 35 at J=3) — tall regime since every block has >= n = 10 rows
    assert_apc_equivalent(103, 10, 1, 4);
    assert_apc_equivalent(103, 10, 3, 5);
    assert_apc_equivalent(103, 10, 4, 6);
}

#[test]
fn apc_bit_identical_fat_regime() {
    // blocks of 15 rows < n = 32: genuine nullspace projectors, the
    // consensus loop does real work (original-APC setting)
    assert_apc_equivalent(60, 32, 4, 7);
    // and a ragged fat split
    assert_apc_equivalent(65, 32, 3, 8);
}

#[test]
fn dgd_bit_identical_across_backends() {
    for &(m, n, j, seed) in
        &[(96usize, 10usize, 1usize, 10u64), (103, 10, 3, 11), (103, 10, 4, 12)]
    {
        let (a, b) = consistent_system(m, n, seed);
        // auto step (dgd_step <= 0) exercises the shared driver-side
        // Gershgorin bound on both backends
        let opts = SolveOptions {
            epochs: 40,
            dgd_step: 0.0,
            collect_x_parts: true,
            ..Default::default()
        };

        let engine = NativeEngine::new();
        let mut local_backend = InProcessBackend::new(&engine, j);
        let local =
            drive_dgd(&mut local_backend, &a, &b, &opts).expect("local dgd");

        let mut cluster =
            LocalCluster::spawn(j, NativeEngine::new).expect("cluster");
        let dist = drive_dgd(cluster.leader.backend_mut(), &a, &b, &opts)
            .expect("cluster dgd");

        assert_eq!(local.xbar, dist.xbar, "dgd diverged: {m}x{n} J={j}");
        assert_eq!(local.residual, dist.residual);
    }
}

#[test]
fn traces_match_point_for_point() {
    // per-epoch MSE traces are computed by the one driver, from
    // bit-identical iterates -> identical floats at every epoch
    let (a, b) = consistent_system(96, 10, 20);
    let mut g = seeded(21);
    let x_true: Vec<f32> = (0..10).map(|_| g.normal_f32()).collect();
    // x_true here is only a trace reference, not the system's solution
    let opts = SolveOptions {
        epochs: 15,
        x_true: Some(x_true),
        ..Default::default()
    };
    let local = in_process_apc(&a, &b, 3, ApcVariant::Decomposed, &opts);
    let dist = cluster_apc(&a, &b, 3, ApcVariant::Decomposed, &opts);
    let lt = local.trace.expect("local trace");
    let dt = dist.trace.expect("cluster trace");
    assert_eq!(lt.points, dt.points);
}

// ---------------------------------------------------------------------------
// Warm-session suite: a session solve must be assert_eq!-bit-identical
// to a cold one-shot solve, and a batch of k to k sequential solves, on
// BOTH backends.  Seeding re-runs the cold init's exact arithmetic over
// the retained factorization, and the batched kernel keeps `dot`'s f64
// accumulation order per column — these tests lock that contract in.
// ---------------------------------------------------------------------------

/// Generate `k` distinct consistent right-hand sides for `a`.
fn rhs_stream(a: &CsrMatrix, k: usize, seed: u64) -> Vec<Vec<f32>> {
    (0..k)
        .map(|i| {
            let mut g = seeded(seed + i as u64);
            let x: Vec<f32> =
                (0..a.cols()).map(|_| g.normal_f32()).collect();
            let mut b = vec![0.0f32; a.rows()];
            a.spmv_into(&x, &mut b);
            b
        })
        .collect()
}

fn warm_session_solves<B: SessionBackend + ?Sized>(
    backend: &mut B,
    a: &CsrMatrix,
    algo: SessionAlgorithm,
    opts: &SolveOptions,
    bs: &[Vec<f32>],
) -> Vec<SolveReport> {
    let config = SessionConfig::new(algo).options(opts.clone());
    let mut session = SolverSession::register(backend, a.clone(), config)
        .expect("register");
    bs.iter().map(|b| session.solve(b).expect("warm solve")).collect()
}

fn warm_session_batch<B: SessionBackend + ?Sized>(
    backend: &mut B,
    a: &CsrMatrix,
    algo: SessionAlgorithm,
    opts: &SolveOptions,
    bs: &[Vec<f32>],
) -> Vec<SolveReport> {
    let config = SessionConfig::new(algo).options(opts.clone());
    let mut session = SolverSession::register(backend, a.clone(), config)
        .expect("register");
    session.solve_batch(bs).expect("batched solve")
}

fn assert_warm_session_equivalent(
    m: usize,
    n: usize,
    j: usize,
    seed: u64,
    variant: ApcVariant,
) {
    let (a, _) = consistent_system(m, n, seed);
    let bs = rhs_stream(&a, 3, seed * 100);
    let algo = SessionAlgorithm::Apc(variant);
    let opts = SolveOptions { epochs: 20, ..Default::default() };
    let engine = NativeEngine::new();

    // cold one-shot reference per rhs (in-process backend)
    let colds: Vec<SolveReport> = bs
        .iter()
        .map(|b| {
            let mut backend = InProcessBackend::new(&engine, j);
            drive_apc(&mut backend, &a, b, variant, &opts).expect("cold")
        })
        .collect();

    // warm in-process session: stream the three rhs
    let mut backend = InProcessBackend::new(&engine, j);
    let warms = warm_session_solves(&mut backend, &a, algo, &opts, &bs);
    for (cold, warm) in colds.iter().zip(&warms) {
        assert_eq!(warm.xbar, cold.xbar, "{m}x{n} J={j} {variant:?} warm");
        assert_eq!(warm.residual, cold.residual);
    }

    // warm cluster session over local channel workers
    let mut cluster = LocalCluster::spawn(j, NativeEngine::new).expect("cluster");
    let dist_warms = warm_session_solves(
        cluster.leader.backend_mut(),
        &a,
        algo,
        &opts,
        &bs,
    );
    for (cold, warm) in colds.iter().zip(&dist_warms) {
        assert_eq!(
            warm.xbar, cold.xbar,
            "{m}x{n} J={j} {variant:?} cluster warm"
        );
        assert_eq!(warm.residual, cold.residual);
    }

    // one k=3 batch vs the 3 sequential solves, both backends
    let mut backend = InProcessBackend::new(&engine, j);
    let batch = warm_session_batch(&mut backend, &a, algo, &opts, &bs);
    let mut cluster2 =
        LocalCluster::spawn(j, NativeEngine::new).expect("cluster");
    let dist_batch = warm_session_batch(
        cluster2.leader.backend_mut(),
        &a,
        algo,
        &opts,
        &bs,
    );
    for c in 0..bs.len() {
        assert_eq!(
            batch[c].xbar, colds[c].xbar,
            "{m}x{n} J={j} {variant:?} batch col {c}"
        );
        assert_eq!(
            dist_batch[c].xbar, colds[c].xbar,
            "{m}x{n} J={j} {variant:?} cluster batch col {c}"
        );
        assert_eq!(batch[c].residual, colds[c].residual);
        assert_eq!(dist_batch[c].residual, colds[c].residual);
    }
}

#[test]
fn warm_session_apc_decomposed_bit_identical_to_cold() {
    assert_warm_session_equivalent(96, 10, 3, 41, ApcVariant::Decomposed);
    // ragged split
    assert_warm_session_equivalent(103, 10, 4, 42, ApcVariant::Decomposed);
}

#[test]
fn warm_session_apc_classical_bit_identical_to_cold() {
    assert_warm_session_equivalent(96, 10, 3, 43, ApcVariant::Classical);
}

#[test]
fn warm_session_fat_regime_bit_identical_to_cold() {
    // 15-row blocks < n = 32: genuine projectors, the batched consensus
    // loop does real work
    assert_warm_session_equivalent(60, 32, 4, 44, ApcVariant::Decomposed);
}

#[test]
fn warm_session_dgd_bit_identical_to_cold() {
    let (a, _) = consistent_system(96, 10, 45);
    let bs = rhs_stream(&a, 3, 4500);
    let opts = SolveOptions {
        epochs: 30,
        dgd_step: 0.0, // auto step, resolved identically on both paths
        ..Default::default()
    };
    let engine = NativeEngine::new();
    let j = 3;

    let colds: Vec<SolveReport> = bs
        .iter()
        .map(|b| {
            let mut backend = InProcessBackend::new(&engine, j);
            drive_dgd(&mut backend, &a, b, &opts).expect("cold dgd")
        })
        .collect();

    let mut backend = InProcessBackend::new(&engine, j);
    let warms = warm_session_solves(
        &mut backend,
        &a,
        SessionAlgorithm::Dgd,
        &opts,
        &bs,
    );
    let mut cluster = LocalCluster::spawn(j, NativeEngine::new).expect("cluster");
    let dist_warms = warm_session_solves(
        cluster.leader.backend_mut(),
        &a,
        SessionAlgorithm::Dgd,
        &opts,
        &bs,
    );
    let mut backend2 = InProcessBackend::new(&engine, j);
    let batch = warm_session_batch(
        &mut backend2,
        &a,
        SessionAlgorithm::Dgd,
        &opts,
        &bs,
    );
    for c in 0..bs.len() {
        assert_eq!(warms[c].xbar, colds[c].xbar, "dgd warm col {c}");
        assert_eq!(dist_warms[c].xbar, colds[c].xbar, "dgd cluster col {c}");
        assert_eq!(batch[c].xbar, colds[c].xbar, "dgd batch col {c}");
        assert_eq!(warms[c].residual, colds[c].residual);
    }
}

#[test]
fn warm_session_interleaved_stream_stays_stateless_per_rhs() {
    // serving b0, b1, then b0 again must reproduce b0's first answer
    // exactly: nothing of a previous solve may leak into the next seed
    let (a, _) = consistent_system(96, 10, 46);
    let bs = rhs_stream(&a, 2, 4600);
    let opts = SolveOptions { epochs: 15, ..Default::default() };
    let engine = NativeEngine::new();
    let mut backend = InProcessBackend::new(&engine, 3);
    let mut session = SolverSession::register(
        &mut backend,
        a.clone(),
        SessionConfig::apc(ApcVariant::Decomposed).options(opts),
    )
    .expect("register");
    let first = session.solve(&bs[0]).expect("b0");
    let _ = session.solve(&bs[1]).expect("b1");
    let again = session.solve(&bs[0]).expect("b0 again");
    assert_eq!(first.xbar, again.xbar);
    assert_eq!(session.stats().rhs_served, 3);
}

// ---------------------------------------------------------------------------
// Multi-tenant suite: requests interleaved across MANY sessions over ONE
// backend must stay bitwise identical to isolated single-session runs,
// on the in-process and cluster backends alike; under a resident-memory
// cap, LRU eviction must never change a single bit while the resident
// total stays under the cap at every step (the ISSUE's acceptance
// criteria for the session-manager tentpole).
// ---------------------------------------------------------------------------

/// (matrix, per-tenant config, rhs stream, isolated expected xbars).
type TenantSpec<'a> =
    (&'a CsrMatrix, SessionConfig, &'a [Vec<f32>], &'a [Vec<f32>]);

/// Isolated reference: a fresh single-session backend per tenant.
fn isolated_xbars(
    a: &CsrMatrix,
    config: &SessionConfig,
    bs: &[Vec<f32>],
    j: usize,
) -> Vec<Vec<f32>> {
    let engine = NativeEngine::new();
    let mut backend = InProcessBackend::new(&engine, j);
    let mut session =
        SolverSession::register(&mut backend, a.clone(), config.clone())
            .expect("isolated register");
    bs.iter().map(|b| session.solve(b).expect("isolated").xbar).collect()
}

/// Register every tenant into one manager and serve the rhs streams in
/// strict round-robin, asserting each reply against the tenant's
/// isolated reference (and the cap, when set).  Returns the eviction
/// count.
fn run_interleaved<B: SessionBackend + ?Sized>(
    backend: &mut B,
    cap: Option<u64>,
    tenants: &[TenantSpec<'_>],
) -> u64 {
    let mut mgr = match cap {
        Some(c) => SessionManager::with_memory_cap(backend, c),
        None => SessionManager::new(backend),
    };
    let sids: Vec<u64> = tenants
        .iter()
        .map(|(a, c, _, _)| {
            mgr.register((*a).clone(), c.clone()).expect("register")
        })
        .collect();
    let rounds = tenants[0].2.len();
    for r in 0..rounds {
        for (i, (_, _, bs, expect)) in tenants.iter().enumerate() {
            let got = mgr.solve(sids[i], &bs[r]).expect("managed solve");
            assert_eq!(
                got.xbar, expect[r],
                "tenant {i} rhs {r}: interleaved solve diverged from the \
                 isolated session"
            );
            if let Some(c) = cap {
                assert!(
                    mgr.resident_bytes() <= c,
                    "resident bytes {} exceed the cap {c}",
                    mgr.resident_bytes()
                );
            }
        }
    }
    mgr.evictions()
}

#[test]
fn interleaved_sessions_bitwise_match_isolated_on_both_backends() {
    let (a1, _) = consistent_system(96, 10, 71);
    let (a2, _) = consistent_system(103, 12, 72);
    let bs1 = rhs_stream(&a1, 2, 7100);
    let bs2 = rhs_stream(&a2, 2, 7200);
    let j = 3;
    let apc = SessionConfig::apc(ApcVariant::Decomposed)
        .partitions(j)
        .epochs(15);
    let dgd = SessionConfig::dgd().partitions(j).epochs(25);

    let e1 = isolated_xbars(&a1, &apc, &bs1, j);
    let e2 = isolated_xbars(&a2, &apc, &bs2, j);
    // a heterogeneous third tenant: DGD multiplexed next to two APCs
    let e3 = isolated_xbars(&a1, &dgd, &bs1, j);
    let tenants: Vec<TenantSpec<'_>> = vec![
        (&a1, apc.clone(), &bs1, &e1),
        (&a2, apc.clone(), &bs2, &e2),
        (&a1, dgd, &bs1, &e3),
    ];

    let engine = NativeEngine::new();
    let mut backend = InProcessBackend::new(&engine, j);
    assert_eq!(run_interleaved(&mut backend, None, &tenants), 0);

    let mut cluster =
        LocalCluster::spawn(j, NativeEngine::new).expect("cluster");
    assert_eq!(
        run_interleaved(cluster.leader.backend_mut(), None, &tenants),
        0
    );
}

#[test]
fn capped_eviction_reproduces_solves_bitwise_on_both_backends() {
    let (a1, _) = consistent_system(96, 10, 73);
    let (a2, _) = consistent_system(103, 12, 74);
    let bs1 = rhs_stream(&a1, 2, 7300);
    let bs2 = rhs_stream(&a2, 2, 7400);
    let j = 3;
    let config = SessionConfig::apc(ApcVariant::Decomposed)
        .partitions(j)
        .epochs(12);
    let e1 = isolated_xbars(&a1, &config, &bs1, j);
    let e2 = isolated_xbars(&a2, &config, &bs2, j);

    // learn each tenant's resident footprint from uncapped managers
    let engine = NativeEngine::new();
    let footprint = |a: &CsrMatrix| -> u64 {
        let mut b = InProcessBackend::new(&engine, j);
        let mut m = SessionManager::new(&mut b);
        m.register(a.clone(), config.clone()).expect("probe register");
        m.resident_bytes()
    };
    let (f1, f2) = (footprint(&a1), footprint(&a2));
    assert!(f1 > 0 && f2 > 0);
    // cap holds EITHER session alone but never both: every cross-session
    // solve forces an eviction and a transparent re-factorization
    let cap = f1.max(f2) + f1.min(f2) / 2;
    assert!(cap < f1 + f2);

    let tenants: Vec<TenantSpec<'_>> = vec![
        (&a1, config.clone(), &bs1, &e1),
        (&a2, config.clone(), &bs2, &e2),
    ];
    let mut backend = InProcessBackend::new(&engine, j);
    let local_evictions =
        run_interleaved(&mut backend, Some(cap), &tenants);
    assert!(
        local_evictions >= 3,
        "thrashing cap must evict on every cross-session hop, got \
         {local_evictions}"
    );

    let mut cluster =
        LocalCluster::spawn(j, NativeEngine::new).expect("cluster");
    let dist_evictions = run_interleaved(
        cluster.leader.backend_mut(),
        Some(cap),
        &tenants,
    );
    assert_eq!(local_evictions, dist_evictions, "eviction schedules differ");
}

#[test]
fn solver_facades_match_driver() {
    // DapcSolver is a facade over the same driver + in-process backend
    use dapc::solver::{DapcSolver, Solver};
    let (a, b) = consistent_system(96, 10, 30);
    let opts = SolveOptions { epochs: 20, ..Default::default() };
    let via_facade = DapcSolver::new(opts.clone())
        .solve(&NativeEngine::new(), &a, &b, 3)
        .unwrap();
    let via_driver = in_process_apc(&a, &b, 3, ApcVariant::Decomposed, &opts);
    assert_eq!(via_facade.xbar, via_driver.xbar);
}
