//! Cross-layer integration tests: the XLA engine (AOT HLO artifacts from
//! python/compile, executed via PJRT) must agree with the native Rust
//! engine on every operation and on full solver runs, and the distributed
//! coordinator must agree with the single-process path.
//!
//! These tests require `make artifacts` to have been run; they are skipped
//! (not failed) when `artifacts/manifest.json` is absent so unit-level CI
//! stays hermetic.

use std::path::{Path, PathBuf};

use dapc::linalg::{norms, Matrix};
use dapc::rng::seeded;
use dapc::runtime::executor::XlaExecutorHost;
use dapc::solver::{
    ApcClassicalSolver, ApcVariant, ComputeEngine, DapcSolver, DgdSolver,
    InitKind, NativeEngine, SolveOptions, Solver, XlaEngine,
};
use dapc::sparse::generate::GeneratorConfig;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built");
                return;
            }
        }
    };
}

fn xla_engine(dir: &Path) -> (XlaExecutorHost, XlaEngine) {
    let host = XlaExecutorHost::spawn(dir).expect("spawn pjrt executor");
    let engine = XlaEngine::new(host.executor());
    (host, engine)
}

fn consistent_block(l: usize, n: usize, seed: u64) -> (Matrix, Vec<f32>, Vec<f32>) {
    let mut g = seeded(seed);
    let a = Matrix::from_fn(l, n, |_, _| g.normal_f32());
    let x: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
    let mut b = vec![0.0f32; l];
    dapc::linalg::blas::gemv(&a, &x, &mut b);
    (a, b, x)
}

#[test]
fn xla_init_qr_matches_native() {
    let dir = require_artifacts!();
    let (_host, xla) = xla_engine(&dir);
    let native = NativeEngine::new();
    let (a, b, x_true) = consistent_block(48, 32, 1);

    let wx = xla.init(InitKind::Qr, &a, &b, 32).unwrap();
    let wn = native.init(InitKind::Qr, &a, &b, 32).unwrap();
    // both solve the consistent system
    for i in 0..32 {
        assert!((wx.x0[i] - x_true[i]).abs() < 1e-2, "xla x0[{i}]");
        assert!((wx.x0[i] - wn.x0[i]).abs() < 1e-2, "xla vs native x0[{i}]");
    }
    // tall-regime projector is rounding noise in both engines
    assert!(norms::max_abs(wx.projector.as_slice()) < 1e-3);
    assert!(norms::max_abs(wn.projector.as_slice()) < 1e-3);
}

#[test]
fn xla_init_classical_matches_native() {
    let dir = require_artifacts!();
    let (_host, xla) = xla_engine(&dir);
    let native = NativeEngine::new();
    let (a, b, _) = consistent_block(40, 32, 2);
    let wx = xla.init(InitKind::Classical, &a, &b, 32).unwrap();
    let wn = native.init(InitKind::Classical, &a, &b, 32).unwrap();
    for i in 0..32 {
        assert!((wx.x0[i] - wn.x0[i]).abs() < 5e-2, "x0[{i}]");
    }
}

#[test]
fn xla_init_fat_matches_native() {
    let dir = require_artifacts!();
    let (_host, xla) = xla_engine(&dir);
    let native = NativeEngine::new();
    // fat bucket in the default manifest: (l=32, n=128)
    let (a, b, _) = consistent_block(32, 128, 3);
    let wx = xla.init(InitKind::Fat, &a, &b, 128).unwrap();
    let wn = native.init(InitKind::Fat, &a, &b, 128).unwrap();
    // min-norm solutions agree
    for i in 0..128 {
        assert!((wx.x0[i] - wn.x0[i]).abs() < 1e-2, "x0[{i}]");
    }
    // genuine projectors agree
    assert!(wx.projector.max_abs_diff(&wn.projector) < 1e-2);
}

#[test]
fn xla_update_average_round_match_native() {
    let dir = require_artifacts!();
    let (_host, xla) = xla_engine(&dir);
    let native = NativeEngine::new();
    let mut g = seeded(4);
    let n = 32;
    let j = 2;
    let xs: Vec<Vec<f32>> = (0..j)
        .map(|_| (0..n).map(|_| g.normal_f32()).collect())
        .collect();
    let xbar: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
    let ps: Vec<Matrix> = (0..j)
        .map(|k| Matrix::from_fn(n, n, |_, _| 0.05 * (k as f32 + 1.0) * g.normal_f32()))
        .collect();

    let ux = xla.update(&xs[0], &xbar, &ps[0], 0.7).unwrap();
    let un = native.update(&xs[0], &xbar, &ps[0], 0.7).unwrap();
    assert!(norms::mae(&ux, &un) < 1e-5, "update mismatch");

    let ax = xla.average(&xs, &xbar, 0.4).unwrap();
    let an = native.average(&xs, &xbar, 0.4).unwrap();
    assert!(norms::mae(&ax, &an) < 1e-6, "average mismatch");

    let (rx, rbx) = xla.round(&xs, &xbar, &ps, 0.7, 0.4).unwrap();
    let (rn, rbn) = native.round(&xs, &xbar, &ps, 0.7, 0.4).unwrap();
    for k in 0..j {
        assert!(norms::mae(&rx[k], &rn[k]) < 1e-5, "round x[{k}]");
    }
    assert!(norms::mae(&rbx, &rbn) < 1e-5, "round xbar");
}

#[test]
fn xla_fused_loop_matches_iterated_rounds() {
    let dir = require_artifacts!();
    let (_host, mut xla) = xla_engine(&dir);
    xla.fused_loop = true;
    let native = NativeEngine::new();
    let mut g = seeded(5);
    let (n, j, t) = (32, 2, 9);
    let xs: Vec<Vec<f32>> = (0..j)
        .map(|_| (0..n).map(|_| g.normal_f32()).collect())
        .collect();
    let xbar: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
    let ps: Vec<Matrix> =
        (0..j).map(|_| Matrix::from_fn(n, n, |_, _| 0.05 * g.normal_f32())).collect();

    let fused = xla
        .solve_loop(&xs, &xbar, &ps, 0.6, 0.5, t)
        .unwrap()
        .expect("solve artifact available");
    let mut ns = xs.clone();
    let mut nb = xbar.clone();
    for _ in 0..t {
        let (a, b2) = native.round(&ns, &nb, &ps, 0.6, 0.5).unwrap();
        ns = a;
        nb = b2;
    }
    assert!(norms::mae(&fused.1, &nb) < 1e-4, "fused loop diverged");
}

#[test]
fn xla_dgd_grad_matches_native_with_padding() {
    let dir = require_artifacts!();
    let (_host, xla) = xla_engine(&dir);
    let native = NativeEngine::new();
    // 42x30 does NOT match any artifact exactly -> exercises pad path
    let (a, b, _) = consistent_block(42, 30, 6);
    let mut g = seeded(7);
    let x: Vec<f32> = (0..30).map(|_| g.normal_f32()).collect();
    let gx = xla.dgd_grad(&a, &x, &b).unwrap();
    let gn = native.dgd_grad(&a, &x, &b).unwrap();
    assert_eq!(gx.len(), 30);
    assert!(norms::mae(&gx, &gn) < 1e-3);
}

#[test]
fn full_dapc_solve_on_xla_engine() {
    let dir = require_artifacts!();
    let (_host, xla) = xla_engine(&dir);
    // n=32 so blocks pad into the (64, 32) init bucket
    let ds = GeneratorConfig::small_demo(32, 3).generate(11);
    let solver = DapcSolver::new(SolveOptions {
        epochs: 30,
        x_true: Some(ds.x_true.clone()),
        ..Default::default()
    });
    let report = solver.solve(&xla, &ds.matrix, &ds.rhs, 3).unwrap();
    assert_eq!(report.engine, "xla");
    let mse = report.final_mse(&ds.x_true);
    assert!(mse < 1e-5, "mse {mse}");
}

#[test]
fn xla_and_native_solvers_agree_end_to_end() {
    let dir = require_artifacts!();
    let (_host, xla) = xla_engine(&dir);
    let native = NativeEngine::new();
    let ds = GeneratorConfig::small_demo(32, 2).generate(12);
    let opts = SolveOptions { epochs: 20, ..Default::default() };

    let rx = DapcSolver::new(opts.clone())
        .solve(&xla, &ds.matrix, &ds.rhs, 2)
        .unwrap();
    let rn = DapcSolver::new(opts)
        .solve(&native, &ds.matrix, &ds.rhs, 2)
        .unwrap();
    assert!(
        norms::mse(&rx.xbar, &rn.xbar) < 1e-8,
        "engines diverged: {:e}",
        norms::mse(&rx.xbar, &rn.xbar)
    );
}

#[test]
fn classical_solver_on_xla_engine() {
    let dir = require_artifacts!();
    let (_host, xla) = xla_engine(&dir);
    let ds = GeneratorConfig::small_demo(32, 2).generate(13);
    let report = ApcClassicalSolver::new(SolveOptions {
        epochs: 20,
        ..Default::default()
    })
    .solve(&xla, &ds.matrix, &ds.rhs, 2)
    .unwrap();
    assert!(report.final_mse(&ds.x_true) < 1e-4);
}

#[test]
fn dgd_solver_on_xla_engine() {
    let dir = require_artifacts!();
    let (_host, xla) = xla_engine(&dir);
    let ds = GeneratorConfig::small_demo(32, 2).generate(14);
    let report = DgdSolver::new(SolveOptions {
        epochs: 150,
        dgd_step: 0.0,
        x_true: Some(ds.x_true.clone()),
        ..Default::default()
    })
    .solve(&xla, &ds.matrix, &ds.rhs, 2)
    .unwrap();
    let tr = report.trace.unwrap();
    assert!(tr.final_mse().unwrap() < tr.initial_mse().unwrap() * 0.5);
}

#[test]
fn distributed_cluster_with_xla_engine() {
    let dir = require_artifacts!();
    let host = XlaExecutorHost::spawn(&dir).unwrap();
    let exec = host.executor();
    let ds = GeneratorConfig::small_demo(32, 2).generate(15);
    let mut cluster = dapc::coordinator::LocalCluster::spawn(2, move || {
        XlaEngine::new(exec.clone())
    })
    .unwrap();
    let report = cluster
        .leader
        .solve_apc(
            &ds.matrix,
            &ds.rhs,
            ApcVariant::Decomposed,
            &SolveOptions { epochs: 20, ..Default::default() },
        )
        .unwrap();
    assert!(report.final_mse(&ds.x_true) < 1e-5);
}

#[test]
fn convergence_shape_matches_figure2() {
    // Fig. 2 qualitative shape on either engine: decomposed starts no
    // better than classical, both reach the same plateau, DGD is slower.
    let dir = require_artifacts!();
    let (_host, xla) = xla_engine(&dir);
    let ds = GeneratorConfig::small_demo(32, 2).generate(16);
    let t = 30;
    let mk = |x_true: &Vec<f32>| SolveOptions {
        epochs: t,
        x_true: Some(x_true.clone()),
        ..Default::default()
    };
    let dec = DapcSolver::new(mk(&ds.x_true))
        .solve(&xla, &ds.matrix, &ds.rhs, 2)
        .unwrap();
    let cls = ApcClassicalSolver::new(mk(&ds.x_true))
        .solve(&xla, &ds.matrix, &ds.rhs, 2)
        .unwrap();
    let dgd = DgdSolver::new(SolveOptions {
        epochs: t,
        dgd_step: 0.0,
        x_true: Some(ds.x_true.clone()),
        ..Default::default()
    })
    .solve(&xla, &ds.matrix, &ds.rhs, 2)
    .unwrap();

    let d = dec.trace.unwrap();
    let c = cls.trace.unwrap();
    let gtrace = dgd.trace.unwrap();
    // both APC variants converge to ~the same minima (paper §4)
    let df = d.final_mse().unwrap();
    let cf = c.final_mse().unwrap();
    assert!(df < 1e-6 && cf < 1e-4, "df={df:e} cf={cf:e}");
    // DGD is far from the APC plateau at the same epoch budget
    assert!(gtrace.final_mse().unwrap() > df * 10.0);
}
