// Seeded violation: HashMap/HashSet outside runtime/ — iteration order
// is nondeterministic, the house types are BTreeMap/BTreeSet.
use std::collections::HashMap;

pub fn histogram(words: &[&str]) -> usize {
    let mut counts: HashMap<&str, usize> = HashMap::new();
    for w in words {
        *counts.entry(w).or_insert(0) += 1;
    }
    counts.len()
}
