//! Ablation: worker-init cost by method — the core of the paper's claim.
//!
//! Sweeps n and times, per (4n x n) block:
//!   * gram+gj     — classical APC: A^T A then O(n^3) Gauss-Jordan inverse
//!   * qr+backsub  — this paper: Householder QR + O(n^2) substitution
//!   * qr+rinv     — middle ground the paper argues against: QR then an
//!                   explicit O(n^3)-ish triangular inverse
//!
//! Expected shape: qr+backsub < qr+rinv < gram+gj, with the gap growing
//! in n — exactly why Table 1's acceleration grows with matrix size.

use dapc::benchkit::{black_box, full_mode, quick_mode, Bench};
use dapc::linalg::{blas, inverse, qr, triangular, Matrix};
use dapc::metrics::TableBuilder;
use dapc::rng::seeded;

fn main() {
    let sizes: &[usize] = if full_mode() {
        &[128, 256, 512, 1024, 2327]
    } else if quick_mode() {
        &[64, 128]
    } else {
        &[64, 128, 256, 512]
    };
    let bench = Bench::default();
    let mut table =
        TableBuilder::new(&["n", "gram+gj", "qr+backsub", "qr+rinv", "speedup gj/backsub"]);

    println!("=== Ablation: init method cost (block = 4n x n) ===");
    for &n in sizes {
        let l = 4 * n;
        let mut g = seeded(n as u64);
        let a = Matrix::from_fn(l, n, |_, _| g.normal_f32());
        let b: Vec<f32> = (0..l).map(|_| g.normal_f32()).collect();

        let classical = bench.run(&format!("gram+gj       n={n}"), || {
            let gram = blas::gram(&a);
            let ginv = inverse::gauss_jordan_inverse(&gram).unwrap();
            let mut atb = vec![0.0f32; n];
            blas::gemv_t(&a, &b, &mut atb);
            let mut x0 = vec![0.0f32; n];
            blas::gemv(&ginv, &atb, &mut x0);
            black_box(x0[0]);
        });
        let decomposed = bench.run(&format!("qr+backsub    n={n}"), || {
            let f = qr::householder_qr(&a);
            let c = qr::qt_mul(&f, &b);
            let x0 = triangular::back_substitute(&f.r, &c);
            black_box(x0[0]);
        });
        let rinv = bench.run(&format!("qr+rinv       n={n}"), || {
            let f = qr::householder_qr(&a);
            let rins = triangular::upper_triangular_inverse(&f.r);
            let c = qr::qt_mul(&f, &b);
            let mut x0 = vec![0.0f32; n];
            blas::gemv(&rins, &c, &mut x0);
            black_box(x0[0]);
        });

        table.row(&[
            n.to_string(),
            format!("{:.2}ms", classical.stats.median() * 1e3),
            format!("{:.2}ms", decomposed.stats.median() * 1e3),
            format!("{:.2}ms", rinv.stats.median() * 1e3),
            format!("{:.2}x", classical.stats.median() / decomposed.stats.median()),
        ]);
    }
    println!("\n{}", table.render());
}
