//! Wire protocol between leader and workers.
//!
//! Hand-rolled binary framing (serde unavailable offline):
//!
//! ```text
//! frame   := u32 header (LE) | u32 payload_len (LE) | payload
//! header  := 0x4450_0000 | WIRE_VERSION   ("DP" magic + version)
//! payload := u8 tag | fields in declaration order
//! vec<f32>:= u64 len | f32 * len        (LE)
//! matrix  := u64 rows | u64 cols | f32 * rows*cols (row-major)
//! string  := u64 len | utf8 bytes
//! f64     := 8 bytes (LE)
//! stats   := u64 count | (string | f64) * count
//! ```
//!
//! The frame header is added by stream transports (see
//! [`super::transport`]); it makes old/new peer mixes fail LOUDLY at the
//! first frame instead of mis-decoding each other's bytes.  Bump
//! [`WIRE_VERSION`] whenever the payload encoding changes.
//!
//! The protocol is deliberately small: projectors are computed worker-side
//! and never serialized; per-epoch traffic is one n-vector each way per
//! worker (the paper's communication pattern).  DGD initialization uses
//! [`InitKindWire::GradOnly`], which ships the block but skips the
//! worker-side factorization entirely.
//!
//! # Sessions (wire v3)
//!
//! The solve-service frames separate the RHS-independent registration
//! from per-RHS serving: [`Message::RegisterMatrix`] ships a block ONCE
//! (the worker factorizes and keeps `A_j`/`P_j`/seed state across
//! solves), then any number of [`Message::SolveRhs`] /
//! [`Message::SolveBatch`] frames stream right-hand sides through the
//! retained factorization.  Batched epochs run over
//! [`Message::RunUpdateBatch`] / [`Message::RunGradBatch`], carrying k
//! n-vectors per frame.  A worker that receives an RHS before a
//! registration rejects it loudly with a [`Message::WorkerError`].
//!
//! # Telemetry (wire v4)
//!
//! [`Message::StatsRequest`] asks a worker for a flattened snapshot of
//! its metrics registry (`obs::MetricsRegistry::snapshot_flat`); the
//! worker answers with [`Message::StatsReport`] carrying `(name, f64)`
//! pairs.  Telemetry frames never carry solver state — they are
//! read-only observation, so requesting stats can never perturb a
//! solve (the observability never-touch-numerics contract, see
//! `crate::obs`).

use crate::error::{DapcError, Result};
use crate::linalg::Matrix;
use crate::solver::InitKind;

/// Version of the payload encoding; carried in every stream frame header.
///
/// v1 was the unversioned PR-0 framing (`u32 len | payload`); v2 added the
/// magic/version header and `InitKindWire::GradOnly`; v3 added the
/// solve-service session frames (`RegisterMatrix`, `SolveRhs`,
/// `SolveBatch` and the batched round/gradient frames); v4 added the
/// telemetry frames (`StatsRequest`/`StatsReport`) and the f64 scalar
/// encoding they carry.
pub const WIRE_VERSION: u32 = 4;

/// Protocol messages (both directions).
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Leader -> worker: here is your partition; run init.
    InitPartition {
        worker_id: u32,
        kind: InitKindWire,
        a: Matrix,
        b: Vec<f32>,
        /// Padded solution width the consensus loop runs at.
        n_target: u32,
    },
    /// Worker -> leader: init finished, here is x_j(0) (empty for
    /// [`InitKindWire::GradOnly`] — DGD starts from x = 0).
    InitDone { worker_id: u32, x0: Vec<f32> },
    /// Leader -> worker: consensus epoch t with the current average.
    RunUpdate { epoch: u32, gamma: f32, xbar: Vec<f32> },
    /// Worker -> leader: updated estimate x_j(t+1).
    UpdateDone { worker_id: u32, x: Vec<f32> },
    /// Leader -> worker: DGD gradient request at the current iterate.
    RunGrad { epoch: u32, x: Vec<f32> },
    /// Worker -> leader: local gradient.
    GradDone { worker_id: u32, grad: Vec<f32> },
    /// Worker -> leader: failure (leader aborts the run).
    WorkerError { worker_id: u32, message: String },
    /// Leader -> worker: done, exit the loop.
    Shutdown,
    /// Leader -> worker (v3): register this block for session service —
    /// factorize once, retain `A_j`/`P_j`/seed state across solves
    /// ([`InitKindWire::GradOnly`] stores the block only).
    RegisterMatrix {
        worker_id: u32,
        kind: InitKindWire,
        a: Matrix,
        /// Padded solution width the consensus loop runs at.
        n_target: u32,
    },
    /// Worker -> leader (v3): registration finished; the factorization
    /// is resident and ready to serve right-hand sides.
    MatrixRegistered { worker_id: u32 },
    /// Leader -> worker (v3): seed ONE fresh rhs slice through the
    /// retained factorization.  Rejected loudly before `RegisterMatrix`.
    SolveRhs { b: Vec<f32> },
    /// Leader -> worker (v3): seed k fresh rhs slices (one batched
    /// solve).  Rejected loudly before `RegisterMatrix`.
    SolveBatch { bs: Vec<Vec<f32>> },
    /// Worker -> leader (v3): per-column initial estimates `x_j(0)`
    /// (empty columns for gradient-only sessions — DGD starts at 0).
    RhsSeeded { worker_id: u32, x0s: Vec<Vec<f32>> },
    /// Leader -> worker (v3): one batched eq. (6) round at the current
    /// per-column averages.
    RunUpdateBatch { epoch: u32, gamma: f32, xbars: Vec<Vec<f32>> },
    /// Worker -> leader (v3): updated estimates for every column.
    UpdateBatchDone { worker_id: u32, xs: Vec<Vec<f32>> },
    /// Leader -> worker (v3): one batched DGD gradient round.
    RunGradBatch { epoch: u32, xs: Vec<Vec<f32>> },
    /// Worker -> leader (v3): per-column local gradients.
    GradBatchDone { worker_id: u32, grads: Vec<Vec<f32>> },
    /// Leader -> worker (v4): ship back a snapshot of your metrics
    /// registry.  Read-only; never perturbs a solve.
    StatsRequest,
    /// Worker -> leader (v4): flattened `(name, value)` metrics
    /// snapshot (counters/gauges verbatim, histograms exploded into
    /// `.count`/`.sum`/quantile entries by
    /// `obs::MetricsRegistry::snapshot_flat`).
    StatsReport { worker_id: u32, stats: Vec<(String, f64)> },
}

/// Human label for each frame type, indexed by [`Message::kind_index`]
/// — the per-kind wire accounting metric names
/// (`wire.tx_frames.{label}` etc.) are built from these.
pub const KIND_LABELS: [&str; 19] = [
    "init_partition",
    "init_done",
    "run_update",
    "update_done",
    "run_grad",
    "grad_done",
    "worker_error",
    "shutdown",
    "register_matrix",
    "matrix_registered",
    "solve_rhs",
    "solve_batch",
    "rhs_seeded",
    "run_update_batch",
    "update_batch_done",
    "run_grad_batch",
    "grad_batch_done",
    "stats_request",
    "stats_report",
];

/// InitKind twin that is wire-encodable, plus the gradient-only mode that
/// has no engine-side factorization at all.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InitKindWire {
    Qr = 0,
    Classical = 1,
    Fat = 2,
    /// Store the block for DGD gradients only: no QR, no Gram inverse,
    /// no projector — worker init is O(nnz) instead of O(l n^2).
    GradOnly = 3,
}

impl InitKindWire {
    /// The engine-side factorization this wire kind requests, or `None`
    /// for [`Self::GradOnly`] (the worker stores the block and returns).
    pub fn engine_kind(self) -> Option<InitKind> {
        match self {
            Self::Qr => Some(InitKind::Qr),
            Self::Classical => Some(InitKind::Classical),
            Self::Fat => Some(InitKind::Fat),
            Self::GradOnly => None,
        }
    }
}

impl From<InitKind> for InitKindWire {
    fn from(k: InitKind) -> Self {
        match k {
            InitKind::Qr => Self::Qr,
            InitKind::Classical => Self::Classical,
            InitKind::Fat => Self::Fat,
        }
    }
}

// --- encoding ---------------------------------------------------------------

struct Enc<'a> {
    buf: &'a mut Vec<u8>,
}

impl<'a> Enc<'a> {
    fn new(buf: &'a mut Vec<u8>, tag: u8) -> Self {
        buf.push(tag);
        Self { buf }
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn vec_f32(&mut self, v: &[f32]) {
        self.buf.extend_from_slice(&(v.len() as u64).to_le_bytes());
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// `u64 count | vec<f32> * count` — the v3 batched-column encoding.
    fn vec2_f32(&mut self, vs: &[Vec<f32>]) {
        self.buf.extend_from_slice(&(vs.len() as u64).to_le_bytes());
        for v in vs {
            self.vec_f32(v);
        }
    }

    fn matrix(&mut self, m: &Matrix) {
        self.buf.extend_from_slice(&(m.rows() as u64).to_le_bytes());
        self.buf.extend_from_slice(&(m.cols() as u64).to_le_bytes());
        for x in m.as_slice() {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn string(&mut self, s: &str) {
        self.buf.extend_from_slice(&(s.len() as u64).to_le_bytes());
        self.buf.extend_from_slice(s.as_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `u64 count | (string | f64) * count` — the v4 telemetry encoding.
    fn stats(&mut self, stats: &[(String, f64)]) {
        self.buf.extend_from_slice(&(stats.len() as u64).to_le_bytes());
        for (name, v) in stats {
            self.string(name);
            self.f64(*v);
        }
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(DapcError::Parse("truncated message".into()));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Bytes left in the payload — the upper bound every decoded length
    /// field must respect BEFORE any size arithmetic, so hostile lengths
    /// can neither overflow a multiplication nor over-allocate.
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn vec_f32(&mut self) -> Result<Vec<f32>> {
        let len = self.u64()? as usize;
        if len > self.remaining() / 4 {
            return Err(DapcError::Parse(format!(
                "vector length {len} exceeds remaining payload"
            )));
        }
        let bytes = self.take(len * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn vec2_f32(&mut self) -> Result<Vec<Vec<f32>>> {
        let count = self.u64()? as usize;
        // every counted column needs at least its u64 length prefix
        if count > self.remaining() / 8 {
            return Err(DapcError::Parse(format!(
                "batch count {count} exceeds remaining payload"
            )));
        }
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            out.push(self.vec_f32()?);
        }
        Ok(out)
    }

    fn matrix(&mut self) -> Result<Matrix> {
        let rows = self.u64()? as usize;
        let cols = self.u64()? as usize;
        let max_elems = self.remaining() / 4;
        let elems = match rows.checked_mul(cols) {
            Some(e) if e <= max_elems => e,
            _ => {
                return Err(DapcError::Parse(format!(
                    "matrix shape {rows}x{cols} exceeds remaining payload"
                )))
            }
        };
        let bytes = self.take(elems * 4)?;
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Matrix::from_vec(rows, cols, data))
    }

    fn string(&mut self) -> Result<String> {
        let len = self.u64()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| DapcError::Parse("invalid utf8 in message".into()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn stats(&mut self) -> Result<Vec<(String, f64)>> {
        let count = self.u64()? as usize;
        // every counted entry needs at least its u64 name-length prefix
        // plus the f64 value
        if count > self.remaining() / 16 {
            return Err(DapcError::Parse(format!(
                "stats count {count} exceeds remaining payload"
            )));
        }
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            let name = self.string()?;
            let v = self.f64()?;
            out.push((name, v));
        }
        Ok(out)
    }

    fn finish(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(DapcError::Parse("trailing bytes in message".into()));
        }
        Ok(())
    }
}

const VEC_HEADER: usize = 8; // u64 length prefix
const MAT_HEADER: usize = 16; // u64 rows + u64 cols

/// Encoded size of a `vec2_f32` column batch.
fn vec2_len(vs: &[Vec<f32>]) -> usize {
    VEC_HEADER
        + vs.iter().map(|v| VEC_HEADER + 4 * v.len()).sum::<usize>()
}

impl Message {
    /// Append the tagged payload (no frame header) to `buf` — the
    /// transports' reused-send-buffer path.
    pub fn encode_into(&self, buf: &mut Vec<u8>) {
        match self {
            Message::InitPartition { worker_id, kind, a, b, n_target } => {
                let mut e = Enc::new(buf, 0);
                e.u32(*worker_id);
                e.buf.push(*kind as u8);
                e.matrix(a);
                e.vec_f32(b);
                e.u32(*n_target);
            }
            Message::InitDone { worker_id, x0 } => {
                let mut e = Enc::new(buf, 1);
                e.u32(*worker_id);
                e.vec_f32(x0);
            }
            Message::RunUpdate { epoch, gamma, xbar } => {
                let mut e = Enc::new(buf, 2);
                e.u32(*epoch);
                e.f32(*gamma);
                e.vec_f32(xbar);
            }
            Message::UpdateDone { worker_id, x } => {
                let mut e = Enc::new(buf, 3);
                e.u32(*worker_id);
                e.vec_f32(x);
            }
            Message::RunGrad { epoch, x } => {
                let mut e = Enc::new(buf, 4);
                e.u32(*epoch);
                e.vec_f32(x);
            }
            Message::GradDone { worker_id, grad } => {
                let mut e = Enc::new(buf, 5);
                e.u32(*worker_id);
                e.vec_f32(grad);
            }
            Message::WorkerError { worker_id, message } => {
                let mut e = Enc::new(buf, 6);
                e.u32(*worker_id);
                e.string(message);
            }
            Message::Shutdown => buf.push(7),
            Message::RegisterMatrix { worker_id, kind, a, n_target } => {
                let mut e = Enc::new(buf, 8);
                e.u32(*worker_id);
                e.buf.push(*kind as u8);
                e.matrix(a);
                e.u32(*n_target);
            }
            Message::MatrixRegistered { worker_id } => {
                let mut e = Enc::new(buf, 9);
                e.u32(*worker_id);
            }
            Message::SolveRhs { b } => {
                let mut e = Enc::new(buf, 10);
                e.vec_f32(b);
            }
            Message::SolveBatch { bs } => {
                let mut e = Enc::new(buf, 11);
                e.vec2_f32(bs);
            }
            Message::RhsSeeded { worker_id, x0s } => {
                let mut e = Enc::new(buf, 12);
                e.u32(*worker_id);
                e.vec2_f32(x0s);
            }
            Message::RunUpdateBatch { epoch, gamma, xbars } => {
                let mut e = Enc::new(buf, 13);
                e.u32(*epoch);
                e.f32(*gamma);
                e.vec2_f32(xbars);
            }
            Message::UpdateBatchDone { worker_id, xs } => {
                let mut e = Enc::new(buf, 14);
                e.u32(*worker_id);
                e.vec2_f32(xs);
            }
            Message::RunGradBatch { epoch, xs } => {
                let mut e = Enc::new(buf, 15);
                e.u32(*epoch);
                e.vec2_f32(xs);
            }
            Message::GradBatchDone { worker_id, grads } => {
                let mut e = Enc::new(buf, 16);
                e.u32(*worker_id);
                e.vec2_f32(grads);
            }
            Message::StatsRequest => buf.push(17),
            Message::StatsReport { worker_id, stats } => {
                let mut e = Enc::new(buf, 18);
                e.u32(*worker_id);
                e.stats(stats);
            }
        }
    }

    /// Dense index of this frame's type (identical to its wire tag);
    /// indexes [`KIND_LABELS`] for per-kind frame/byte accounting.
    pub fn kind_index(&self) -> usize {
        match self {
            Message::InitPartition { .. } => 0,
            Message::InitDone { .. } => 1,
            Message::RunUpdate { .. } => 2,
            Message::UpdateDone { .. } => 3,
            Message::RunGrad { .. } => 4,
            Message::GradDone { .. } => 5,
            Message::WorkerError { .. } => 6,
            Message::Shutdown => 7,
            Message::RegisterMatrix { .. } => 8,
            Message::MatrixRegistered { .. } => 9,
            Message::SolveRhs { .. } => 10,
            Message::SolveBatch { .. } => 11,
            Message::RhsSeeded { .. } => 12,
            Message::RunUpdateBatch { .. } => 13,
            Message::UpdateBatchDone { .. } => 14,
            Message::RunGradBatch { .. } => 15,
            Message::GradBatchDone { .. } => 16,
            Message::StatsRequest => 17,
            Message::StatsReport { .. } => 18,
        }
    }

    /// Accounting label for this frame's type.
    pub fn kind_label(&self) -> &'static str {
        KIND_LABELS[self.kind_index()]
    }

    /// Encode to a fresh tagged payload (no frame header).
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(self.encoded_len());
        self.encode_into(&mut buf);
        buf
    }

    /// Exact payload size [`Self::encode`] produces, without encoding —
    /// used for wire-byte accounting on in-process transports.
    pub fn encoded_len(&self) -> usize {
        match self {
            Message::InitPartition { a, b, .. } => {
                1 + 4
                    + 1
                    + MAT_HEADER
                    + 4 * a.rows() * a.cols()
                    + VEC_HEADER
                    + 4 * b.len()
                    + 4
            }
            Message::InitDone { x0, .. } => 1 + 4 + VEC_HEADER + 4 * x0.len(),
            Message::RunUpdate { xbar, .. } => {
                1 + 4 + 4 + VEC_HEADER + 4 * xbar.len()
            }
            Message::UpdateDone { x, .. } => 1 + 4 + VEC_HEADER + 4 * x.len(),
            Message::RunGrad { x, .. } => 1 + 4 + VEC_HEADER + 4 * x.len(),
            Message::GradDone { grad, .. } => {
                1 + 4 + VEC_HEADER + 4 * grad.len()
            }
            Message::WorkerError { message, .. } => {
                1 + 4 + VEC_HEADER + message.len()
            }
            Message::Shutdown => 1,
            Message::RegisterMatrix { a, .. } => {
                1 + 4 + 1 + MAT_HEADER + 4 * a.rows() * a.cols() + 4
            }
            Message::MatrixRegistered { .. } => 1 + 4,
            Message::SolveRhs { b } => 1 + VEC_HEADER + 4 * b.len(),
            Message::SolveBatch { bs } => 1 + vec2_len(bs),
            Message::RhsSeeded { x0s, .. } => 1 + 4 + vec2_len(x0s),
            Message::RunUpdateBatch { xbars, .. } => {
                1 + 4 + 4 + vec2_len(xbars)
            }
            Message::UpdateBatchDone { xs, .. } => 1 + 4 + vec2_len(xs),
            Message::RunGradBatch { xs, .. } => 1 + 4 + vec2_len(xs),
            Message::GradBatchDone { grads, .. } => 1 + 4 + vec2_len(grads),
            Message::StatsRequest => 1,
            Message::StatsReport { stats, .. } => {
                1 + 4
                    + VEC_HEADER
                    + stats
                        .iter()
                        .map(|(name, _)| VEC_HEADER + name.len() + 8)
                        .sum::<usize>()
            }
        }
    }

    /// Decode from a tagged payload.
    pub fn decode(buf: &[u8]) -> Result<Message> {
        let mut d = Dec { buf, pos: 0 };
        let tag = d.u8()?;
        let msg = match tag {
            0 => {
                let worker_id = d.u32()?;
                let kind = decode_kind(d.u8()?)?;
                let a = d.matrix()?;
                let b = d.vec_f32()?;
                let n_target = d.u32()?;
                Message::InitPartition { worker_id, kind, a, b, n_target }
            }
            1 => Message::InitDone { worker_id: d.u32()?, x0: d.vec_f32()? },
            2 => Message::RunUpdate {
                epoch: d.u32()?,
                gamma: d.f32()?,
                xbar: d.vec_f32()?,
            },
            3 => Message::UpdateDone { worker_id: d.u32()?, x: d.vec_f32()? },
            4 => Message::RunGrad { epoch: d.u32()?, x: d.vec_f32()? },
            5 => Message::GradDone { worker_id: d.u32()?, grad: d.vec_f32()? },
            6 => Message::WorkerError {
                worker_id: d.u32()?,
                message: d.string()?,
            },
            7 => Message::Shutdown,
            8 => {
                let worker_id = d.u32()?;
                let kind = decode_kind(d.u8()?)?;
                let a = d.matrix()?;
                let n_target = d.u32()?;
                Message::RegisterMatrix { worker_id, kind, a, n_target }
            }
            9 => Message::MatrixRegistered { worker_id: d.u32()? },
            10 => Message::SolveRhs { b: d.vec_f32()? },
            11 => Message::SolveBatch { bs: d.vec2_f32()? },
            12 => Message::RhsSeeded {
                worker_id: d.u32()?,
                x0s: d.vec2_f32()?,
            },
            13 => Message::RunUpdateBatch {
                epoch: d.u32()?,
                gamma: d.f32()?,
                xbars: d.vec2_f32()?,
            },
            14 => Message::UpdateBatchDone {
                worker_id: d.u32()?,
                xs: d.vec2_f32()?,
            },
            15 => Message::RunGradBatch {
                epoch: d.u32()?,
                xs: d.vec2_f32()?,
            },
            16 => Message::GradBatchDone {
                worker_id: d.u32()?,
                grads: d.vec2_f32()?,
            },
            17 => Message::StatsRequest,
            18 => Message::StatsReport {
                worker_id: d.u32()?,
                stats: d.stats()?,
            },
            other => {
                return Err(DapcError::Parse(format!("unknown tag {other}")))
            }
        };
        d.finish()?;
        Ok(msg)
    }
}

fn decode_kind(byte: u8) -> Result<InitKindWire> {
    match byte {
        0 => Ok(InitKindWire::Qr),
        1 => Ok(InitKindWire::Classical),
        2 => Ok(InitKindWire::Fat),
        3 => Ok(InitKindWire::GradOnly),
        k => Err(DapcError::Parse(format!("bad init kind {k}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn variants() -> Vec<Message> {
        vec![
            Message::InitPartition {
                worker_id: 3,
                kind: InitKindWire::Qr,
                a: Matrix::from_fn(4, 3, |i, j| (i * 3 + j) as f32 * 0.5),
                b: vec![1.0, -2.0, 3.0, 0.25],
                n_target: 3,
            },
            Message::InitPartition {
                worker_id: 1,
                kind: InitKindWire::GradOnly,
                a: Matrix::from_fn(2, 2, |i, j| (i + j) as f32),
                b: vec![1.0, 2.0],
                n_target: 2,
            },
            Message::InitDone { worker_id: 1, x0: vec![0.1, 0.2] },
            Message::RunUpdate { epoch: 9, gamma: 0.75, xbar: vec![5.0; 7] },
            Message::UpdateDone { worker_id: 0, x: vec![] },
            Message::RunGrad { epoch: 2, x: vec![1.0] },
            Message::GradDone { worker_id: 4, grad: vec![-1.5, 2.5] },
            Message::WorkerError {
                worker_id: 2,
                message: "qr failed: naïve".into(),
            },
            Message::Shutdown,
            Message::RegisterMatrix {
                worker_id: 7,
                kind: InitKindWire::Qr,
                a: Matrix::from_fn(3, 2, |i, j| (i + 2 * j) as f32),
                n_target: 2,
            },
            Message::MatrixRegistered { worker_id: 7 },
            Message::SolveRhs { b: vec![0.5, -1.5, 2.0] },
            Message::SolveBatch {
                bs: vec![vec![1.0, 2.0], vec![], vec![3.0]],
            },
            Message::RhsSeeded {
                worker_id: 1,
                x0s: vec![vec![0.25, 0.5], vec![]],
            },
            Message::RunUpdateBatch {
                epoch: 4,
                gamma: 0.9,
                xbars: vec![vec![1.0; 3], vec![2.0; 3]],
            },
            Message::UpdateBatchDone {
                worker_id: 3,
                xs: vec![vec![0.0; 3], vec![-1.0; 3]],
            },
            Message::RunGradBatch { epoch: 6, xs: vec![vec![1.0, 2.0]] },
            Message::GradBatchDone {
                worker_id: 0,
                grads: vec![vec![-0.5, 0.5]],
            },
            Message::StatsRequest,
            Message::StatsReport {
                worker_id: 5,
                stats: vec![
                    ("worker.update_ns.count".into(), 128.0),
                    ("worker.update_ns.p99".into(), 4095.0),
                    ("".into(), -1.5),
                ],
            },
            Message::StatsReport { worker_id: 0, stats: vec![] },
        ]
    }

    #[test]
    fn all_variants_roundtrip() {
        for m in variants() {
            let enc = m.encode();
            let dec = Message::decode(&enc).unwrap();
            assert_eq!(m, dec);
        }
    }

    #[test]
    fn encoded_len_is_exact() {
        for m in variants() {
            assert_eq!(m.encoded_len(), m.encode().len(), "{m:?}");
        }
    }

    #[test]
    fn encode_into_appends() {
        let m = Message::RunGrad { epoch: 2, x: vec![1.0] };
        let mut buf = vec![0xAA, 0xBB];
        m.encode_into(&mut buf);
        assert_eq!(&buf[..2], &[0xAA, 0xBB]);
        assert_eq!(Message::decode(&buf[2..]).unwrap(), m);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Message::decode(&[]).is_err());
        assert!(Message::decode(&[99]).is_err());
        // truncated InitDone
        let mut enc = Message::InitDone { worker_id: 1, x0: vec![1.0, 2.0] }.encode();
        enc.truncate(enc.len() - 2);
        assert!(Message::decode(&enc).is_err());
        // trailing bytes
        let mut enc2 = Message::Shutdown.encode();
        enc2.push(0);
        assert!(Message::decode(&enc2).is_err());
        // bad init kind
        let mut enc3 = Message::InitPartition {
            worker_id: 0,
            kind: InitKindWire::Qr,
            a: Matrix::zeros(1, 1),
            b: vec![0.0],
            n_target: 1,
        }
        .encode();
        enc3[5] = 9; // kind byte
        assert!(Message::decode(&enc3).is_err());
    }

    #[test]
    fn hostile_batch_count_rejected() {
        // a SolveBatch whose count claims more columns than the payload
        // could hold must fail cleanly, not over-allocate
        let mut enc = Message::SolveBatch { bs: vec![vec![1.0]] }.encode();
        // overwrite the u64 count (right after the tag byte)
        enc[1..9].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(Message::decode(&enc).is_err());

        // hostile inner vector length: must error, not wrap the
        // length * 4 multiplication into a tiny read
        let mut enc = Message::SolveRhs { b: vec![1.0, 2.0] }.encode();
        enc[1..9].copy_from_slice(&(u64::MAX / 2).to_le_bytes());
        assert!(Message::decode(&enc).is_err());

        // hostile matrix dims (rows * cols overflows usize)
        let mut enc = Message::RegisterMatrix {
            worker_id: 0,
            kind: InitKindWire::Qr,
            a: Matrix::zeros(1, 1),
            n_target: 1,
        }
        .encode();
        // rows u64 sits after tag (1) + worker_id (4) + kind (1)
        enc[6..14].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(Message::decode(&enc).is_err());

        // hostile stats count: claims more entries than the payload
        // could hold — must fail cleanly, not over-allocate
        let mut enc = Message::StatsReport {
            worker_id: 0,
            stats: vec![("a".into(), 1.0)],
        }
        .encode();
        // count u64 sits after tag (1) + worker_id (4)
        enc[5..13].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(Message::decode(&enc).is_err());
    }

    #[test]
    fn kind_index_matches_wire_tag_and_labels() {
        assert_eq!(KIND_LABELS.len(), 19);
        for m in variants() {
            let idx = m.kind_index();
            assert_eq!(m.encode()[0] as usize, idx, "{m:?}");
            assert_eq!(m.kind_label(), KIND_LABELS[idx]);
        }
        assert_eq!(Message::StatsRequest.kind_label(), "stats_request");
    }

    #[test]
    fn init_kind_conversion() {
        for k in [InitKind::Qr, InitKind::Classical, InitKind::Fat] {
            let w: InitKindWire = k.into();
            assert_eq!(w.engine_kind(), Some(k));
        }
        assert_eq!(InitKindWire::GradOnly.engine_kind(), None);
    }
}
