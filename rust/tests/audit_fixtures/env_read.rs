// Seeded violation: a raw DAPC_* environment read bypassing
// config::envvars.  Unregistered knobs are invisible to `dapc kernels`
// and undocumented.
pub fn sneaky_flag() -> bool {
    std::env::var("DAPC_SNEAKY").map(|v| v == "1").unwrap_or(false)
}

pub fn unrelated_env_is_fine() -> Option<String> {
    // non-DAPC reads are out of scope for the registry rule
    std::env::var("HOME").ok()
}
