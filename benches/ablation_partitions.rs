//! Ablation: partition count / task granularity (paper §2's "largest
//! number of small-sized tasks" argument).
//!
//! Fixed dataset, J sweep: measures init wall time (shrinks with J — more
//! parallelism, smaller QR blocks), per-epoch consensus time (grows with
//! J — more coordination), and end-to-end time on the threaded local
//! cluster, including the coordination overhead a real deployment pays.

use dapc::benchkit::{black_box, full_mode, quick_mode, Bench};
use dapc::coordinator::LocalCluster;
use dapc::metrics::TableBuilder;
use dapc::prelude::*;
use dapc::solver::ApcVariant;
use dapc::sparse::generate::GeneratorConfig;

fn main() {
    let n = if full_mode() {
        2327
    } else if quick_mode() {
        128
    } else {
        512
    };
    let epochs = if quick_mode() { 10 } else { 60 };
    let ds = GeneratorConfig::schenk_like(n).generate(31);
    let m = ds.matrix.rows();
    // tall regime requires l = m/J >= n; m = 4n => J <= 4
    let js: &[usize] = &[1, 2, 4];
    let bench = Bench::default();
    let mut table = TableBuilder::new(&[
        "J",
        "regime",
        "single-proc total",
        "cluster total",
        "cluster init",
        "cluster epochs",
    ]);

    println!("=== Ablation: partition count (m={m}, n={n}, T={epochs}) ===");
    for &j in js {
        let opts = SolveOptions { epochs, ..Default::default() };
        // single-process (no coordination overhead)
        let sp = bench.run_once(&format!("single-proc J={j}"), || {
            let r = DapcSolver::new(opts.clone())
                .solve(&NativeEngine::new(), &ds.matrix, &ds.rhs, j)
                .expect("solve");
            assert!(r.final_mse(&ds.x_true) < 1e-4);
            black_box(r.xbar.len());
        });

        // threaded cluster (channel coordination, concurrent workers)
        let mut init_s = 0.0;
        let mut iter_s = 0.0;
        let cl = bench.run_once(&format!("cluster     J={j}"), || {
            let mut cluster =
                LocalCluster::spawn(j, NativeEngine::new).expect("cluster");
            let r = cluster
                .leader
                .solve_apc(&ds.matrix, &ds.rhs, ApcVariant::Decomposed, &opts)
                .expect("solve");
            assert!(r.final_mse(&ds.x_true) < 1e-4);
            init_s = r.init_time.as_secs_f64();
            iter_s = r.iterate_time.as_secs_f64();
            black_box(r.xbar.len());
        });

        table.row(&[
            j.to_string(),
            if m / j >= n { "tall".into() } else { "fat".into() },
            format!("{:.3}s", sp.stats.mean()),
            format!("{:.3}s", cl.stats.mean()),
            format!("{init_s:.3}s"),
            format!("{iter_s:.3}s"),
        ]);
    }
    println!("\n{}", table.render());
    println!(
        "expected shape: cluster init time drops with J (parallel QR over \
         smaller blocks); epoch time grows mildly with J (coordination)."
    );
}
