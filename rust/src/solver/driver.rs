//! The unified consensus driver: ONE epoch loop for every deployment
//! topology.
//!
//! The paper's algorithm (eqs. (5)-(7)) is topology-independent: the same
//! iteration runs on a laptop and on a cluster, only *where* the per-
//! partition work executes changes.  This module encodes that split:
//!
//! * [`ConsensusBackend`] — the topology: where partitions live and how a
//!   round's estimates come back.  [`InProcessBackend`] executes on a
//!   [`ComputeEngine`] in this process through the allocation-free
//!   `round_into`/[`RoundWorkspace`] path; `coordinator::ClusterBackend`
//!   scatters over transports to remote workers.
//! * [`drive_apc`] / [`drive_dgd`] — the algorithm: eq. (5) seeding,
//!   eq. (7) mixing, the DGD step, convergence tracing, phase timing and
//!   [`SolveReport`] assembly live HERE, once.  Backends never duplicate
//!   the epoch loop.
//!
//! Numerical contract: a backend either returns its round through the
//! streaming f64 accumulator (`acc[i] = sum_j x_j[i]`, partitions summed
//! in fixed order `j = 0..J`) and lets the driver apply eq. (7), or mixes
//! in place via an engine whose averaging kernel is the *same* fixed-order
//! f64 reduction (`engine::average_chunk_kernel`).  Either way
//! every backend produces bit-identical iterates — the property
//! `tests/distributed_equivalence.rs` locks in.

use std::time::Instant;

use crate::error::{DapcError, Result};
use crate::linalg::{norms, Matrix};
use crate::metrics::ConvergenceTrace;
use crate::partition::{PartitionPlan, PartitionRegime};
use crate::sparse::CsrMatrix;

use super::consensus::ApcVariant;
use super::engine::{ComputeEngine, InitKind, RoundWorkspace};
use super::report::{residual_norm, SolveOptions, SolveReport};

/// How a backend returned the consensus round to the driver.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundOutcome {
    /// `acc` holds `sum_j x_j(t+1)` (fixed order `j = 0..J`, f64); the
    /// driver applies the eq. (7) mixing.
    Accumulated,
    /// The backend already wrote `xbar(t+1)` in place through an engine
    /// whose fused round includes the identical eq. (7) reduction.
    Mixed,
}

/// Where the per-partition work of Algorithm 1 executes.
///
/// Implementations hold all per-partition state (estimates, projectors or
/// the dense blocks) so the driver only ever owns n-length vectors — the
/// paper's leader-side memory guarantee.
pub trait ConsensusBackend {
    /// Number of partitions / workers J this backend drives.
    fn partitions(&self) -> usize;

    /// Algorithm 1 steps 1-4: distribute the `plan`'s blocks, run the
    /// per-partition init (`kind`), and leave `acc[i] = sum_j x_j(0)[i]`
    /// (fixed order, f64).  Returns the solution width the consensus loop
    /// runs at (`>= plan.n` when the engine pads to shape buckets);
    /// `acc` is resized to that width.
    fn init_partitions(
        &mut self,
        kind: InitKind,
        plan: &PartitionPlan,
        a: &CsrMatrix,
        b: &[f32],
        acc: &mut Vec<f64>,
    ) -> Result<usize>;

    /// One eq. (6) round at the current `xbar` across all partitions.
    /// On [`RoundOutcome::Accumulated`] the backend has overwritten `acc`
    /// with the fixed-order sum of the updated estimates; on
    /// [`RoundOutcome::Mixed`] it has written `xbar(t+1)` into `xbar`.
    fn run_round(
        &mut self,
        gamma: f32,
        eta: f32,
        xbar: &mut [f32],
        acc: &mut [f64],
    ) -> Result<RoundOutcome>;

    /// Run all `epochs` rounds in one fused call when the backend's
    /// engine supports it (e.g. the XLA whole-loop artifact), writing the
    /// final average into `xbar`.  `Ok(false)` = not supported, drive the
    /// per-round loop instead.
    fn try_solve_loop(
        &mut self,
        _gamma: f32,
        _eta: f32,
        _epochs: usize,
        _xbar: &mut [f32],
    ) -> Result<bool> {
        Ok(false)
    }

    /// DGD setup: distribute the `plan`'s blocks withOUT any
    /// factorization (workers only need `A_j`, `b_j` for gradients).
    fn init_grad(
        &mut self,
        plan: &PartitionPlan,
        a: &CsrMatrix,
        b: &[f32],
    ) -> Result<()>;

    /// One DGD gradient round at `x`: overwrite `acc` with
    /// `sum_j A_j^T (A_j x - b_j)` (fixed order, f64).
    fn grad_round(&mut self, x: &[f32], acc: &mut [f64]) -> Result<()>;

    /// Per-partition estimates after the last round (only called when
    /// [`SolveOptions::collect_x_parts`] asks for them).
    fn x_parts(&mut self) -> Result<Vec<Vec<f32>>>;

    /// Engine label for [`SolveReport::engine`].
    fn backend_name(&self) -> &'static str;
}

/// Overwrite `acc` with the fixed-order f64 sum of the estimates.  This
/// is the first half of `engine::average_chunk_kernel`; keeping the
/// identical j-order keeps backends bit-identical.
pub(crate) fn accumulate_sum(xs: &[Vec<f32>], acc: &mut [f64]) {
    for a in acc.iter_mut() {
        *a = 0.0;
    }
    for x in xs {
        for (a, &v) in acc.iter_mut().zip(x.iter()) {
            *a += v as f64;
        }
    }
}

/// Eq. (7) in place: `xbar[i] = eta * (acc[i] / J) + (1 - eta) * xbar[i]`
/// — the second half of `engine::average_chunk_kernel`, same f64
/// arithmetic, so driver-side mixing is bit-identical to engine-side.
fn mix_into(acc: &[f64], j: usize, eta: f32, xbar: &mut [f32]) {
    let jf = j as f64;
    let eta = eta as f64;
    for (xb, &a) in xbar.iter_mut().zip(acc.iter()) {
        *xb = (eta * (a / jf) + (1.0 - eta) * *xb as f64) as f32;
    }
}

/// Eq. (5) from the init accumulator: `xbar(0)[i] = acc[i] / J`.
fn mean_from_acc(acc: &[f64], j: usize) -> Vec<f32> {
    let jf = j as f64;
    acc.iter().map(|&s| (s / jf) as f32).collect()
}

fn apc_label(variant: ApcVariant) -> &'static str {
    match variant {
        ApcVariant::Decomposed => "dapc-decomposed",
        ApcVariant::Classical => "apc-classical",
    }
}

fn check_shapes(a: &CsrMatrix, b: &[f32], j: usize) -> Result<(usize, usize)> {
    if j == 0 {
        return Err(DapcError::Coordinator(
            "consensus driver needs at least one partition/worker (got 0)"
                .into(),
        ));
    }
    let (m, n) = a.shape();
    if b.len() != m {
        return Err(DapcError::Shape(format!(
            "rhs length {} != matrix rows {m}",
            b.len()
        )));
    }
    Ok((m, n))
}

/// Full Algorithm 1 over any backend: partition -> init -> consensus.
///
/// This is THE apc epoch loop — `DapcSolver`/`ApcClassicalSolver` run it
/// over [`InProcessBackend`], `coordinator::Leader` over
/// `ClusterBackend`.
pub fn drive_apc<B: ConsensusBackend + ?Sized>(
    backend: &mut B,
    a: &CsrMatrix,
    b: &[f32],
    variant: ApcVariant,
    opts: &SolveOptions,
) -> Result<SolveReport> {
    let j = backend.partitions();
    let (m, n) = check_shapes(a, b, j)?;
    let plan = PartitionPlan::contiguous(m, n, j)?;
    let init_kind = match (variant, plan.regime) {
        (_, PartitionRegime::Fat) => InitKind::Fat,
        (ApcVariant::Decomposed, PartitionRegime::Tall) => InitKind::Qr,
        (ApcVariant::Classical, PartitionRegime::Tall) => InitKind::Classical,
    };

    // ---- init phase (Algorithm 1 steps 1-4) -----------------------------
    let t0 = Instant::now();
    let mut acc: Vec<f64> = Vec::new();
    let n_target = backend.init_partitions(init_kind, &plan, a, b, &mut acc)?;
    debug_assert_eq!(acc.len(), n_target);
    // eq. (5): xbar(0) = mean of initial estimates
    let mut xbar = mean_from_acc(&acc, j);
    let init_time = t0.elapsed();

    // ---- iterate phase (steps 5-8) --------------------------------------
    let algorithm = apc_label(variant);
    let t1 = Instant::now();
    let mut trace = opts.x_true.as_ref().map(|xt| {
        let mut tr = ConvergenceTrace::new(algorithm);
        tr.push(0, norms::mse(&xbar[..xt.len().min(xbar.len())], xt));
        tr
    });

    let fused = opts.fused_loop
        && trace.is_none()
        && backend.try_solve_loop(opts.gamma, opts.eta, opts.epochs, &mut xbar)?;
    if !fused {
        for t in 0..opts.epochs {
            match backend.run_round(opts.gamma, opts.eta, &mut xbar, &mut acc)? {
                RoundOutcome::Accumulated => {
                    mix_into(&acc, j, opts.eta, &mut xbar)
                }
                RoundOutcome::Mixed => {}
            }
            if let (Some(tr), Some(xt)) = (&mut trace, &opts.x_true) {
                tr.push(t + 1, norms::mse(&xbar[..xt.len().min(xbar.len())], xt));
            }
        }
    }
    let iterate_time = t1.elapsed();

    // strip any bucket padding
    xbar.truncate(n);
    let residual = residual_norm(a, b, &xbar);
    let x_parts = if opts.collect_x_parts {
        let mut parts = backend.x_parts()?;
        for x in &mut parts {
            x.truncate(n);
        }
        parts
    } else {
        Vec::new()
    };

    Ok(SolveReport {
        xbar,
        x_parts,
        trace,
        residual: Some(residual),
        init_time,
        iterate_time,
        algorithm,
        engine: backend.backend_name(),
        epochs: opts.epochs,
    })
}

/// Conservative DGD step from the Gershgorin-style bound on
/// `lambda_max(A^T A)` via column squared norms — one implementation for
/// every backend (the leader always holds the CSR matrix).
pub fn auto_dgd_step(a: &CsrMatrix) -> f32 {
    let (m, n) = a.shape();
    let mut colsq = vec![0.0f64; n];
    for r in 0..m {
        let (cols, vals) = a.row(r);
        for (c, v) in cols.iter().zip(vals) {
            colsq[*c] += (*v as f64) * (*v as f64);
        }
    }
    let total: f64 = colsq.iter().sum();
    (1.0 / total.max(1e-12)) as f32
}

/// Distributed gradient descent over any backend — the same partition
/// layout and gather as APC so the Fig. 2 comparison is apples-to-apples.
pub fn drive_dgd<B: ConsensusBackend + ?Sized>(
    backend: &mut B,
    a: &CsrMatrix,
    b: &[f32],
    opts: &SolveOptions,
) -> Result<SolveReport> {
    let j = backend.partitions();
    let (m, n) = check_shapes(a, b, j)?;
    let plan = PartitionPlan::contiguous(m, n, j)?;

    let t0 = Instant::now();
    backend.init_grad(&plan, a, b)?;
    let alpha = if opts.dgd_step > 0.0 {
        opts.dgd_step
    } else {
        auto_dgd_step(a)
    };
    let mut x = vec![0.0f32; n];
    let init_time = t0.elapsed();

    let mut trace = opts.x_true.as_ref().map(|xt| {
        let mut tr = ConvergenceTrace::new("dgd");
        tr.push(0, norms::mse(&x, xt));
        tr
    });

    let t1 = Instant::now();
    let mut acc = vec![0.0f64; n];
    for t in 0..opts.epochs {
        backend.grad_round(&x, &mut acc)?;
        for (xi, g) in x.iter_mut().zip(&acc) {
            *xi -= alpha * (*g as f32);
        }
        if let (Some(tr), Some(xt)) = (&mut trace, &opts.x_true) {
            tr.push(t + 1, norms::mse(&x, xt));
        }
    }
    let iterate_time = t1.elapsed();
    let residual = residual_norm(a, b, &x);

    let x_parts = if opts.collect_x_parts {
        vec![x.clone()]
    } else {
        Vec::new()
    };
    Ok(SolveReport {
        xbar: x,
        x_parts,
        trace,
        residual: Some(residual),
        init_time,
        iterate_time,
        algorithm: "dgd",
        engine: backend.backend_name(),
        epochs: opts.epochs,
    })
}

// ---------------------------------------------------------------------------
// In-process backend
// ---------------------------------------------------------------------------

/// Backend executing every partition on a [`ComputeEngine`] in this
/// process.
///
/// The consensus path goes through the engine's
/// [`ComputeEngine::round_into`] with a warmed [`RoundWorkspace`] and
/// double-buffered estimates, so the steady-state epoch loop performs no
/// heap allocations — exactly the PR-1 hot path, now reachable from the
/// shared driver.
pub struct InProcessBackend<'e, E: ComputeEngine> {
    engine: &'e E,
    j: usize,
    // consensus state (filled by init_partitions)
    xs: Vec<Vec<f32>>,
    next_xs: Vec<Vec<f32>>,
    ps: Vec<Matrix>,
    ws: RoundWorkspace,
    next_xbar: Vec<f32>,
    // dgd state (filled by init_grad)
    blocks: Vec<(Matrix, Vec<f32>)>,
    ax: Vec<Vec<f32>>,
    grad: Vec<f32>,
}

impl<'e, E: ComputeEngine> InProcessBackend<'e, E> {
    /// Backend over `engine` splitting the system into `j` partitions.
    pub fn new(engine: &'e E, j: usize) -> Self {
        Self {
            engine,
            j,
            xs: Vec::new(),
            next_xs: Vec::new(),
            ps: Vec::new(),
            ws: RoundWorkspace::default(),
            next_xbar: Vec::new(),
            blocks: Vec::new(),
            ax: Vec::new(),
            grad: Vec::new(),
        }
    }
}

impl<E: ComputeEngine> ConsensusBackend for InProcessBackend<'_, E> {
    fn partitions(&self) -> usize {
        self.j
    }

    fn init_partitions(
        &mut self,
        kind: InitKind,
        plan: &PartitionPlan,
        a: &CsrMatrix,
        b: &[f32],
        acc: &mut Vec<f64>,
    ) -> Result<usize> {
        let j = self.j;
        // engines may pad to a bucket; all partitions must agree on the
        // target width
        let max_rows = plan.blocks.iter().map(|blk| blk.len()).max().unwrap();
        let n_target = self
            .engine
            .init_bucket(kind, max_rows, plan.n)?
            .map(|(_, np)| np)
            .unwrap_or(plan.n);
        // blocks are densified on demand inside init_all: the sequential
        // engine holds one at a time (unchanged peak memory), the parallel
        // engine extracts + factorizes partitions concurrently
        let inits =
            self.engine
                .init_all(kind, j, &|i| plan.extract(a, b, i), n_target)?;
        self.xs = inits.iter().map(|w| w.x0.clone()).collect();
        self.ps = inits.into_iter().map(|w| w.projector).collect();
        self.next_xs =
            self.xs.iter().map(|x| vec![0.0f32; x.len()]).collect();
        self.next_xbar = vec![0.0f32; n_target];
        self.ws.ensure(j, n_target);
        acc.clear();
        acc.resize(n_target, 0.0);
        accumulate_sum(&self.xs, acc);
        Ok(n_target)
    }

    fn run_round(
        &mut self,
        gamma: f32,
        eta: f32,
        xbar: &mut [f32],
        _acc: &mut [f64],
    ) -> Result<RoundOutcome> {
        // allocation-free: warmed workspace + double-buffered estimates
        self.engine.round_into(
            &self.xs,
            xbar,
            &self.ps,
            gamma,
            eta,
            &mut self.ws,
            &mut self.next_xs,
            &mut self.next_xbar,
        )?;
        std::mem::swap(&mut self.xs, &mut self.next_xs);
        xbar.copy_from_slice(&self.next_xbar);
        Ok(RoundOutcome::Mixed)
    }

    fn try_solve_loop(
        &mut self,
        gamma: f32,
        eta: f32,
        epochs: usize,
        xbar: &mut [f32],
    ) -> Result<bool> {
        match self
            .engine
            .solve_loop(&self.xs, xbar, &self.ps, gamma, eta, epochs)?
        {
            Some((new_xs, new_xbar)) => {
                self.xs = new_xs;
                xbar.copy_from_slice(&new_xbar);
                Ok(true)
            }
            None => Ok(false),
        }
    }

    fn init_grad(
        &mut self,
        plan: &PartitionPlan,
        a: &CsrMatrix,
        b: &[f32],
    ) -> Result<()> {
        self.blocks = (0..self.j).map(|i| plan.extract(a, b, i)).collect();
        self.ax = self
            .blocks
            .iter()
            .map(|(sub, _)| vec![0.0f32; sub.rows()])
            .collect();
        self.grad = vec![0.0f32; plan.n];
        Ok(())
    }

    fn grad_round(&mut self, x: &[f32], acc: &mut [f64]) -> Result<()> {
        for a in acc.iter_mut() {
            *a = 0.0;
        }
        for ((sub, rhs), ax) in self.blocks.iter().zip(self.ax.iter_mut()) {
            self.engine.dgd_grad_into(sub, x, rhs, ax, &mut self.grad)?;
            for (a, g) in acc.iter_mut().zip(&self.grad) {
                *a += *g as f64;
            }
        }
        Ok(())
    }

    fn x_parts(&mut self) -> Result<Vec<Vec<f32>>> {
        Ok(self.xs.clone())
    }

    fn backend_name(&self) -> &'static str {
        self.engine.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::engine::NativeEngine;
    use crate::sparse::generate::GeneratorConfig;

    #[test]
    fn zero_partitions_rejected_with_coordinator_error() {
        let e = NativeEngine::new();
        let ds = GeneratorConfig::small_demo(8, 1).generate(1);
        let mut backend = InProcessBackend::new(&e, 0);
        for r in [
            drive_apc(
                &mut backend,
                &ds.matrix,
                &ds.rhs,
                ApcVariant::Decomposed,
                &SolveOptions::default(),
            ),
            drive_dgd(&mut backend, &ds.matrix, &ds.rhs, &SolveOptions::default()),
        ] {
            match r {
                Err(DapcError::Coordinator(msg)) => {
                    assert!(msg.contains("at least one"), "{msg}")
                }
                other => panic!("expected Coordinator error, got {other:?}"),
            }
        }
    }

    #[test]
    fn driver_mix_matches_engine_average_bitwise() {
        // driver-side eq. (7) must be bit-identical to the engine kernel
        let e = NativeEngine::new();
        let mut g = crate::rng::seeded(9);
        let (j, n) = (3usize, 23usize);
        let xs: Vec<Vec<f32>> = (0..j)
            .map(|_| (0..n).map(|_| g.normal_f32()).collect())
            .collect();
        let xbar: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
        let want = e.average(&xs, &xbar, 0.85).unwrap();

        let mut acc = vec![0.0f64; n];
        accumulate_sum(&xs, &mut acc);
        let mut got = xbar.clone();
        mix_into(&acc, j, 0.85, &mut got);
        assert_eq!(want, got);
    }

    #[test]
    fn x_parts_collected_only_on_request() {
        let ds = GeneratorConfig::small_demo(16, 2).generate(3);
        let e = NativeEngine::new();
        let base = SolveOptions { epochs: 5, ..Default::default() };

        let mut b1 = InProcessBackend::new(&e, 2);
        let without =
            drive_apc(&mut b1, &ds.matrix, &ds.rhs, ApcVariant::Decomposed, &base)
                .unwrap();
        assert!(without.x_parts.is_empty());

        let mut b2 = InProcessBackend::new(&e, 2);
        let with = drive_apc(
            &mut b2,
            &ds.matrix,
            &ds.rhs,
            ApcVariant::Decomposed,
            &SolveOptions { collect_x_parts: true, ..base },
        )
        .unwrap();
        assert_eq!(with.x_parts.len(), 2);
        assert_eq!(with.xbar, without.xbar);
    }

    #[test]
    fn auto_step_matches_dense_column_norms() {
        let ds = GeneratorConfig::small_demo(12, 2).generate(4);
        let dense = ds.matrix.to_dense();
        let mut colsq = vec![0.0f64; dense.cols()];
        for r in 0..dense.rows() {
            for (c, v) in dense.row(r).iter().enumerate() {
                colsq[c] += (*v as f64) * (*v as f64);
            }
        }
        let total: f64 = colsq.iter().sum();
        let want = (1.0 / total.max(1e-12)) as f32;
        assert_eq!(auto_dgd_step(&ds.matrix), want);
    }
}
