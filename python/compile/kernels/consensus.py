"""Layer-1 Pallas kernels for the APC consensus hot path.

The per-epoch work of Algorithm 1 is, for every partition j:

    x_j <- x_j + gamma * P_j @ (xbar - x_j)          (paper eq. (6))

followed by the leader-side mixing

    xbar <- (eta / J) * sum_j x_j + (1 - eta) * xbar (paper eq. (7))

Both are implemented as Pallas kernels, tiled so a TPU lowering would stream
``P`` tiles HBM->VMEM while the (small) vectors stay resident in VMEM:

* :func:`consensus_update` — batched over J: grid (J, n/BN), each program
  computes a BN-row slice of ``P_j (xbar - x_j)`` with the full n-length
  vectors in VMEM (BN x n tile of P per program).
* :func:`eta_average` — grid (n/BN,), reduces the J solutions column-wise.

``interpret=True`` is mandatory here: the CPU PJRT client cannot execute
Mosaic custom-calls, and interpret-mode lowers these kernels to plain HLO
(dots, loops) that any backend runs.  Correctness is pinned to
``kernels.ref`` by ``python/tests/``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["consensus_update", "eta_average", "BN_DEFAULT"]

# Row-block size for P tiles. 128 matches the MXU/VPU lane width so a real
# TPU lowering gets full-width tiles; shapes not divisible by BN fall back to
# a single block (interpret mode does not require padding).
BN_DEFAULT = 128


def _block(n: int, bn: int) -> int:
    """Largest tile size <= bn that divides n (n is padded upstream to a
    manifest bucket, so in practice this returns bn)."""
    if n % bn == 0:
        return bn
    for cand in (64, 32, 16, 8, 4, 2, 1):
        if n % cand == 0 and cand <= bn:
            return cand
    return n


def consensus_update(
    x: jnp.ndarray,
    xbar: jnp.ndarray,
    p: jnp.ndarray,
    gamma: jnp.ndarray,
    *,
    bn: int | None = None,
) -> jnp.ndarray:
    """Batched eq. (6): ``x[j] + gamma * P[j] @ (xbar - x[j])`` for all j.

    Args:
      x:     (J, n) per-partition estimates.
      xbar:  (n,)   consensus average.
      p:     (J, n, n) nullspace projectors.
      gamma: scalar (0-d or (1,1)) step size.

    Returns (J, n) updated estimates.
    """
    jn, n = x.shape
    bn = _block(n, bn or BN_DEFAULT)
    gamma2d = jnp.reshape(gamma, (1, 1)).astype(x.dtype)

    # The residual d_j = xbar - x_j is formed once outside the kernel (cheap,
    # fused by XLA) so each program only streams its P tile + the full d_j.
    d = xbar[None, :] - x  # (J, n)

    def kernel(x_ref, d_full_ref, p_ref, gamma_ref, o_ref):
        # x_ref      (1, BN)    row-block slice of x_j
        # d_full_ref (1, n)     full residual for partition j (VMEM resident)
        # p_ref      (1, BN, n) BN rows of P_j (streamed tile)
        # gamma_ref  (1, 1)
        g = gamma_ref[0, 0]
        pd = p_ref[0] @ d_full_ref[0]  # (BN,)
        o_ref[0, :] = x_ref[0, :] + g * pd

    grid = (jn, n // bn)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn), lambda j, i: (j, i)),
            pl.BlockSpec((1, n), lambda j, i: (j, 0)),
            pl.BlockSpec((1, bn, n), lambda j, i: (j, i, 0)),
            pl.BlockSpec((1, 1), lambda j, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda j, i: (j, i)),
        out_shape=jax.ShapeDtypeStruct((jn, n), x.dtype),
        interpret=True,
    )(x, d, p, gamma2d)


def eta_average(
    x: jnp.ndarray,
    xbar: jnp.ndarray,
    eta: jnp.ndarray,
    *,
    bn: int | None = None,
) -> jnp.ndarray:
    """Eq. (7): ``(eta / J) * sum_j x[j] + (1 - eta) * xbar``.

    Args:
      x:    (J, n) updated estimates.
      xbar: (n,)   previous average.
      eta:  scalar mixing weight in (0, 1).

    Returns (n,) new consensus average.
    """
    jn, n = x.shape
    bn = _block(n, bn or BN_DEFAULT)
    eta2d = jnp.reshape(eta, (1, 1)).astype(x.dtype)

    def kernel(x_ref, xbar_ref, eta_ref, o_ref):
        # x_ref (J, BN) — all partitions for this column block
        e = eta_ref[0, 0]
        col_mean = jnp.sum(x_ref[...], axis=0) / jn
        o_ref[0, :] = e * col_mean + (1.0 - e) * xbar_ref[0, :]

    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((jn, bn), lambda i: (0, i)),
            pl.BlockSpec((1, bn), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), x.dtype),
        interpret=True,
    )(x, xbar[None, :], eta2d)[0]
