//! Wire v5 exhaustiveness: every [`Message`] variant roundtrips through
//! `encode`/`decode`, `encoded_len` is exact, and every *strict prefix*
//! of a valid encoding is rejected (the decoder consumes the payload
//! deterministically and `finish()` refuses trailing bytes, so a
//! truncated frame can never silently decode as a shorter message).
//!
//! Coverage is enforced structurally, not by convention: the test
//! asserts that the `kind_index` values of the constructed set cover
//! `0..KIND_LABELS.len()` exactly once each, so adding a wire variant
//! without extending this suite fails the build's test leg (and the
//! `wire-pairing` audit rule fails the lint leg).

use dapc::coordinator::message::{InitKindWire, Message, KIND_LABELS};
use dapc::linalg::Matrix;

/// One instance of every wire v5 variant, with non-trivial field values
/// (non-zero ids, non-square matrices, ragged batches, unicode strings)
/// so a field mix-up cannot roundtrip by coincidence.
fn all_variants() -> Vec<Message> {
    vec![
        Message::InitPartition {
            worker_id: 3,
            kind: InitKindWire::Qr,
            a: Matrix::from_vec(2, 3, vec![1.5, -2.0, 0.25, 4.0, -0.5, 8.0]),
            b: vec![0.75, -1.25],
            n_target: 3,
        },
        Message::InitDone { worker_id: 1, x0: vec![0.1, -0.2, 0.3] },
        Message::RunUpdate { epoch: 41, gamma: 0.9, xbar: vec![5.0, -6.0] },
        Message::UpdateDone { worker_id: 2, x: vec![7.5] },
        Message::RunGrad { epoch: 11, x: vec![-3.0, 3.0] },
        Message::GradDone { worker_id: 4, grad: vec![1e-3, -1e3] },
        Message::WorkerError {
            worker_id: 5,
            message: "qr failed: naïve block ω".into(),
        },
        Message::Shutdown,
        Message::RegisterMatrix {
            worker_id: 6,
            kind: InitKindWire::GradOnly,
            a: Matrix::from_vec(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            n_target: 2,
        },
        Message::MatrixRegistered { worker_id: 7 },
        Message::SolveRhs { b: vec![0.5, -1.5, 2.5] },
        Message::SolveBatch { bs: vec![vec![1.0, 2.0], vec![], vec![3.0]] },
        Message::RhsSeeded {
            worker_id: 8,
            x0s: vec![vec![0.25, 0.5], vec![0.125]],
        },
        Message::RunUpdateBatch {
            epoch: 13,
            gamma: 0.5,
            xbars: vec![vec![-1.0], vec![2.0, -2.0]],
        },
        Message::UpdateBatchDone {
            worker_id: 9,
            xs: vec![vec![4.0, 5.0], vec![6.0]],
        },
        Message::RunGradBatch { epoch: 17, xs: vec![vec![9.0], vec![]] },
        Message::GradBatchDone {
            worker_id: 10,
            grads: vec![vec![-0.5], vec![0.5, 1.5]],
        },
        Message::StatsRequest,
        Message::StatsReport {
            worker_id: 11,
            stats: vec![
                ("wire.tx_frames.run_update".to_string(), 42.0),
                ("gemm.packed.nanos.p99".to_string(), 1.25e9),
                ("π.unicode.name".to_string(), -0.0),
            ],
        },
        // v5 session frames: ids chosen wide (> u32::MAX) so a u64
        // field truncated to 32 bits cannot roundtrip by coincidence
        Message::EvictSession { session_id: 0x1_0000_0007 },
        Message::SessionEvicted { worker_id: 12, session_id: 0x2_0000_0003 },
        Message::SubmitSolve {
            session_id: 0x3_0000_0001,
            request_id: 0x4_0000_0009,
            bs: vec![vec![0.5, -0.25], vec![], vec![1e-6]],
        },
        Message::SolveResult {
            session_id: 0x5_0000_0002,
            request_id: 0x6_0000_0004,
            xbars: vec![vec![-7.5], vec![8.0, -9.0]],
            residuals: vec![1e-9, f32::INFINITY],
        },
        Message::Busy { request_id: 0x7_0000_0006, queue_depth: 17 },
        Message::Evicted {
            session_id: 0x8_0000_0008,
            request_id: 0x9_0000_000a,
        },
        Message::Credit { credits: 4 },
    ]
}

#[test]
fn every_variant_is_constructed_exactly_once() {
    let msgs = all_variants();
    assert_eq!(msgs.len(), KIND_LABELS.len(), "suite out of sync with wire");
    let mut seen = vec![false; KIND_LABELS.len()];
    for m in &msgs {
        let k = m.kind_index();
        assert!(!seen[k], "duplicate variant {}", KIND_LABELS[k]);
        seen[k] = true;
    }
    assert!(seen.iter().all(|&s| s), "a kind_index was never produced");
}

#[test]
fn every_variant_roundtrips_bit_exactly() {
    for m in all_variants() {
        let enc = m.encode();
        assert_eq!(
            enc.len(),
            m.encoded_len(),
            "encoded_len lies for {}",
            m.kind_label()
        );
        let back = Message::decode(&enc)
            .unwrap_or_else(|e| panic!("{} failed decode: {e}", m.kind_label()));
        assert_eq!(back, m, "roundtrip mismatch for {}", m.kind_label());
        // encoding is deterministic: same message, same bytes
        assert_eq!(enc, m.encode(), "non-deterministic encode for {}", m.kind_label());
    }
}

#[test]
fn every_strict_prefix_is_rejected() {
    for m in all_variants() {
        let enc = m.encode();
        for cut in 0..enc.len() {
            assert!(
                Message::decode(&enc[..cut]).is_err(),
                "{}: truncation to {cut}/{} bytes decoded successfully",
                m.kind_label(),
                enc.len()
            );
        }
    }
}

#[test]
fn trailing_bytes_and_unknown_tags_are_rejected() {
    for m in all_variants() {
        let mut enc = m.encode();
        enc.push(0);
        assert!(
            Message::decode(&enc).is_err(),
            "{}: trailing byte accepted",
            m.kind_label()
        );
    }
    // tags beyond the variant count must fail loudly, not wrap around
    for bad in [KIND_LABELS.len() as u8, 0x7f, 0xff] {
        assert!(Message::decode(&[bad]).is_err(), "tag {bad} accepted");
    }
}
