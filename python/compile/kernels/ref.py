"""Pure-jnp oracles for every kernel and graph in the compile path.

These are the correctness references the pytest suite pins the Pallas
kernels (``kernels.consensus``), the pure-HLO linalg (``kernels.linalg``)
and the exported graphs (``compile.model``) against.  They use whatever
jnp/np routine is most obviously correct — including LAPACK-backed ones,
which are fine here because ref code never ships in an artifact.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = [
    "consensus_update_ref",
    "eta_average_ref",
    "consensus_round_ref",
    "qr_ref",
    "back_substitution_ref",
    "forward_substitution_ref",
    "inverse_ref",
    "worker_init_qr_ref",
    "worker_init_classical_ref",
    "dgd_gradient_ref",
    "solve_loop_ref",
]


def consensus_update_ref(x, xbar, p, gamma):
    """Eq. (6) for all partitions: x_j + gamma * P_j (xbar - x_j)."""
    d = xbar[None, :] - x  # (J, n)
    pd = jnp.einsum("jab,jb->ja", p, d)
    return x + gamma * pd


def eta_average_ref(x, xbar, eta):
    """Eq. (7): eta * mean_j x_j + (1 - eta) * xbar."""
    return eta * jnp.mean(x, axis=0) + (1.0 - eta) * xbar


def consensus_round_ref(x, xbar, p, gamma, eta):
    """One full epoch: eq. (6) for every j then eq. (7)."""
    xn = consensus_update_ref(x, xbar, p, gamma)
    return xn, eta_average_ref(xn, xbar, eta)


def qr_ref(a):
    """Economy QR via numpy (LAPACK)."""
    q, r = np.linalg.qr(np.asarray(a), mode="reduced")
    return q, r


def back_substitution_ref(r, c):
    import scipy.linalg as sla

    return sla.solve_triangular(np.asarray(r), np.asarray(c), lower=False)


def forward_substitution_ref(lo, c):
    import scipy.linalg as sla

    return sla.solve_triangular(np.asarray(lo), np.asarray(c), lower=True)


def inverse_ref(a):
    return np.linalg.inv(np.asarray(a))


def worker_init_qr_ref(a, b):
    """Decomposed (paper) init: QR + backsub x0, P = I - Q1^T Q1."""
    q, r = qr_ref(a)
    x0 = back_substitution_ref(r, q.T @ np.asarray(b))
    n = a.shape[1]
    p = np.eye(n) - q.T @ q
    return x0, p


def worker_init_classical_ref(a, b):
    """Classical APC init: Gram inverse. x0 = (A^T A)^-1 A^T b,
    P = I - (A^T A)^-1 (A^T A) computed *numerically* (the rounding noise is
    the point — see DESIGN.md §1 soundness note)."""
    a = np.asarray(a)
    g = a.T @ a
    ginv = np.linalg.inv(g)
    x0 = ginv @ (a.T @ np.asarray(b))
    n = a.shape[1]
    p = np.eye(n) - ginv @ g
    return x0, p


def dgd_gradient_ref(a, x, b):
    """Per-partition least-squares gradient A^T (A x - b)."""
    a = np.asarray(a)
    return a.T @ (a @ np.asarray(x) - np.asarray(b))


def solve_loop_ref(x0, xbar0, p, gamma, eta, epochs):
    """T epochs of Algorithm 1 steps 5-8."""
    x, xbar = jnp.asarray(x0), jnp.asarray(xbar0)
    for _ in range(epochs):
        x, xbar = consensus_round_ref(x, xbar, jnp.asarray(p), gamma, eta)
    return x, xbar
