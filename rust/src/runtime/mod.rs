//! PJRT runtime: loads the AOT HLO artifacts produced by
//! `python/compile/aot.py` and executes them on the `xla` crate's CPU
//! client.  This is the only bridge between Layer 3 and Layers 1/2 —
//! python never runs at request time.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (shape/dtype
//!   metadata for every compiled graph);
//! * [`pjrt`] — thread-local context: HLO text -> compile -> execute,
//!   with an executable cache keyed by artifact name;
//! * [`executor`] — a `Send + Clone` handle running a dedicated executor
//!   thread (the PJRT client is `Rc`-based and cannot cross threads), so
//!   coordinator workers can share one compiled-executable cache;
//! * [`tensor`] — the plain-data tensor type that crosses the channel.

pub mod executor;
pub mod manifest;
pub mod pjrt;
pub mod tensor;

pub use executor::XlaExecutor;
pub use manifest::{ArtifactManifest, ArtifactMeta};
pub use pjrt::PjrtContext;
pub use tensor::Tensor;
