//! Custom bench harness (criterion is unavailable offline).
//!
//! `cargo bench` binaries use [`Bench`] for warmup + timed iterations with
//! mean/median/p95 reporting, and honor two environment variables:
//!
//! * `DAPC_FULL=1`   — run paper-scale shapes (Table 1 sizes);
//! * `DAPC_QUICK=1`  — minimum iterations, for CI smoke runs.
//!
//! [`JsonReport`] additionally writes machine-readable results
//! (`BENCH_<name>.json`, or under `$DAPC_BENCH_DIR` when set) so the
//! repo's perf trajectory accumulates across PRs.

use std::path::PathBuf;
use std::time::Instant;

use crate::metrics::TimingStats;

/// One benchmark runner with a fixed iteration budget.
pub struct Bench {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        if quick_mode() {
            Self { warmup_iters: 1, iters: 3 }
        } else {
            Self { warmup_iters: 2, iters: 10 }
        }
    }
}

/// `DAPC_QUICK=1` => smoke-test iteration counts (see
/// [`crate::config::envvars`] for the full registry).
pub fn quick_mode() -> bool {
    crate::config::envvars::quick_bench()
}

/// `DAPC_FULL=1` => paper-scale workloads.
pub fn full_mode() -> bool {
    crate::config::envvars::full_bench()
}

/// A measured result, printable as one bench line.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub stats: TimingStats,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} mean {:>10}  median {:>10}  p95 {:>10}  (n={})",
            self.name,
            fmt_secs(self.stats.mean()),
            fmt_secs(self.stats.median()),
            fmt_secs(self.stats.p95()),
            self.stats.samples.len(),
        )
    }
}

/// Human-readable seconds.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

impl Bench {
    pub fn new(warmup_iters: usize, iters: usize) -> Self {
        Self { warmup_iters, iters }
    }

    /// Run `f` with warmup, returning timing stats.  `f` should perform
    /// one complete unit of work per call.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let res = BenchResult {
            name: name.to_string(),
            stats: TimingStats::from_secs(samples),
        };
        println!("{}", res.line());
        res
    }

    /// Time a single invocation (for long end-to-end runs where repeated
    /// iterations are impractical, e.g. Table-1 paper-scale rows).
    pub fn run_once<F: FnOnce()>(&self, name: &str, f: F) -> BenchResult {
        let t0 = Instant::now();
        f();
        let res = BenchResult {
            name: name.to_string(),
            stats: TimingStats::from_secs(vec![t0.elapsed().as_secs_f64()]),
        };
        println!("{}", res.line());
        res
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

// ---------------------------------------------------------------------------
// Machine-readable results
// ---------------------------------------------------------------------------

/// Accumulates [`BenchResult`]s plus per-record metadata (threads, shape,
/// J, ...) and writes them as `BENCH_<name>.json` at bench exit.  JSON is
/// emitted by hand — serde is unavailable offline — and is parseable by
/// the in-repo [`crate::config::json::Json`] reader (round-trip tested).
#[derive(Debug, Default)]
pub struct JsonReport {
    name: String,
    records: Vec<String>,
}

impl JsonReport {
    /// Report named `name` -> file `BENCH_<name>.json`.
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), records: Vec::new() }
    }

    /// Append one result.  `nums` / `strs` are extra metadata fields
    /// (e.g. `("threads", 4.0)`, `("shape", "1163x290")`).
    pub fn add(
        &mut self,
        res: &BenchResult,
        nums: &[(&str, f64)],
        strs: &[(&str, &str)],
    ) {
        let mut fields = vec![
            format!("\"name\": {}", json_str(&res.name)),
            format!("\"mean_s\": {}", json_num(res.stats.mean())),
            format!("\"median_s\": {}", json_num(res.stats.median())),
            format!("\"p95_s\": {}", json_num(res.stats.p95())),
            format!("\"p50_s\": {}", json_num(res.stats.p50())),
            format!("\"p99_s\": {}", json_num(res.stats.p99())),
            format!("\"min_s\": {}", json_num(res.stats.min())),
            format!("\"max_s\": {}", json_num(res.stats.max())),
            format!("\"samples\": {}", res.stats.samples.len()),
        ];
        for (k, v) in nums {
            fields.push(format!("{}: {}", json_str(k), json_num(*v)));
        }
        for (k, v) in strs {
            fields.push(format!("{}: {}", json_str(k), json_str(v)));
        }
        self.records.push(format!("    {{{}}}", fields.join(", ")));
    }

    /// Number of accumulated records.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Destination path: `$DAPC_BENCH_DIR` (or the working directory)
    /// joined with `BENCH_<name>.json`.
    pub fn path(&self) -> PathBuf {
        crate::config::envvars::bench_dir()
            .join(format!("BENCH_{}.json", self.name))
    }

    /// Render the full JSON document.
    pub fn render(&self) -> String {
        format!(
            "{{\n  \"bench\": {},\n  \"results\": [\n{}\n  ]\n}}\n",
            json_str(&self.name),
            self.records.join(",\n")
        )
    }

    /// Write `BENCH_<name>.json`; returns the path written.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let path = self.path();
        std::fs::write(&path, self.render())?;
        Ok(path)
    }
}

// ---------------------------------------------------------------------------
// Result validation (the CI `bench-validate` self-check)
// ---------------------------------------------------------------------------

/// Keys every [`JsonReport`] record carries ([`JsonReport::add`] writes
/// them unconditionally); [`validate_report_text`] requires them all.
/// `p50_s`/`p99_s` are the tail-latency percentiles ROADMAP item 5
/// tracks — a bench artifact without them fails `dapc bench-validate`.
pub const RECORD_KEYS: [&str; 8] = [
    "mean_s", "median_s", "p95_s", "p50_s", "p99_s", "min_s", "max_s",
    "samples",
];

/// Validate one rendered `BENCH_*.json` document: it must parse with the
/// in-repo JSON reader, name its bench, and carry a **non-empty**
/// `results` array whose records each hold a name plus every
/// [`RECORD_KEYS`] timing field (finite, non-negative, >= 1 sample).
///
/// This is what `dapc bench-validate` runs in CI after the smoke
/// benches: a bench binary that exited 0 but silently wrote nothing (or
/// wrote a truncated/NaN-laden document) fails the build instead of
/// uploading a hollow artifact.
///
/// Returns the number of validated records.
pub fn validate_report_text(text: &str) -> crate::error::Result<usize> {
    use crate::config::json::Json;
    use crate::error::DapcError;
    let doc = Json::parse(text)?;
    let bench = doc
        .get("bench")
        .and_then(Json::as_str)
        .filter(|s| !s.is_empty())
        .ok_or_else(|| {
            DapcError::Parse("bench json: missing or empty \"bench\" name".into())
        })?;
    let results = doc.get("results").and_then(Json::as_arr).ok_or_else(|| {
        DapcError::Parse(format!("bench {bench:?}: missing \"results\" array"))
    })?;
    if results.is_empty() {
        return Err(DapcError::Parse(format!(
            "bench {bench:?}: empty \"results\" — the bench produced no records"
        )));
    }
    for (i, r) in results.iter().enumerate() {
        r.get("name")
            .and_then(Json::as_str)
            .filter(|s| !s.is_empty())
            .ok_or_else(|| {
                DapcError::Parse(format!(
                    "bench {bench:?} record {i}: missing or empty \"name\""
                ))
            })?;
        for key in RECORD_KEYS {
            let v = r.get(key).and_then(Json::as_f64).ok_or_else(|| {
                DapcError::Parse(format!(
                    "bench {bench:?} record {i}: missing numeric {key:?}"
                ))
            })?;
            if !v.is_finite() || v < 0.0 {
                return Err(DapcError::Parse(format!(
                    "bench {bench:?} record {i}: {key:?} = {v} is not a \
                     finite non-negative number"
                )));
            }
            if key == "samples" && v < 1.0 {
                return Err(DapcError::Parse(format!(
                    "bench {bench:?} record {i}: zero samples"
                )));
            }
        }
    }
    Ok(results.len())
}

/// [`validate_report_text`] over a file on disk.
pub fn validate_report_file(path: &std::path::Path) -> crate::error::Result<usize> {
    let text = std::fs::read_to_string(path)?;
    validate_report_text(&text)
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
/// Shared with the metrics exporter (`obs::export`).
pub(crate) fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Finite JSON number.  NaN/inf have no JSON form — emit `null` so a
/// poisoned timing fails [`validate_report_text`] loudly (`as_f64` on
/// `Json::Null` is `None` -> "missing numeric" error) instead of being
/// laundered into a plausible-looking zero.
pub(crate) fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:e}")
    } else {
        "null".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_collects_samples() {
        let b = Bench::new(1, 5);
        let mut count = 0usize;
        let res = b.run("noop", || {
            count += 1;
        });
        assert_eq!(count, 6); // warmup + iters
        assert_eq!(res.stats.samples.len(), 5);
        assert!(res.line().contains("noop"));
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }

    #[test]
    fn run_once_single_sample() {
        let res = Bench::default().run_once("one", || {});
        assert_eq!(res.stats.samples.len(), 1);
    }

    #[test]
    fn json_report_roundtrips_through_repo_parser() {
        use crate::config::json::Json;
        let mut rep = JsonReport::new("unit_test");
        let res = BenchResult {
            name: "solve \"quoted\" (1163x290)".into(),
            stats: TimingStats::from_secs(vec![0.5, 1.0, 1.5]),
        };
        rep.add(&res, &[("threads", 4.0), ("j", 8.0)], &[("shape", "1163x290")]);
        assert_eq!(rep.len(), 1);
        let doc = Json::parse(&rep.render()).expect("valid json");
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("unit_test"));
        let results = doc.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results.len(), 1);
        let r0 = &results[0];
        assert_eq!(
            r0.get("name").and_then(Json::as_str),
            Some("solve \"quoted\" (1163x290)")
        );
        assert!((r0.get("mean_s").and_then(Json::as_f64).unwrap() - 1.0).abs() < 1e-12);
        assert!((r0.get("threads").and_then(Json::as_f64).unwrap() - 4.0).abs() < 1e-12);
        assert_eq!(r0.get("shape").and_then(Json::as_str), Some("1163x290"));
        assert_eq!(r0.get("samples").and_then(Json::as_f64), Some(3.0));
    }

    #[test]
    fn validator_accepts_real_reports() {
        let mut rep = JsonReport::new("validator_ok");
        let res = BenchResult {
            name: "k1".into(),
            stats: TimingStats::from_secs(vec![0.25, 0.5]),
        };
        rep.add(&res, &[("n", 4096.0)], &[("backend", "scalar")]);
        rep.add(&res, &[], &[]);
        assert_eq!(validate_report_text(&rep.render()).unwrap(), 2);
    }

    #[test]
    fn validator_rejects_empty_results() {
        let rep = JsonReport::new("validator_empty");
        let err = validate_report_text(&rep.render()).unwrap_err();
        assert!(err.to_string().contains("no records"), "{err}");
    }

    #[test]
    fn validator_rejects_missing_keys_and_junk() {
        // a record missing the timing fields the harness always writes
        let doc = "{\n  \"bench\": \"x\",\n  \"results\": [\n    \
                   {\"name\": \"k\", \"mean_s\": 1.0}\n  ]\n}\n";
        let err = validate_report_text(doc).unwrap_err();
        assert!(err.to_string().contains("median_s"), "{err}");
        // outright junk fails at the parser
        assert!(validate_report_text("BENCH { not json").is_err());
        // a non-finite timing is written as null (json_num) and must be
        // rejected as a missing numeric, not laundered into a zero
        let mut rep = JsonReport::new("validator_nan");
        rep.add(
            &BenchResult {
                name: "poisoned".into(),
                stats: TimingStats::from_secs(vec![f64::NAN]),
            },
            &[],
            &[],
        );
        assert!(validate_report_text(&rep.render()).is_err());
        // a literal negative fails the range check
        let neg = "{\n  \"bench\": \"x\",\n  \"results\": [\n    \
                   {\"name\": \"k\", \"mean_s\": -1.0, \"median_s\": 1.0, \
                   \"p95_s\": 1.0, \"p50_s\": 1.0, \"p99_s\": 1.0, \
                   \"min_s\": 1.0, \"max_s\": 1.0, \
                   \"samples\": 2}\n  ]\n}\n";
        let err = validate_report_text(neg).unwrap_err();
        assert!(err.to_string().contains("mean_s"), "{err}");
        // zero samples — a bench that timed nothing — fails
        let zs = "{\n  \"bench\": \"x\",\n  \"results\": [\n    \
                  {\"name\": \"k\", \"mean_s\": 1.0, \"median_s\": 1.0, \
                  \"p95_s\": 1.0, \"p50_s\": 1.0, \"p99_s\": 1.0, \
                  \"min_s\": 1.0, \"max_s\": 1.0, \
                  \"samples\": 0}\n  ]\n}\n";
        let err = validate_report_text(zs).unwrap_err();
        assert!(err.to_string().contains("zero samples"), "{err}");
        // a record predating the percentile keys fails on p50_s/p99_s
        let old = "{\n  \"bench\": \"x\",\n  \"results\": [\n    \
                   {\"name\": \"k\", \"mean_s\": 1.0, \"median_s\": 1.0, \
                   \"p95_s\": 1.0, \"min_s\": 1.0, \"max_s\": 1.0, \
                   \"samples\": 2}\n  ]\n}\n";
        let err = validate_report_text(old).unwrap_err();
        assert!(
            err.to_string().contains("p50_s")
                || err.to_string().contains("p99_s"),
            "{err}"
        );
    }

    #[test]
    fn validator_roundtrips_through_file() {
        let dir = std::env::temp_dir().join("dapc_benchkit_validate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut rep = JsonReport::new("validator_file");
        rep.add(&Bench::new(0, 1).run_once("noop", || {}), &[], &[]);
        let path = dir.join("BENCH_validator_file.json");
        std::fs::write(&path, rep.render()).unwrap();
        assert_eq!(validate_report_file(&path).unwrap(), 1);
        assert!(validate_report_file(&dir.join("BENCH_absent.json")).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn json_report_writes_to_bench_dir() {
        let dir = std::env::temp_dir().join("dapc_benchkit_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut rep = JsonReport::new("write_test");
        rep.add(
            &Bench::new(0, 1).run_once("noop", || {}),
            &[],
            &[],
        );
        // path honors DAPC_BENCH_DIR; write explicitly to the temp copy
        let path = dir.join("BENCH_write_test.json");
        std::fs::write(&path, rep.render()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"bench\": \"write_test\""));
        let _ = std::fs::remove_file(&path);
    }
}
