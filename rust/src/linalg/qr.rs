//! Panel-blocked Householder QR factorization (paper §2, eq. (1)).
//!
//! Reduced (economy) form `A = Q1 R` for tall `A` (l x n, l >= n): `Q1` is
//! (l x n) with orthonormal columns, `R` is (n x n) upper triangular.  This
//! is the native-engine twin of `kernels/linalg.py::householder_qr` — the
//! decomposed-APC init is built on it, and since PR 3 it is the dominant
//! cost a warm solver session pays (cold registration).
//!
//! # Blocking and parallelism
//!
//! Reflectors are produced one column at a time inside a [`PANEL`]-wide
//! panel (the classic reflector-at-a-time arithmetic, restricted to the
//! panel), then accumulated into the compact WY form
//! `H_0 .. H_{nb-1} = I - V T V^T` (the LAPACK `larft` recurrence with
//! `tau = 2`: reflectors are stored unit-norm).  The trailing matrix gets
//! ONE blocked update per panel — two gemm-shaped sweeps,
//!
//! ```text
//!   W = V^T A_trail              (panel-wide dots per trailing column)
//!   A_trail -= V (T^T W)         (panel-wide axpys per trailing column)
//! ```
//!
//! Both sweeps are **column-separable**: trailing column c reads only the
//! shared (V, T) pair plus its own entries, through [`blas::dot`] /
//! [`blas::axpy`] in a fixed order.  Splitting the trailing columns across
//! the thread pool ([`householder_qr_pooled`]) therefore cannot change a
//! single output bit — thread-count independence holds *by construction*,
//! because the pooled and serial paths run the SAME per-column kernel over
//! different column chunks.  (This is also why the sweeps do not go
//! through the packed f32 `gemm` microkernel: dot/axpy per column make
//! chunk-independence self-evident, where repacked panels would make it an
//! argument about packing boundaries.  Routing them through the packed
//! gemm once a chunk-stable packing story exists is the remaining QR
//! headroom — see ROADMAP "Performance".)
//!
//! The per-column `blas::dot`/`blas::axpy` calls themselves go through
//! the runtime-dispatched SIMD layer ([`crate::linalg::simd`]): the
//! trailing sweeps run on AVX2+FMA where available, and because that
//! layer's scalar fallback is lane-structured to be bit-identical to the
//! vector path, the factors stay independent of BOTH the thread count
//! and the kernel dispatch — the two switches compose without weakening
//! either invariant.
//!
//! The working copy is stored **column-major** (`work_t`, one contiguous
//! l-length slice per column): reflector extraction, every per-column
//! dot/axpy, and the parallel column chunking are all contiguous slice
//! operations.
//!
//! # Panel-size tuning (`PANEL`)
//!
//! `PANEL * l * 4` bytes of V plus one trailing column must stay
//! cache-resident through the two sweeps; 32 keeps V under half an L2 for
//! Table-1 block heights while amortizing each column's T-apply over 32
//! reflectors.  Methodology mirrors the `MC`/`KC`/`NC` constants in
//! `blas.rs`: sweep `PANEL` one value at a time against
//! `cargo bench --bench microbench_linalg` (QR lines), then confirm
//! end-to-end on `benches/register_scaling.rs` (cold session registration
//! is pure factorization).

use super::{blas, Matrix};
use crate::parallel::ThreadPool;

/// Panel width NB of the blocked factorization (see module docs for the
/// tuning methodology).
const PANEL: usize = 32;

/// Result of a reduced QR factorization.
pub struct QrFactors {
    /// (l x n) semi-orthogonal factor.
    pub q1: Matrix,
    /// (n x n) upper-triangular factor.
    pub r: Matrix,
}

/// Reduced Householder QR of a tall matrix (l >= n), serial.
///
/// This is [`householder_qr_pooled`] without a pool — the two produce
/// bit-identical factors, so callers pick purely by where the threads
/// should come from.
pub fn householder_qr(a: &Matrix) -> QrFactors {
    householder_qr_pooled(a, None)
}

/// Reduced Householder QR with the per-panel trailing updates (and the
/// Q1 recovery) fanned out over `pool`'s workers when one is given.
///
/// Bit-identical to the serial [`householder_qr`] at any thread count:
/// the parallel split is over *columns*, and every column's arithmetic is
/// independent of the chunking (module docs).
pub fn householder_qr_pooled(a: &Matrix, pool: Option<&ThreadPool>) -> QrFactors {
    let (l, n) = a.shape();
    assert!(l >= n, "householder_qr requires a tall matrix, got {l}x{n}");
    let npanels = n.div_ceil(PANEL);

    // column-major working copy: column c of A lives in work_t[c*l..(c+1)*l]
    let mut work_t = vec![0.0f32; n * l];
    for i in 0..l {
        let row = a.row(i);
        for (c, &v) in row.iter().enumerate() {
            work_t[c * l + i] = v;
        }
    }
    // reflector k is unit-norm in vs[k*l..(k+1)*l], zero above row k
    let mut vs = vec![0.0f32; n * l];
    // per-panel compact-WY T factor (PANEL x PANEL row-major, upper
    // triangular; null reflectors leave their row/column zero)
    let mut ts = vec![0.0f32; npanels * PANEL * PANEL];

    for p in 0..npanels {
        let k0 = p * PANEL;
        let nb = PANEL.min(n - k0);
        let t = &mut ts[p * PANEL * PANEL..(p + 1) * PANEL * PANEL];
        factor_panel(&mut work_t, &mut vs, t, l, k0, nb);
        // one blocked update of every trailing column:
        // A_trail <- (I - V T^T V^T) A_trail  (= H_{nb-1} .. H_0 A_trail)
        let v = &vs[k0 * l..(k0 + nb) * l];
        apply_block(
            v,
            t,
            l,
            k0,
            nb,
            Sweep::Adjoint,
            &mut work_t[(k0 + nb) * l..],
            pool,
        );
    }

    // R = upper triangle of the first n rows of the reduced working copy
    let mut r = Matrix::zeros(n, n);
    for j in 0..n {
        let col = &work_t[j * l..j * l + l];
        for i in 0..=j {
            r[(i, j)] = col[i];
        }
    }

    // Q1 = (I - V_0 T_0 V_0^T) .. (I - V_{P-1} T_{P-1} V_{P-1}^T) E with
    // E = first n columns of I_l, applied panel-last first.  Columns
    // c < k0 are still e_c with support above every row where V_p is
    // nonzero, so each panel's update is restricted to cols >= k0 — the
    // same halving of the recovery cost as the unblocked kernel (§Perf).
    let mut q_t = vec![0.0f32; n * l];
    for c in 0..n {
        q_t[c * l + c] = 1.0;
    }
    for p in (0..npanels).rev() {
        let k0 = p * PANEL;
        let nb = PANEL.min(n - k0);
        let t = &ts[p * PANEL * PANEL..(p + 1) * PANEL * PANEL];
        let v = &vs[k0 * l..(k0 + nb) * l];
        apply_block(v, t, l, k0, nb, Sweep::Forward, &mut q_t[k0 * l..], pool);
    }
    let mut q1 = Matrix::zeros(l, n);
    for i in 0..l {
        let row = q1.row_mut(i);
        for (c, slot) in row.iter_mut().enumerate() {
            *slot = q_t[c * l + i];
        }
    }
    QrFactors { q1, r }
}

/// Factor columns `[k0, k0 + nb)` of the column-major working copy in
/// place: the classic reflector-at-a-time arithmetic restricted to the
/// panel, plus the `larft` recurrence filling the panel's `T` factor
/// (`tau = 2` for the unit-norm reflectors, 0 for null ones — a zero T
/// row/column makes the blocked apply skip that reflector exactly).
fn factor_panel(
    work_t: &mut [f32],
    vs: &mut [f32],
    t: &mut [f32],
    l: usize,
    k0: usize,
    nb: usize,
) {
    let mut z = [0.0f32; PANEL];
    for kk in 0..nb {
        let k = k0 + kk;
        // v = masked column k of the working copy (rows >= k)
        let (vs_done, vs_rest) = vs.split_at_mut(k * l);
        let v = &mut vs_rest[..l];
        v[k..].copy_from_slice(&work_t[k * l + k..(k + 1) * l]);
        let sigma = blas::dot(&v[k..], &v[k..]).sqrt();
        if sigma == 0.0 {
            // zero column below k: null reflector, leave v = 0
            v[k..].fill(0.0);
            continue;
        }
        let alpha = if v[k] >= 0.0 { -sigma } else { sigma } as f32;
        v[k] -= alpha;
        let vnorm = blas::dot(&v[k..], &v[k..]).sqrt();
        if vnorm < 1e-30 {
            v[k..].fill(0.0);
            continue;
        }
        let inv = (1.0 / vnorm) as f32;
        for vi in v[k..].iter_mut() {
            *vi *= inv;
        }
        // panel-internal H_k = I - 2 v v^T over columns k..panel end
        // (column k itself becomes the k-th R column, ~zero below the
        // diagonal); per column one contiguous dot + one contiguous axpy
        for c in k..k0 + nb {
            let col = &mut work_t[c * l..(c + 1) * l];
            let w = blas::dot(&v[k..], &col[k..]) as f32;
            blas::axpy(-2.0 * w, &v[k..], &mut col[k..]);
        }
        // larft column kk: z = V[:, 0..kk]^T v (earlier reflectors are
        // zero above their own pivot row <= k, and v is zero above k, so
        // the suffix dot captures every nonzero product), then
        // t[s][kk] = -2 * sum_{r in s..kk} t[s][r] * z[r], t[kk][kk] = 2.
        for r in 0..kk {
            let vr = &vs_done[(k0 + r) * l..(k0 + r + 1) * l];
            z[r] = blas::dot(&vr[k..], &v[k..]) as f32;
        }
        for s in 0..kk {
            let mut acc = 0.0f64;
            for r in s..kk {
                acc += t[s * PANEL + r] as f64 * z[r] as f64;
            }
            t[s * PANEL + kk] = (-2.0 * acc) as f32;
        }
        t[kk * PANEL + kk] = 2.0;
    }
}

/// Which accumulated panel operator a sweep applies: triangularization
/// hits the trailing columns with the reflectors first-to-last
/// (`H_{nb-1} .. H_0 = I - V T^T V^T`), the Q1 recovery with the forward
/// product (`H_0 .. H_{nb-1} = I - V T V^T`).
#[derive(Clone, Copy)]
enum Sweep {
    /// `I - V T^T V^T`.
    Adjoint,
    /// `I - V T V^T`.
    Forward,
}

/// Apply one panel's accumulated reflectors to `cols` (column-major,
/// `cols.len() / l` columns).  The work is column-separable, so chunks of
/// columns go to the pool when one is provided, each chunk running the
/// identical per-column kernel — bit-identical to the serial sweep at any
/// thread count.
#[allow(clippy::too_many_arguments)]
fn apply_block(
    v: &[f32],
    t: &[f32],
    l: usize,
    k0: usize,
    nb: usize,
    sweep: Sweep,
    cols: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    let ncols = cols.len() / l.max(1);
    match pool {
        Some(pool) if pool.size() > 1 && ncols > 1 => {
            let parts = pool.size().min(ncols);
            let chunk = ncols.div_ceil(parts);
            pool.scope(|s| {
                for ch in cols.chunks_mut(chunk * l) {
                    s.spawn(move || {
                        apply_block_serial(v, t, l, k0, nb, sweep, ch)
                    });
                }
            });
        }
        _ => apply_block_serial(v, t, l, k0, nb, sweep, cols),
    }
}

/// The per-chunk kernel behind [`apply_block`]: for every column,
/// `w = V^T col`, `y = T^T w` (or `T w`), `col -= V y`.  `w`/`y` live on
/// the stack — no per-reflector (or even per-column) heap scratch, the
/// hoisted descendant of the old `apply_reflector_left` allocation.
fn apply_block_serial(
    v: &[f32],
    t: &[f32],
    l: usize,
    k0: usize,
    nb: usize,
    sweep: Sweep,
    cols: &mut [f32],
) {
    let mut w = [0.0f32; PANEL];
    let mut y = [0.0f32; PANEL];
    for col in cols.chunks_mut(l) {
        // W = V^T col (reflector r is zero above row k0 + r)
        for (r, vr) in v.chunks_exact(l).enumerate() {
            w[r] = blas::dot(&vr[k0 + r..], &col[k0 + r..]) as f32;
        }
        // y = T^T w (adjoint) or T w (forward); T is upper triangular
        for s in 0..nb {
            let mut acc = 0.0f64;
            match sweep {
                Sweep::Adjoint => {
                    for r in 0..=s {
                        acc += t[r * PANEL + s] as f64 * w[r] as f64;
                    }
                }
                Sweep::Forward => {
                    for r in s..nb {
                        acc += t[s * PANEL + r] as f64 * w[r] as f64;
                    }
                }
            }
            y[s] = acc as f32;
        }
        // col -= V y
        for (r, vr) in v.chunks_exact(l).enumerate() {
            blas::axpy(-y[r], &vr[k0 + r..], &mut col[k0 + r..]);
        }
    }
}

/// Apply `Q1^T` to a vector of length l, returning length-n `Q1^T b`.
pub fn qt_mul(f: &QrFactors, b: &[f32]) -> Vec<f32> {
    let n = f.r.cols();
    let mut out = vec![0.0f32; n];
    blas::gemv_t(&f.q1, b, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::{gemm, gemm_tn};
    use crate::rng::seeded;

    fn randm(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut g = seeded(seed);
        Matrix::from_fn(rows, cols, |_, _| g.normal_f32())
    }

    // -----------------------------------------------------------------
    // Reference oracle: the pre-blocking reflector-at-a-time kernel,
    // kept verbatim (modulo the hoisted `w` scratch) so the blocked
    // implementation is always checked against the original arithmetic.
    // -----------------------------------------------------------------

    /// `m[:, col_start..] <- (I - 2 v v^T) m[:, col_start..]`, skipping
    /// the first `k` rows where v is zero.  `w_buf` is caller scratch of
    /// at least `cols - col_start` (hoisted out of the reflector loop).
    fn reference_apply_reflector_left(
        m: &mut Matrix,
        v: &[f32],
        k: usize,
        col_start: usize,
        w_buf: &mut [f32],
    ) {
        let (rows, cols) = m.shape();
        debug_assert_eq!(v.len(), rows);
        let w = &mut w_buf[..cols - col_start];
        w.fill(0.0);
        for i in k..rows {
            let vi = v[i];
            if vi != 0.0 {
                blas::axpy(vi, &m.row(i)[col_start..], w);
            }
        }
        for i in k..rows {
            let c = -2.0 * v[i];
            if c != 0.0 {
                blas::axpy(c, w, &mut m.row_mut(i)[col_start..]);
            }
        }
    }

    /// Reflector-at-a-time reduced QR — the numerical oracle.
    fn reference_qr(a: &Matrix) -> QrFactors {
        let (l, n) = a.shape();
        assert!(l >= n);
        let mut work = a.clone();
        let mut vs = vec![0.0f32; n * l];
        let mut w_buf = vec![0.0f32; n];

        for k in 0..n {
            let v = &mut vs[k * l..(k + 1) * l];
            for i in k..l {
                v[i] = work[(i, k)];
            }
            let sigma = blas::dot(&v[k..], &v[k..]).sqrt();
            if sigma == 0.0 {
                v.fill(0.0);
                continue;
            }
            let alpha = if v[k] >= 0.0 { -sigma } else { sigma } as f32;
            v[k] -= alpha;
            let vnorm = blas::dot(&v[k..], &v[k..]).sqrt();
            if vnorm < 1e-30 {
                v.fill(0.0);
                continue;
            }
            let inv = (1.0 / vnorm) as f32;
            for vi in v[k..].iter_mut() {
                *vi *= inv;
            }
            reference_apply_reflector_left(&mut work, v, k, k, &mut w_buf);
        }

        let mut r = Matrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                r[(i, j)] = work[(i, j)];
            }
        }
        let mut q1 = Matrix::from_fn(l, n, |i, j| if i == j { 1.0 } else { 0.0 });
        for k in (0..n).rev() {
            let v = &vs[k * l..(k + 1) * l];
            reference_apply_reflector_left(&mut q1, v, k, k, &mut w_buf);
        }
        QrFactors { q1, r }
    }

    /// Compare two QR factorizations up to per-column sign: the
    /// Householder sign convention reads the sign of a rounding-sensitive
    /// pivot, so two correct implementations may legitimately flip a row
    /// of R (and the matching column of Q1) when that pivot sits at
    /// rounding noise.
    fn assert_matches_up_to_sign(
        f: &QrFactors,
        o: &QrFactors,
        tol: f32,
        ctx: &str,
    ) {
        let (l, n) = f.q1.shape();
        assert_eq!(o.q1.shape(), (l, n), "{ctx}");
        for i in 0..n {
            let s = if f.r[(i, i)] * o.r[(i, i)] < 0.0 { -1.0f32 } else { 1.0 };
            for j in 0..n {
                let d = (f.r[(i, j)] - s * o.r[(i, j)]).abs();
                assert!(d < tol, "{ctx}: R[{i},{j}] diff {d}");
            }
            for row in 0..l {
                let d = (f.q1[(row, i)] - s * o.q1[(row, i)]).abs();
                assert!(d < tol, "{ctx}: Q1[{row},{i}] diff {d}");
            }
        }
    }

    #[test]
    fn reconstruction() {
        for &(l, n) in &[(4, 4), (16, 8), (64, 32), (33, 7), (100, 100)] {
            let a = randm(l, n, l as u64 * 31 + n as u64);
            let f = householder_qr(&a);
            let recon = gemm(&f.q1, &f.r);
            assert!(recon.max_abs_diff(&a) < 5e-4, "({l},{n})");
        }
    }

    #[test]
    fn orthonormal_columns() {
        let a = randm(48, 20, 7);
        let f = householder_qr(&a);
        let qtq = gemm_tn(&f.q1, &f.q1);
        // the blocked recovery composes reflectors through T, so the
        // orthonormality noise floor is a little above the unblocked one
        assert!(qtq.max_abs_diff(&Matrix::eye(20)) < 2e-4);
    }

    #[test]
    fn r_upper_triangular() {
        let a = randm(30, 12, 9);
        let f = householder_qr(&a);
        for i in 0..12 {
            for j in 0..i {
                assert_eq!(f.r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn zero_column_no_nan() {
        let mut a = Matrix::zeros(10, 4);
        for i in 0..10 {
            a[(i, 0)] = 1.0;
            a[(i, 2)] = i as f32;
        }
        let f = householder_qr(&a);
        assert!(f.q1.as_slice().iter().all(|v| v.is_finite()));
        assert!(f.r.as_slice().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn padded_rows_leave_r_and_qtb_unchanged() {
        // QR([A; 0]) must produce the same R and the same Q1^T [b; 0] —
        // this is what makes shape-bucket padding exact (DESIGN.md §3).
        // Re-asserted here against the panel-blocked kernel: the proof
        // depends only on zero rows contributing nothing to any reflector,
        // which blocking does not change.
        let a = randm(20, 8, 13);
        let mut g = seeded(14);
        let b: Vec<f32> = (0..20).map(|_| g.normal_f32()).collect();
        let f = householder_qr(&a);
        let ap = a.pad_rows(32);
        let mut bp = b.clone();
        bp.resize(32, 0.0);
        let fp = householder_qr(&ap);
        // R unique up to sign of rows; our sign convention is deterministic
        assert!(f.r.max_abs_diff(&fp.r) < 1e-4);
        let qtb = qt_mul(&f, &b);
        let qtbp = qt_mul(&fp, &bp);
        for i in 0..8 {
            assert!((qtb[i] - qtbp[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn property_random_shapes() {
        // hand-rolled property sweep (no proptest offline)
        let mut g = seeded(99);
        for case in 0..25 {
            let n = g.gen_range(1, 24);
            let l = n + g.gen_range(0, 24);
            let a = randm(l, n, 1000 + case);
            let f = householder_qr(&a);
            assert!(gemm(&f.q1, &f.r).max_abs_diff(&a) < 2e-3, "case {case} ({l},{n})");
            let qtq = gemm_tn(&f.q1, &f.q1);
            assert!(qtq.max_abs_diff(&Matrix::eye(n)) < 2e-3, "case {case}");
        }
    }

    #[test]
    fn blocked_matches_reference_oracle_across_panel_boundaries() {
        // shapes below, exactly at, one past, and spanning several PANEL
        // boundaries — including square (empty trailing block on the last
        // panel) and very ragged last panels
        for &(l, n) in &[
            (8, 5),
            (40, 31),
            (40, 32),
            (50, 33),
            (90, 64),
            (120, 70),
            (70, 70),
            (33, 7),
        ] {
            let a = randm(l, n, 7000 + (l * 131 + n) as u64);
            let f = householder_qr(&a);
            let o = reference_qr(&a);
            assert_matches_up_to_sign(&f, &o, 2e-3, &format!("({l},{n})"));
        }
    }

    #[test]
    fn blocked_matches_reference_oracle_across_property_sweep() {
        // the same random-shape sweep as `property_random_shapes`, judged
        // against the reflector-at-a-time oracle instead of the algebraic
        // identities
        let mut g = seeded(99);
        for case in 0..25 {
            let n = g.gen_range(1, 24);
            let l = n + g.gen_range(0, 24);
            let a = randm(l, n, 1000 + case);
            let f = householder_qr(&a);
            let o = reference_qr(&a);
            assert_matches_up_to_sign(
                &f,
                &o,
                2e-3,
                &format!("case {case} ({l},{n})"),
            );
        }
    }

    #[test]
    fn pooled_bitwise_matches_serial_at_any_thread_count() {
        // the contract the engines rely on: the pooled trailing sweeps
        // chunk columns, never reorder arithmetic, so factors are
        // bit-identical to the serial kernel
        for &(l, n) in &[(16, 5), (64, 33), (100, 40), (70, 70)] {
            let a = randm(l, n, 4000 + (l * 7 + n) as u64);
            let serial = householder_qr(&a);
            for threads in [2usize, 3, 5] {
                let pool = ThreadPool::new(threads);
                let pooled = householder_qr_pooled(&a, Some(&pool));
                assert_eq!(
                    serial.q1.as_slice(),
                    pooled.q1.as_slice(),
                    "Q1 ({l},{n}) t={threads}"
                );
                assert_eq!(
                    serial.r.as_slice(),
                    pooled.r.as_slice(),
                    "R ({l},{n}) t={threads}"
                );
            }
        }
    }

    #[test]
    fn zero_columns_match_oracle_too() {
        // null reflectors leave zero T rows/columns; the blocked apply
        // must skip them exactly like the unblocked kernel does
        let mut a = Matrix::zeros(12, 5);
        for i in 0..12 {
            a[(i, 0)] = (i + 1) as f32;
            a[(i, 3)] = 1.0 - i as f32 * 0.25;
        }
        let f = householder_qr(&a);
        let o = reference_qr(&a);
        assert!(f.r.max_abs_diff(&o.r) < 1e-4);
        assert!(f.q1.max_abs_diff(&o.q1) < 1e-4);
    }
}
