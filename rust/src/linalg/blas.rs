//! Blocked BLAS-like primitives for the native engine.
//!
//! `gemm` follows the BLIS/GotoBLAS decomposition: the operand matrices
//! are *packed* into contiguous panels sized to the cache hierarchy, and
//! an `MR x NR` register-tiled microkernel does all the flops over the
//! packed panels.  `gemv` accumulates per-row dot products (with a pooled
//! row-chunk-parallel variant for the consensus hot path).
//!
//! # Kernel dispatch (see [`super::simd`])
//!
//! The flop-carrying primitives — [`dot`], [`dot_wide`], [`axpy`],
//! [`widen`] and the gemm microkernel — are thin wrappers over the
//! runtime-dispatched SIMD layer in `linalg::simd`: AVX2+FMA intrinsics
//! when the CPU has them, a **lane-structured scalar fallback**
//! otherwise (or under `DAPC_FORCE_SCALAR=1`).  The two paths are
//! bit-identical by construction — the scalar fallback accumulates in
//! the same fixed 8-lane order with the same horizontal reduction tree
//! the vector path uses — so the dispatch choice, exactly like the
//! thread count, can never change a result.  `simd.rs` documents the
//! contract (lane order, remainder handling, where FMA is and is not
//! allowed, NaN policy); `tests/simd_lane_contract.rs` enforces it
//! bitwise across every `n % 8` remainder class, and the `dapc audit`
//! static pass enforces its preconditions repo-wide (no fused float
//! ops and no order-sensitive reductions outside the kernel layer —
//! see CONTRIBUTING.md, "The determinism contract, statically").
//!
//! # The chunk-stable packing contract
//!
//! The packed-gemm entry points ([`pack_a_strided`], [`pack_b_strided`],
//! [`packed_gemm_into`]) promise that **the f32 accumulation order of
//! every output element is a pure function of its (row-tile, col-tile,
//! depth-block) coordinates** — never of which thread packed a panel,
//! which thread ran a tile, or how the caller chunked the output:
//!
//! * packing is a pure gather: `a_pack[q*k*MR + p*MR + i]` and
//!   `b_pack[q*k*NR + p*NR + j]` are plain copies (zero-padded fringes),
//!   so packing the panels in parallel, in any order, yields identical
//!   buffers;
//! * the microkernel's `MR x NR` lanes are elementwise-independent: a
//!   tile's position selects *which* accumulator lane an element lands
//!   in, never the arithmetic performed on that lane;
//! * depth blocking is a function of `k` alone: every element is
//!   accumulated per `KC` block (accumulator zeroed, `kc` sequential
//!   steps, one add into C), whatever the surrounding tile loops do.
//!
//! Consequence: splitting the *columns* of C across threads (each worker
//! packs its own B panels against one shared packed A) reproduces the
//! serial bits exactly — this is what lets the QR trailing sweeps run
//! through the packed microkernel while keeping `householder_qr_pooled`
//! bitwise-equal to serial at any thread count
//! (`tests/packing_contract.rs` proves the property over every
//! `m % MR` / `n % NR` / `k % 8` remainder class with 1, 2 and 7
//! workers).  The contract holds at *both* kernel tiers: tier-1 changes
//! the per-element rounding (fused multiply-add), not the per-element
//! order, so within one backend tier-1 results are equally
//! chunk-stable.  Small blocks (`m < MR` or `n < NR`) skip packing
//! entirely ([`GemmPath`]): the direct dot/axpy path replays the same
//! per-element order, bitwise-identical to the packed path under
//! tier-0.
//!
//! # Prepacked operands ([`PrepackedPanels`])
//!
//! Packing is a pure gather of a *constant* operand, so an operand that
//! is reused across many products — the per-partition projector `P_j`,
//! applied every consensus epoch for the lifetime of a registered
//! matrix — can pay the pack **once** and keep the panel buffer
//! resident, exactly like prepacked weights in an inference stack.
//! [`PrepackedPanels::from_matrix`] snapshots a row-major matrix into
//! full-depth MR-row panels (the [`pack_a_strided`] layout; the source
//! matrix can be dropped or kept independently), and
//! [`packed_gemm_prepacked_into`] multiplies the resident panels
//! against a freshly packed B, accumulating in **f64** through the wide
//! microkernel (`simd::microkernel_wide_on`): every output element
//! carries the bit-exact value of `dot(row_i(A), col_j(B))`, so the
//! prepacked epoch path equals the per-row `dot`/`dot_wide` path it
//! replaces bit-for-bit, at any thread count and any output chunking
//! (the chunk-stable contract above, strengthened from "pure function
//! of tile coordinates" to "equal to the row dot").  The cost is
//! memory: the panel buffer duplicates the operand
//! (`packed_a_len(m, k)` f32s, ~m·k plus fringe padding), which is why
//! the solver retains panels only for *registered* sessions, never for
//! one-shot solves, and reports the resident bytes in `ServiceStats`.
//!
//! # Block-size tuning (`MC`/`KC`/`NC`)
//!
//! The three cache block sizes map onto the cache hierarchy:
//!
//! * `KC x NR` slivers of the packed B panel are streamed from L1 by the
//!   microkernel, so `KC` is chosen to keep one `MC x KC` A panel
//!   resident in L2: `MC * KC * 4 bytes` ≈ 64 KiB at the defaults —
//!   half of a typical 128-512 KiB L2, leaving room for the B sliver
//!   and C tile;
//! * `KC * NC * 4 bytes` (the packed B panel) targets L3 (512 KiB at the
//!   defaults);
//! * `MR x NR` (4 x 8, defined next to the microkernel in `simd.rs`)
//!   keeps the accumulator tile in registers: 32 f32 accumulators =
//!   4 vector registers of 8 lanes, held explicitly by the AVX2
//!   microkernel and reliably register-allocated by LLVM on the scalar
//!   fallback.
//!
//! Methodology: sweep one constant at a time against
//! `cargo bench --bench microbench_linalg` (the gemm GFLOP/s line) and
//! then confirm on `benches/parallel_scaling.rs` end-to-end — init-phase
//! QR is gemm-shaped, so end-to-end gains track the microbench.  Values
//! below were chosen for a generic x86-64 container; re-tune when the
//! deployment hardware is known (see ROADMAP "Performance").

use super::simd::{self, Backend, KernelTier, MR, NR};
use super::Matrix;
use crate::parallel::ThreadPool;

/// Rows of the packed A panel (L2 block).
pub const MC: usize = 64;
/// Shared (depth) dimension of both packed panels (L1/L2 block).
pub const KC: usize = 256;
/// Columns of the packed B panel (L3 block).
pub const NC: usize = 512;

/// `y += alpha * x` (axpy), runtime-dispatched (`linalg::simd`).
///
/// Elementwise f32 mul + add on both backends — no reduction, no f32
/// FMA — so the dispatch choice never changes a bit.  Length mismatch
/// is checked in release builds too: a silent mismatch here would read
/// past the kernel's assumptions in every caller.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    simd::axpy_on(simd::active(), alpha, x, y)
}

/// Dot product with f64 accumulation, runtime-dispatched
/// (`linalg::simd`).
///
/// Both backends accumulate in the same fixed 8-lane order (8
/// independent f64 accumulators, one shared horizontal reduction tree,
/// sequential `n % 8` tail added last), so the result is bit-identical
/// whichever path runs.  The AVX2 path may fuse the multiply-add: the
/// widened f32 products are exact in f64, so the fused rounding point
/// is the same one the scalar fallback rounds at.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    simd::dot_on(simd::active(), x, y)
}

/// Widen an f32 slice into a caller-provided f64 buffer.  f32 -> f64 is
/// exact, so downstream arithmetic over the widened values is
/// bit-identical to widening on the fly (and vectorizing the conversion
/// is trivially lane-safe).
#[inline]
pub fn widen(src: &[f32], dst: &mut [f64]) {
    simd::widen_on(simd::active(), src, dst)
}

/// [`dot`] against a pre-widened left operand: same fixed 8-lane f64
/// accumulator split, same summation order, same rounding points — the
/// result is bit-identical to `dot(x32, y)` whenever `x[i] == x32[i] as
/// f64`.  The batched multi-RHS update uses this to widen each projector
/// row ONCE and reuse it across every column of the batch.  (Unlike
/// [`dot`], no backend may fuse here: a general 53-bit x 24-bit product
/// is not exact, so both paths round the product before accumulating.)
#[inline]
pub fn dot_wide(x: &[f64], y: &[f32]) -> f64 {
    simd::dot_wide_on(simd::active(), x, y)
}

/// `y = A x` for row-major A (rows x cols), x of length cols.
pub fn gemv(a: &Matrix, x: &[f32], y: &mut [f32]) {
    assert_eq!(a.cols(), x.len());
    assert_eq!(a.rows(), y.len());
    for i in 0..a.rows() {
        y[i] = dot(a.row(i), x) as f32;
    }
}

/// `y = A x` with the row range split across pool workers.
///
/// Bitwise-identical to [`gemv`] for any thread count: each output row is
/// an independent [`dot`] over the same operands in the same order, so
/// parallelism never reorders a reduction.  Must not be called from
/// inside another scope on the same pool (the pool does not nest).
pub fn gemv_pooled(pool: &ThreadPool, a: &Matrix, x: &[f32], y: &mut [f32]) {
    assert_eq!(a.cols(), x.len());
    assert_eq!(a.rows(), y.len());
    let rows = a.rows();
    if rows == 0 {
        return;
    }
    let parts = pool.size().min(rows).max(1);
    let chunk = rows.div_ceil(parts);
    pool.scope(|s| {
        for (ci, yc) in y.chunks_mut(chunk).enumerate() {
            let lo = ci * chunk;
            s.spawn(move || {
                for (r, yi) in yc.iter_mut().enumerate() {
                    *yi = dot(a.row(lo + r), x) as f32;
                }
            });
        }
    });
}

/// `y = A^T x` for row-major A, x of length rows (avoids materializing A^T).
pub fn gemv_t(a: &Matrix, x: &[f32], y: &mut [f32]) {
    assert_eq!(a.rows(), x.len());
    assert_eq!(a.cols(), y.len());
    y.fill(0.0);
    for i in 0..a.rows() {
        axpy(x[i], a.row(i), y);
    }
}

/// `C = A B` (packed panels + register-tiled microkernel, row-major).
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows(), b.cols());
    gemm_into(a, b, &mut c);
    c
}

/// Which gemm inner path [`gemm_into_on`] takes.
///
/// `Auto` picks `Direct` exactly when the output is thinner than one
/// microtile (`m < MR` or `n < NR`) — the fat-regime projector blocks
/// and single-vector products where packing overhead is a recorded
/// loss — and `Packed` otherwise.  The choice is a pure function of the
/// problem shape, and under tier-0 the two paths agree bitwise anyway
/// (regression-tested below), so dispatch never costs reproducibility.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum GemmPath {
    /// Shape-deterministic choice between the other two.
    #[default]
    Auto,
    /// Packed panels + register-tiled microkernel (the BLIS nest).
    Packed,
    /// No packing: per-row axpy accumulation (same per-element order).
    Direct,
}

/// `C = A B` into a caller-provided output (overwritten).
///
/// Shape-dispatched ([`GemmPath::Auto`]) under the process-default
/// backend and kernel tier.
pub fn gemm_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    gemm_into_on(simd::active(), simd::active_tier(), GemmPath::Auto, a, b, c)
}

/// [`gemm_into`] with an explicit inner path (benches and the crossover
/// regression tests pin `Packed` / `Direct` to compare them).
pub fn gemm_into_with(path: GemmPath, a: &Matrix, b: &Matrix, c: &mut Matrix) {
    gemm_into_on(simd::active(), simd::active_tier(), path, a, b, c)
}

/// `C = A B` with every dispatch decision explicit: backend, kernel
/// tier, and inner path.  The engines route through this so a per-solve
/// [`KernelTier`] override reaches the flop-carrying loops.
pub fn gemm_into_on(
    backend: Backend,
    tier: KernelTier,
    path: GemmPath,
    a: &Matrix,
    b: &Matrix,
    c: &mut Matrix,
) {
    assert_eq!(a.cols(), b.rows(), "gemm inner dimension mismatch");
    assert_eq!(c.rows(), a.rows(), "gemm output rows mismatch");
    assert_eq!(c.cols(), b.cols(), "gemm output cols mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    c.as_mut_slice().fill(0.0);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let direct = match path {
        GemmPath::Auto => m < MR || n < NR,
        GemmPath::Packed => false,
        GemmPath::Direct => true,
    };
    if direct {
        gemm_direct(backend, a, b, c);
        return;
    }

    // pack buffers sized to the largest panel this problem needs
    let kc_max = KC.min(k);
    let mc_max = round_up(MC.min(m), MR);
    let nc_max = round_up(NC.min(n), NR);
    let mut a_pack = vec![0.0f32; mc_max * kc_max];
    let mut b_pack = vec![0.0f32; kc_max * nc_max];

    let mut jc = 0;
    while jc < n {
        let nc = NC.min(n - jc);
        let col_panels = nc.div_ceil(NR);
        let mut pc = 0;
        while pc < k {
            let kc = KC.min(k - pc);
            pack_b(b, pc, jc, kc, nc, &mut b_pack);
            let mut ic = 0;
            while ic < m {
                let mc = MC.min(m - ic);
                let row_panels = mc.div_ceil(MR);
                pack_a(a, ic, pc, mc, kc, &mut a_pack);
                for q in 0..col_panels {
                    let jr = q * NR;
                    let nr = NR.min(nc - jr);
                    let bp = &b_pack[q * kc * NR..(q + 1) * kc * NR];
                    for t in 0..row_panels {
                        let ir = t * MR;
                        let mr = MR.min(mc - ir);
                        let ap = &a_pack[t * kc * MR..(t + 1) * kc * MR];
                        let mut acc = [[0.0f32; NR]; MR];
                        simd::microkernel_tier_on(backend, tier, kc, ap, bp, &mut acc);
                        // fringe lanes were zero-padded in the packs, so
                        // the full tile is valid; write only the live part
                        for i in 0..mr {
                            let crow = c.row_mut(ic + ir + i);
                            for (j, &v) in acc[i][..nr].iter().enumerate() {
                                crow[jc + jr + j] += v;
                            }
                        }
                    }
                }
                ic += MC;
            }
            pc += KC;
        }
        jc += NC;
    }
}

/// The no-packing inner path for thin outputs (`m < MR` or `n < NR`).
///
/// Replays the packed path's per-element accumulation order exactly —
/// per `KC` depth block: zero a per-row f32 accumulator, one [`axpy`]
/// per depth step (f32 mul + add, same rounding as the tier-0
/// microkernel lane step), then fold the block into C — so under tier-0
/// the two paths are bitwise-identical for every shape.  The direct
/// path is tier-independent (axpy never fuses): at tier-1 the paths may
/// differ by fused rounding, but [`GemmPath::Auto`] is a pure function
/// of shape, so any given product always takes the same path.
fn gemm_direct(backend: Backend, a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut acc_row = vec![0.0f32; n];
    let mut pc = 0;
    while pc < k {
        let kc = KC.min(k - pc);
        for i in 0..m {
            acc_row.fill(0.0);
            let arow = a.row(i);
            for p in 0..kc {
                simd::axpy_on(backend, arow[pc + p], b.row(pc + p), &mut acc_row);
            }
            for (cj, aj) in c.row_mut(i).iter_mut().zip(&acc_row) {
                *cj += *aj;
            }
        }
        pc += KC;
    }
}

#[inline]
fn round_up(x: usize, m: usize) -> usize {
    x.div_ceil(m) * m
}

/// Pack an `mc x kc` block of A into MR-row panels, k-major inside each
/// panel: `buf[q*kc*MR + p*MR + i] = A[ic + q*MR + i, pc + p]` (zero
/// padding for the ragged last panel).
fn pack_a(
    a: &Matrix,
    ic: usize,
    pc: usize,
    mc: usize,
    kc: usize,
    buf: &mut [f32],
) {
    let panels = mc.div_ceil(MR);
    for q in 0..panels {
        let r0 = q * MR;
        let rows = MR.min(mc - r0);
        let base = q * kc * MR;
        for i in 0..MR {
            if i < rows {
                let row = &a.row(ic + r0 + i)[pc..pc + kc];
                for (p, &v) in row.iter().enumerate() {
                    buf[base + p * MR + i] = v;
                }
            } else {
                for p in 0..kc {
                    buf[base + p * MR + i] = 0.0;
                }
            }
        }
    }
}

/// Pack a `kc x nc` block of B into NR-column panels, k-major inside each
/// panel: `buf[q*kc*NR + p*NR + j] = B[pc + p, jc + q*NR + j]` (zero
/// padding for the ragged last panel).
fn pack_b(
    b: &Matrix,
    pc: usize,
    jc: usize,
    kc: usize,
    nc: usize,
    buf: &mut [f32],
) {
    let panels = nc.div_ceil(NR);
    for p in 0..kc {
        let brow = b.row(pc + p);
        for q in 0..panels {
            let c0 = q * NR;
            let cols = NR.min(nc - c0);
            let off = q * kc * NR + p * NR;
            buf[off..off + cols]
                .copy_from_slice(&brow[jc + c0..jc + c0 + cols]);
            for j in cols..NR {
                buf[off + j] = 0.0;
            }
        }
    }
}

/// Length of a full-depth packed A buffer for an `m x k` operand:
/// `m.div_ceil(MR)` MR-row panels, each `k * MR` long (fringe rows
/// zero-padded by the packer).
#[inline]
pub fn packed_a_len(m: usize, k: usize) -> usize {
    m.div_ceil(MR) * MR * k
}

/// Length of a full-depth packed B buffer for a `k x n` operand:
/// `n.div_ceil(NR)` NR-column panels, each `k * NR` long.
#[inline]
pub fn packed_b_len(k: usize, n: usize) -> usize {
    n.div_ceil(NR) * NR * k
}

/// Pack a strided `m x k` operand into full-depth MR-row panels:
/// `buf[q*k*MR + p*MR + i] = src[(q*MR + i)*rs + p*cs]` (ragged last
/// panel zero-padded).
///
/// A pure gather — part of the chunk-stable packing contract (module
/// docs): packing panels in any order, on any thread, produces
/// identical bytes.  The stride pair expresses both orientations
/// without a copy: `rs = ld, cs = 1` packs row-major rows, `rs = 1,
/// cs = ld` packs a column-major view (i.e. the transpose) — the QR
/// sweeps use both over the same reflector block.
pub fn pack_a_strided(src: &[f32], rs: usize, cs: usize, m: usize, k: usize, buf: &mut [f32]) {
    let panels = m.div_ceil(MR);
    assert!(buf.len() >= panels * MR * k, "packed A buffer too short");
    if m > 0 && k > 0 {
        // highest index touched by the gather below
        assert!((m - 1) * rs + (k - 1) * cs < src.len(), "packed A source too short");
    }
    for q in 0..panels {
        let r0 = q * MR;
        let rows = MR.min(m - r0);
        let base = q * k * MR;
        for i in 0..MR {
            if i < rows {
                for p in 0..k {
                    buf[base + p * MR + i] = src[(r0 + i) * rs + p * cs];
                }
            } else {
                for p in 0..k {
                    buf[base + p * MR + i] = 0.0;
                }
            }
        }
    }
}

/// Pack a strided `k x n` operand into full-depth NR-column panels:
/// `buf[q*k*NR + p*NR + j] = src[p*rs + (q*NR + j)*cs]` (ragged last
/// panel zero-padded).  Same pure-gather contract as
/// [`pack_a_strided`].
pub fn pack_b_strided(src: &[f32], rs: usize, cs: usize, k: usize, n: usize, buf: &mut [f32]) {
    let panels = n.div_ceil(NR);
    assert!(buf.len() >= panels * NR * k, "packed B buffer too short");
    if n > 0 && k > 0 {
        assert!((k - 1) * rs + (n - 1) * cs < src.len(), "packed B source too short");
    }
    for q in 0..panels {
        let c0 = q * NR;
        let cols = NR.min(n - c0);
        let base = q * k * NR;
        for p in 0..k {
            let off = base + p * NR;
            for j in 0..cols {
                buf[off + j] = src[p * rs + (c0 + j) * cs];
            }
            for j in cols..NR {
                buf[off + j] = 0.0;
            }
        }
    }
}

/// How [`packed_gemm_into`] combines the product with the output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Accum {
    /// `C = A B` (the output's prior contents never enter the sum).
    Store,
    /// `C -= A B` (the trailing-update shape `A -= V (T^T W)`).
    Sub,
}

/// Register-tiled gemm over **pre-packed** operands, with strided
/// output: `C (+)= op(A_pack B_pack)` per [`Accum`].
///
/// The caller packs once with [`pack_a_strided`] / [`pack_b_strided`]
/// and may reuse either pack across many calls — the QR trailing sweep
/// packs the reflector block once per panel and streams every trailing
/// column chunk against it.  `c[(i, j)]` lives at `i*rs_c + j*cs_c`, so
/// both row-major chunks and column-major scratch (the `W` buffer) are
/// valid outputs without a transpose.
///
/// Accumulation order per element is fixed by the contract (module
/// docs): per `KC` depth block — accumulator zeroed, `kc` sequential
/// fused-or-not steps (per `tier`), one combine into C (`Store`: first
/// block writes, later blocks add; `Sub`: every block subtracts).  The
/// order is a pure function of (i, j, k), so results are independent of
/// how the caller chunked rows or columns across threads.
pub fn packed_gemm_into(
    backend: Backend,
    tier: KernelTier,
    m: usize,
    n: usize,
    k: usize,
    a_pack: &[f32],
    b_pack: &[f32],
    accum: Accum,
    c: &mut [f32],
    rs_c: usize,
    cs_c: usize,
) {
    assert!(a_pack.len() >= packed_a_len(m, k), "packed A too short");
    assert!(b_pack.len() >= packed_b_len(k, n), "packed B too short");
    if m == 0 || n == 0 {
        return;
    }
    assert!(
        (m - 1) * rs_c + (n - 1) * cs_c < c.len(),
        "packed gemm output too short"
    );
    if k == 0 {
        if accum == Accum::Store {
            for i in 0..m {
                for j in 0..n {
                    c[i * rs_c + j * cs_c] = 0.0;
                }
            }
        }
        return;
    }
    let row_panels = m.div_ceil(MR);
    let col_panels = n.div_ceil(NR);
    for q in 0..col_panels {
        let nr = NR.min(n - q * NR);
        for t in 0..row_panels {
            let mr = MR.min(m - t * MR);
            let mut pc = 0;
            while pc < k {
                let kc = KC.min(k - pc);
                // full-depth panels keep each depth block's sliver
                // contiguous: panel stride k*MR (k*NR), block offset pc
                let ap = &a_pack[t * k * MR + pc * MR..][..kc * MR];
                let bp = &b_pack[q * k * NR + pc * NR..][..kc * NR];
                let mut acc = [[0.0f32; NR]; MR];
                simd::microkernel_tier_on(backend, tier, kc, ap, bp, &mut acc);
                for i in 0..mr {
                    for (j, &v) in acc[i][..nr].iter().enumerate() {
                        let idx = (t * MR + i) * rs_c + (q * NR + j) * cs_c;
                        match accum {
                            Accum::Store if pc == 0 => c[idx] = v,
                            Accum::Store => c[idx] += v,
                            Accum::Sub => c[idx] -= v,
                        }
                    }
                }
                pc += KC;
            }
        }
    }
}

/// A matrix packed once into full-depth MR-row A-panels and kept
/// resident for reuse across many products (module docs, "Prepacked
/// operands").  The epoch loop builds one per projector at
/// `register_matrix` time and streams every epoch's B panels against
/// it via [`packed_gemm_prepacked_into`].
#[derive(Debug, Clone)]
pub struct PrepackedPanels {
    buf: Vec<f32>,
    m: usize,
    k: usize,
}

impl PrepackedPanels {
    /// Pack a row-major `m x k` matrix ([`pack_a_strided`] with
    /// `rs = k, cs = 1`).  Pure gather: the result is a deterministic
    /// function of the matrix bytes.
    pub fn from_matrix(a: &Matrix) -> Self {
        let (m, k) = a.shape();
        let mut buf = vec![0.0f32; packed_a_len(m, k)];
        pack_a_strided(a.as_slice(), k, 1, m, k, &mut buf);
        PrepackedPanels { buf, m, k }
    }

    /// Rows of the packed operand.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Columns (depth) of the packed operand.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Resident bytes of the panel buffer (the pack-once memory
    /// tradeoff `ServiceStats` reports).
    pub fn bytes(&self) -> usize {
        self.buf.len() * std::mem::size_of::<f32>()
    }

    /// The raw panel buffer (`buf[t*k*MR + p*MR + i]`, fringe rows
    /// zero-padded).
    pub fn panels(&self) -> &[f32] {
        &self.buf
    }
}

/// Wide-microkernel gemm over a resident prepacked A and a packed B:
/// `C[i - row0, j] = Σ_p A[i, p] · B[p, j]` for `i` in
/// `row0..row0 + rows`, f64 accumulation, **overwriting** C.
///
/// Unlike [`packed_gemm_into`] the depth is never split into `KC`
/// blocks: each output element is one full-depth pass of the wide
/// microkernel, whose lane discipline makes it bit-equal to
/// `dot(row_i(A), col_j(B))` under tier-0 (`simd.rs` module docs).
/// `row0` must be MR-aligned so a row range addresses whole panels —
/// callers split C across threads at MR boundaries, and because each
/// element is a pure function of its own row and column, any such split
/// reproduces the serial bits.  `c[(i - row0, j)]` lives at
/// `(i - row0)*rs_c + j*cs_c`.
#[allow(clippy::too_many_arguments)]
pub fn packed_gemm_prepacked_into(
    backend: Backend,
    tier: KernelTier,
    a: &PrepackedPanels,
    row0: usize,
    rows: usize,
    n: usize,
    b_pack: &[f32],
    c: &mut [f32],
    rs_c: usize,
    cs_c: usize,
) {
    let k = a.k;
    assert_eq!(row0 % MR, 0, "prepacked row range must be MR-aligned");
    assert!(row0 + rows <= a.m, "prepacked row range out of bounds");
    assert!(b_pack.len() >= packed_b_len(k, n), "packed B too short");
    if rows == 0 || n == 0 {
        return;
    }
    assert!(
        (rows - 1) * rs_c + (n - 1) * cs_c < c.len(),
        "prepacked gemm output too short"
    );
    let t0 = row0 / MR;
    let row_panels = (row0 + rows).div_ceil(MR) - t0;
    let col_panels = n.div_ceil(NR);
    for q in 0..col_panels {
        let nr = NR.min(n - q * NR);
        let bpanel = &b_pack[q * k * NR..(q + 1) * k * NR];
        for t in 0..row_panels {
            let ir = (t0 + t) * MR;
            let mr = MR.min(row0 + rows - ir);
            let ap = &a.buf[(t0 + t) * k * MR..(t0 + t + 1) * k * MR];
            let mut out = [[0.0f64; NR]; MR];
            simd::microkernel_wide_tier_on(backend, tier, k, ap, bpanel, &mut out);
            for (i, orow) in out.iter().enumerate().take(mr) {
                let ci = ir + i - row0;
                for (j, &v) in orow[..nr].iter().enumerate() {
                    c[ci * rs_c + (q * NR + j) * cs_c] = v as f32;
                }
            }
        }
    }
}

/// `C = A^T B` without materializing the transpose.
pub fn gemm_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows());
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    for kk in 0..k {
        let arow = a.row(kk);
        let brow = b.row(kk);
        for i in 0..m {
            let aik = arow[i];
            if aik != 0.0 {
                axpy(aik, brow, c.row_mut(i));
            }
        }
    }
    c
}

/// Gram matrix `A^T A` exploiting symmetry (classical-APC init cost).
pub fn gram(a: &Matrix) -> Matrix {
    let n = a.cols();
    let mut g = Matrix::zeros(n, n);
    for r in 0..a.rows() {
        let row = a.row(r);
        for i in 0..n {
            let ri = row[i];
            if ri != 0.0 {
                // only the upper triangle
                for j in i..n {
                    g[(i, j)] += ri * row[j];
                }
            }
        }
    }
    for i in 0..n {
        for j in 0..i {
            g[(i, j)] = g[(j, i)];
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::seeded;

    fn randm(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut g = seeded(seed);
        Matrix::from_fn(rows, cols, |_, _| g.normal_f32())
    }

    fn naive_gemm(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0f64;
                for k in 0..a.cols() {
                    s += a[(i, k)] as f64 * b[(k, j)] as f64;
                }
                c[(i, j)] = s as f32;
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive() {
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 33, 9), (70, 300, 40)] {
            let a = randm(m, k, 1);
            let b = randm(k, n, 2);
            let c = gemm(&a, &b);
            assert!(c.max_abs_diff(&naive_gemm(&a, &b)) < 1e-3, "({m},{k},{n})");
        }
    }

    #[test]
    fn gemm_fringe_and_blocking_shapes() {
        // shapes straddling every blocking boundary: the MR/NR fringes,
        // multi-panel MC/KC/NC loops, and exact multiples
        for &(m, k, n) in &[
            (4, 8, 8),     // exact single tile
            (5, 9, 11),    // all fringes
            (64, 256, 8),  // exact MC x KC panel
            (65, 257, 9),  // one past every L2 block edge
            (130, 70, 17), // several row panels, ragged everywhere
        ] {
            let a = randm(m, k, (m * 1000 + n) as u64);
            let b = randm(k, n, (k * 7 + 3) as u64);
            let c = gemm(&a, &b);
            assert!(
                c.max_abs_diff(&naive_gemm(&a, &b)) < 1e-3,
                "({m},{k},{n})"
            );
        }
    }

    #[test]
    fn gemm_into_overwrites_dirty_output() {
        let a = randm(6, 5, 10);
        let b = randm(5, 7, 11);
        let mut c = Matrix::from_fn(6, 7, |_, _| 123.0);
        gemm_into(&a, &b, &mut c);
        assert!(c.max_abs_diff(&naive_gemm(&a, &b)) < 1e-3);
    }

    #[test]
    fn gemm_tn_matches_explicit_transpose() {
        let a = randm(20, 12, 3);
        let b = randm(20, 7, 4);
        let c = gemm_tn(&a, &b);
        let want = gemm(&a.transpose(), &b);
        assert!(c.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn gram_matches_gemm() {
        let a = randm(30, 10, 5);
        let g = gram(&a);
        let want = gemm(&a.transpose(), &a);
        assert!(g.max_abs_diff(&want) < 1e-3);
        // symmetric
        assert!(g.max_abs_diff(&g.transpose()) < 1e-9);
    }

    #[test]
    fn gemv_both_orientations() {
        let a = randm(9, 13, 6);
        let x: Vec<f32> = (0..13).map(|i| i as f32 * 0.1).collect();
        let mut y = vec![0.0; 9];
        gemv(&a, &x, &mut y);
        let xv = Matrix::from_vec(13, 1, x.clone());
        let want = gemm(&a, &xv);
        for i in 0..9 {
            assert!((y[i] - want[(i, 0)]).abs() < 1e-4);
        }

        let z: Vec<f32> = (0..9).map(|i| 1.0 - i as f32 * 0.2).collect();
        let mut w = vec![0.0; 13];
        gemv_t(&a, &z, &mut w);
        let zv = Matrix::from_vec(9, 1, z);
        let want_t = gemm(&a.transpose(), &zv);
        for i in 0..13 {
            assert!((w[i] - want_t[(i, 0)]).abs() < 1e-4);
        }
    }

    #[test]
    fn gemv_pooled_bitwise_matches_serial() {
        let pool = ThreadPool::new(3);
        // rows chosen to leave a ragged last chunk
        for &(rows, cols) in &[(1, 5), (7, 16), (64, 33), (101, 29)] {
            let a = randm(rows, cols, rows as u64 + 50);
            let mut g = seeded(rows as u64 + 51);
            let x: Vec<f32> = (0..cols).map(|_| g.normal_f32()).collect();
            let mut y_serial = vec![0.0f32; rows];
            let mut y_pooled = vec![0.0f32; rows];
            gemv(&a, &x, &mut y_serial);
            gemv_pooled(&pool, &a, &x, &mut y_pooled);
            assert_eq!(y_serial, y_pooled, "({rows},{cols})");
        }
    }

    #[test]
    fn dot_wide_bitwise_matches_dot() {
        // the batched-solve contract: widening the left operand up front
        // must not change a single output bit, at any length (all tail
        // classes of the fixed 8-lane accumulator split)
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 31, 257] {
            let mut g = seeded(900 + len as u64);
            let x: Vec<f32> = (0..len).map(|_| g.normal_f32()).collect();
            let y: Vec<f32> = (0..len).map(|_| g.normal_f32()).collect();
            let mut xw = vec![0.0f64; len];
            widen(&x, &mut xw);
            assert_eq!(dot(&x, &y).to_bits(), dot_wide(&xw, &y).to_bits());
        }
    }

    #[test]
    fn dispatched_kernels_match_pinned_scalar_bitwise() {
        // whatever backend `active()` picked (native leg or the
        // DAPC_FORCE_SCALAR=1 CI leg), the public wrappers must agree
        // bitwise with the lane-structured scalar reference — the full
        // remainder-class sweep lives in tests/simd_lane_contract.rs
        use crate::linalg::simd::{self, Backend};
        let mut g = seeded(321);
        for len in [0usize, 1, 7, 8, 9, 64, 130] {
            let x: Vec<f32> = (0..len).map(|_| g.normal_f32()).collect();
            let y: Vec<f32> = (0..len).map(|_| g.normal_f32()).collect();
            assert_eq!(
                dot(&x, &y).to_bits(),
                simd::dot_on(Backend::Scalar, &x, &y).to_bits(),
                "dot len {len}"
            );
            let mut ya = y.clone();
            let mut yb = y.clone();
            axpy(0.37, &x, &mut ya);
            simd::axpy_on(Backend::Scalar, 0.37, &x, &mut yb);
            assert_eq!(ya, yb, "axpy len {len}");
        }
    }

    #[test]
    fn dot_f64_accumulation_stability() {
        // catastrophic in pure f32: 1e8 + tiny values
        let x = vec![1.0f32; 4096];
        let mut y = vec![1e-4f32; 4096];
        y[0] = 1e8;
        let d = dot(&x, &y);
        assert!((d - (1e8 + 4095.0 * 1e-4)).abs() / 1e8 < 1e-9);
    }

    #[test]
    fn axpy_basic() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [1.0f32, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
    }

    #[test]
    #[should_panic]
    fn axpy_length_mismatch_panics_in_release_too() {
        let x = [1.0f32, 2.0];
        let mut y = [0.0f32; 3];
        axpy(1.0, &x, &mut y);
    }

    #[test]
    #[should_panic]
    fn dot_length_mismatch_panics_in_release_too() {
        let _ = dot(&[1.0, 2.0], &[1.0]);
    }

    #[test]
    fn direct_and_packed_paths_agree_bitwise_under_tier0() {
        // the per-shape dispatch regression: whatever Auto would pick,
        // both inner paths must produce identical bits at tier-0 —
        // shapes cover the crossover region (thin m, thin n, both, and
        // fat shapes that straddle a KC depth boundary)
        let backend = simd::active();
        for &(m, k, n) in &[
            (1, 5, 1),
            (2, 300, 3),   // thin both ways, multi-KC depth
            (3, 17, 40),   // m < MR only
            (40, 17, 5),   // n < NR only
            (13, 257, 23), // fat: packed is the natural path
        ] {
            let a = randm(m, k, (m * 31 + k) as u64);
            let b = randm(k, n, (n * 17 + 1) as u64);
            let mut c_direct = Matrix::zeros(m, n);
            let mut c_packed = Matrix::zeros(m, n);
            gemm_into_on(
                backend,
                KernelTier::Deterministic,
                GemmPath::Direct,
                &a,
                &b,
                &mut c_direct,
            );
            gemm_into_on(
                backend,
                KernelTier::Deterministic,
                GemmPath::Packed,
                &a,
                &b,
                &mut c_packed,
            );
            let db: Vec<u32> = c_direct.as_slice().iter().map(|v| v.to_bits()).collect();
            let pb: Vec<u32> = c_packed.as_slice().iter().map(|v| v.to_bits()).collect();
            assert_eq!(db, pb, "({m},{k},{n})");
        }
    }

    #[test]
    fn auto_path_small_shapes_match_naive() {
        // Auto sends these through the direct path; accuracy must hold
        for &(m, k, n) in &[(1, 1, 1), (3, 40, 2), (2, 513, 7), (1, 9, 100)] {
            let a = randm(m, k, (m + k) as u64);
            let b = randm(k, n, (k + n + 7) as u64);
            let c = gemm(&a, &b);
            assert!(c.max_abs_diff(&naive_gemm(&a, &b)) < 1e-3, "({m},{k},{n})");
        }
    }

    #[test]
    fn packed_gemm_into_matches_gemm_into() {
        // the pre-packed entry must agree with the blocked path exactly:
        // both accumulate per KC depth block in the same per-element
        // order, and Store-first-block == fill(0.0)-then-add up to the
        // sign of zero (exercised shapes avoid exact-zero outputs)
        let backend = simd::active();
        for &(m, k, n) in &[(4, 8, 8), (5, 9, 11), (33, 300, 17), (12, 256, 8)] {
            let a = randm(m, k, (m * 7 + k) as u64);
            let b = randm(k, n, (n * 3 + k) as u64);
            let mut a_pack = vec![0.0f32; packed_a_len(m, k)];
            let mut b_pack = vec![0.0f32; packed_b_len(k, n)];
            pack_a_strided(a.as_slice(), k, 1, m, k, &mut a_pack);
            pack_b_strided(b.as_slice(), n, 1, k, n, &mut b_pack);
            let mut c = Matrix::from_fn(m, n, |_, _| 99.0); // dirty: Store must win
            packed_gemm_into(
                backend,
                KernelTier::Deterministic,
                m,
                n,
                k,
                &a_pack,
                &b_pack,
                Accum::Store,
                c.as_mut_slice(),
                n,
                1,
            );
            let mut want = Matrix::zeros(m, n);
            gemm_into_on(
                backend,
                KernelTier::Deterministic,
                GemmPath::Packed,
                &a,
                &b,
                &mut want,
            );
            let cb: Vec<u32> = c.as_slice().iter().map(|v| v.to_bits()).collect();
            let wb: Vec<u32> = want.as_slice().iter().map(|v| v.to_bits()).collect();
            assert_eq!(cb, wb, "({m},{k},{n})");
        }
    }

    #[test]
    fn packed_gemm_sub_and_column_major_output() {
        // Sub into a strided (column-major) output — the exact shape of
        // the QR trailing update writing W / subtracting V(T^T W)
        let backend = simd::active();
        let (m, k, n) = (7, 19, 5);
        let a = randm(m, k, 71);
        let b = randm(k, n, 72);
        let mut a_pack = vec![0.0f32; packed_a_len(m, k)];
        let mut b_pack = vec![0.0f32; packed_b_len(k, n)];
        pack_a_strided(a.as_slice(), k, 1, m, k, &mut a_pack);
        pack_b_strided(b.as_slice(), n, 1, k, n, &mut b_pack);
        // column-major C: element (i, j) at i + j*m
        let mut c = vec![0.5f32; m * n];
        packed_gemm_into(
            backend,
            KernelTier::Deterministic,
            m,
            n,
            k,
            &a_pack,
            &b_pack,
            Accum::Sub,
            &mut c,
            1,
            m,
        );
        let prod = gemm(&a, &b);
        for i in 0..m {
            for j in 0..n {
                let want = 0.5 - prod[(i, j)];
                assert!((c[i + j * m] - want).abs() < 1e-4, "({i},{j})");
            }
        }
    }

    #[test]
    fn pack_strided_transpose_view() {
        // rs=1, cs=ld packs the transpose without materializing it: the
        // QR sweep packs V^T (rows = contiguous reflectors) this way
        let (rows, cols) = (6, 9);
        let a = randm(rows, cols, 80);
        let at = a.transpose();
        let mut direct = vec![0.0f32; packed_a_len(cols, rows)];
        let mut viewed = vec![0.0f32; packed_a_len(cols, rows)];
        pack_a_strided(at.as_slice(), rows, 1, cols, rows, &mut direct);
        pack_a_strided(a.as_slice(), 1, cols, cols, rows, &mut viewed);
        assert_eq!(direct, viewed);
    }

    #[test]
    fn prepacked_gemm_is_row_dot_bitwise() {
        // the tentpole contract: every element of the prepacked product
        // equals dot(row_i(A), col_j(B)) bit-for-bit — shapes cover MR
        // and NR fringes and every k % 8 class the epoch loop can see
        let backend = simd::active();
        for &(m, k, n) in &[
            (4, 8, 8),
            (5, 9, 3),
            (16, 29, 1),
            (13, 31, 11),
            (24, 64, 8),
        ] {
            let a = randm(m, k, (m * 13 + k) as u64);
            let b = randm(k, n, (n * 11 + k) as u64);
            let packs = PrepackedPanels::from_matrix(&a);
            assert_eq!((packs.m(), packs.k()), (m, k));
            assert_eq!(packs.bytes(), packed_a_len(m, k) * 4);
            let mut b_pack = vec![0.0f32; packed_b_len(k, n)];
            pack_b_strided(b.as_slice(), n, 1, k, n, &mut b_pack);
            let mut c = vec![9.0f32; m * n];
            packed_gemm_prepacked_into(
                backend,
                KernelTier::Deterministic,
                &packs,
                0,
                m,
                n,
                &b_pack,
                &mut c,
                n,
                1,
            );
            for i in 0..m {
                for j in 0..n {
                    let col: Vec<f32> = (0..k).map(|p| b[(p, j)]).collect();
                    let want = dot(a.row(i), &col) as f32;
                    assert_eq!(
                        c[i * n + j].to_bits(),
                        want.to_bits(),
                        "({m},{k},{n}) at ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn prepacked_gemm_row_chunks_reproduce_full_sweep() {
        // MR-aligned row chunks into disjoint output buffers must equal
        // the one-shot full sweep — the pooled fan-out shape
        let backend = simd::active();
        let (m, k, n) = (21, 37, 9);
        let a = randm(m, k, 91);
        let b = randm(k, n, 92);
        let packs = PrepackedPanels::from_matrix(&a);
        let mut b_pack = vec![0.0f32; packed_b_len(k, n)];
        pack_b_strided(b.as_slice(), n, 1, k, n, &mut b_pack);
        let mut full = vec![0.0f32; m * n];
        packed_gemm_prepacked_into(
            backend,
            KernelTier::Deterministic,
            &packs,
            0,
            m,
            n,
            &b_pack,
            &mut full,
            n,
            1,
        );
        let mut chunked = vec![0.0f32; m * n];
        let rows_per = 2 * MR; // MR-aligned, leaves a ragged tail chunk
        for (ci, cbuf) in chunked.chunks_mut(rows_per * n).enumerate() {
            let lo = ci * rows_per;
            let rows = rows_per.min(m - lo);
            packed_gemm_prepacked_into(
                backend,
                KernelTier::Deterministic,
                &packs,
                lo,
                rows,
                n,
                &b_pack,
                cbuf,
                n,
                1,
            );
        }
        let fb: Vec<u32> = full.iter().map(|v| v.to_bits()).collect();
        let cb: Vec<u32> = chunked.iter().map(|v| v.to_bits()).collect();
        assert_eq!(fb, cb);
    }

    #[test]
    fn packed_gemm_k_zero_store_zero_fills() {
        let mut c = vec![7.0f32; 6];
        packed_gemm_into(
            simd::active(),
            KernelTier::Deterministic,
            2,
            3,
            0,
            &[],
            &[],
            Accum::Store,
            &mut c,
            3,
            1,
        );
        assert_eq!(c, vec![0.0; 6]);
        // Sub with k == 0 leaves the output untouched
        let mut d = vec![7.0f32; 6];
        packed_gemm_into(
            simd::active(),
            KernelTier::Deterministic,
            2,
            3,
            0,
            &[],
            &[],
            Accum::Sub,
            &mut d,
            3,
            1,
        );
        assert_eq!(d, vec![7.0; 6]);
    }
}
