//! Panel-blocked Householder QR factorization (paper §2, eq. (1)).
//!
//! Reduced (economy) form `A = Q1 R` for tall `A` (l x n, l >= n): `Q1` is
//! (l x n) with orthonormal columns, `R` is (n x n) upper triangular.  This
//! is the native-engine twin of `kernels/linalg.py::householder_qr` — the
//! decomposed-APC init is built on it, and since PR 3 it is the dominant
//! cost a warm solver session pays (cold registration).
//!
//! # Blocking and parallelism
//!
//! Reflectors are produced one column at a time inside a [`PANEL`]-wide
//! panel (the classic reflector-at-a-time arithmetic, restricted to the
//! panel), then accumulated into the compact WY form
//! `H_0 .. H_{nb-1} = I - V T V^T` (the LAPACK `larft` recurrence with
//! `tau = 2`: reflectors are stored unit-norm).  The trailing matrix gets
//! ONE blocked update per panel — two gemm-shaped sweeps,
//!
//! ```text
//!   W = V^T A_trail              (panel-wide dots per trailing column)
//!   A_trail -= V (T^T W)         (panel-wide axpys per trailing column)
//! ```
//!
//! Both sweeps run through the **packed register-tiled gemm**
//! ([`blas::packed_gemm_into`]): the reflector block is packed once per
//! panel (both orientations, [`blas::pack_a_strided`]), every trailing
//! column chunk packs its own B panels against it, and the `MR x NR`
//! microkernel carries all the flops.  Thread-count independence now
//! rests on the **chunk-stable packing contract** (`blas.rs` module
//! docs, enforced by `tests/packing_contract.rs`): packing is a pure
//! gather and each output element's f32 accumulation order is a pure
//! function of its (row, col, depth) coordinates — never of which
//! thread packed a panel or where a column chunk boundary fell.
//! Splitting the trailing columns across the thread pool
//! ([`householder_qr_pooled`]) therefore still cannot change a single
//! output bit, even though a column's position inside an NR-wide
//! microtile shifts with the chunking.  The per-column `T`-apply
//! between the two gemms stays in f64, exactly as before.
//!
//! The microkernel itself goes through the runtime-dispatched SIMD
//! layer ([`crate::linalg::simd`]): AVX2+FMA where available, with the
//! lane-structured scalar fallback bit-identical to the vector path at
//! tier-0 — so the factors stay independent of the thread count AND the
//! kernel dispatch.  Under the tier-1 fast kernels
//! ([`householder_qr_tiered`], `DAPC_KERNEL_TIER=fast`) the fused
//! rounding changes the factor bits *once per backend*, but the
//! chunk-stable order is unchanged, so pooled == serial stays bitwise
//! at any thread count within a tier+backend pair.
//!
//! The **panel factorization** itself is also pooled: the in-panel
//! reflector application (one dot + one axpy per remaining panel
//! column) and the `larft` z-dots fan over the pool's workers when the
//! panel has enough work.  Both loops are elementwise-independent
//! across their fan axis, so the fan-out is bit-transparent too —
//! cold registration no longer serializes on O(l * PANEL^2) per panel
//! (`benches/register_scaling.rs` tracks the win).
//!
//! The working copy is stored **column-major** (`work_t`, one contiguous
//! l-length slice per column): reflector extraction, every per-column
//! dot/axpy, and the parallel column chunking are all contiguous slice
//! operations.
//!
//! # Panel-size tuning (`PANEL`)
//!
//! `PANEL * l * 4` bytes of V plus one trailing column must stay
//! cache-resident through the two sweeps; 32 keeps V under half an L2 for
//! Table-1 block heights while amortizing each column's T-apply over 32
//! reflectors.  Methodology mirrors the `MC`/`KC`/`NC` constants in
//! `blas.rs`: sweep `PANEL` one value at a time against
//! `cargo bench --bench microbench_linalg` (QR lines), then confirm
//! end-to-end on `benches/register_scaling.rs` (cold session registration
//! is pure factorization).

use super::simd::{self, Backend, KernelTier};
use super::{blas, Matrix};
use crate::parallel::ThreadPool;

/// Panel width NB of the blocked factorization (see module docs for the
/// tuning methodology).  Public so `dapc kernels` can report it next to
/// the gemm blocking constants.
pub const PANEL: usize = 32;

/// Result of a reduced QR factorization.
pub struct QrFactors {
    /// (l x n) semi-orthogonal factor.
    pub q1: Matrix,
    /// (n x n) upper-triangular factor.
    pub r: Matrix,
}

/// Reduced Householder QR of a tall matrix (l >= n), serial.
///
/// This is [`householder_qr_pooled`] without a pool — the two produce
/// bit-identical factors, so callers pick purely by where the threads
/// should come from.
pub fn householder_qr(a: &Matrix) -> QrFactors {
    householder_qr_pooled(a, None)
}

/// Reduced Householder QR with the per-panel trailing updates, the
/// panel factorization, and the Q1 recovery fanned out over `pool`'s
/// workers when one is given.
///
/// Bit-identical to the serial [`householder_qr`] at any thread count:
/// the parallel split is over *columns*, and every column's arithmetic is
/// independent of the chunking (module docs).  Runs at the
/// process-default kernel tier.
pub fn householder_qr_pooled(a: &Matrix, pool: Option<&ThreadPool>) -> QrFactors {
    householder_qr_tiered(a, pool, simd::active_tier())
}

/// [`householder_qr_pooled`] with an explicit kernel tier — the engines
/// route a per-solve [`crate::solver::SolveOptions::kernel_tier`]
/// override through this.  The pooled == serial bitwise guarantee holds
/// at either tier; only cross-tier comparisons need a tolerance
/// (`tests/kernel_tier.rs`).
pub fn householder_qr_tiered(
    a: &Matrix,
    pool: Option<&ThreadPool>,
    tier: KernelTier,
) -> QrFactors {
    let (l, n) = a.shape();
    assert!(l >= n, "householder_qr requires a tall matrix, got {l}x{n}");
    let npanels = n.div_ceil(PANEL);
    // one dispatch decision for the whole factorization (cannot affect
    // tier-0 bits; at tier-1 it pins the within-backend reproducibility)
    let backend = simd::active();

    // column-major working copy: column c of A lives in work_t[c*l..(c+1)*l]
    let mut work_t = vec![0.0f32; n * l];
    for i in 0..l {
        let row = a.row(i);
        for (c, &v) in row.iter().enumerate() {
            work_t[c * l + i] = v;
        }
    }
    // reflector k is unit-norm in vs[k*l..(k+1)*l], zero above row k
    let mut vs = vec![0.0f32; n * l];
    // per-panel compact-WY T factor (PANEL x PANEL row-major, upper
    // triangular; null reflectors leave their row/column zero)
    let mut ts = vec![0.0f32; npanels * PANEL * PANEL];

    for p in 0..npanels {
        let k0 = p * PANEL;
        let nb = PANEL.min(n - k0);
        let t = &mut ts[p * PANEL * PANEL..(p + 1) * PANEL * PANEL];
        factor_panel(&mut work_t, &mut vs, t, l, k0, nb, pool);
        // one blocked update of every trailing column:
        // A_trail <- (I - V T^T V^T) A_trail  (= H_{nb-1} .. H_0 A_trail)
        let v = &vs[k0 * l..(k0 + nb) * l];
        apply_block(
            backend,
            tier,
            v,
            t,
            l,
            k0,
            nb,
            Sweep::Adjoint,
            &mut work_t[(k0 + nb) * l..],
            pool,
        );
    }

    // R = upper triangle of the first n rows of the reduced working copy
    let mut r = Matrix::zeros(n, n);
    for j in 0..n {
        let col = &work_t[j * l..j * l + l];
        for i in 0..=j {
            r[(i, j)] = col[i];
        }
    }

    // Q1 = (I - V_0 T_0 V_0^T) .. (I - V_{P-1} T_{P-1} V_{P-1}^T) E with
    // E = first n columns of I_l, applied panel-last first.  Columns
    // c < k0 are still e_c with support above every row where V_p is
    // nonzero, so each panel's update is restricted to cols >= k0 — the
    // same halving of the recovery cost as the unblocked kernel (§Perf).
    let mut q_t = vec![0.0f32; n * l];
    for c in 0..n {
        q_t[c * l + c] = 1.0;
    }
    for p in (0..npanels).rev() {
        let k0 = p * PANEL;
        let nb = PANEL.min(n - k0);
        let t = &ts[p * PANEL * PANEL..(p + 1) * PANEL * PANEL];
        let v = &vs[k0 * l..(k0 + nb) * l];
        apply_block(
            backend,
            tier,
            v,
            t,
            l,
            k0,
            nb,
            Sweep::Forward,
            &mut q_t[k0 * l..],
            pool,
        );
    }
    let mut q1 = Matrix::zeros(l, n);
    for i in 0..l {
        let row = q1.row_mut(i);
        for (c, slot) in row.iter_mut().enumerate() {
            *slot = q_t[c * l + i];
        }
    }
    QrFactors { q1, r }
}

/// Minimum `(rows) * (fan width)` product before [`factor_panel`] fans a
/// loop over the pool: below this the spawn overhead dwarfs the dots.
/// The gate reads only the problem shape — never the data — and the
/// fanned kernels are chunk-independent, so the threshold cannot change
/// a bit (it only decides who computes it).
const PANEL_FAN_MIN_WORK: usize = 8192;

/// Factor columns `[k0, k0 + nb)` of the column-major working copy in
/// place: the classic reflector-at-a-time arithmetic restricted to the
/// panel, plus the `larft` recurrence filling the panel's `T` factor
/// (`tau = 2` for the unit-norm reflectors, 0 for null ones — a zero T
/// row/column makes the blocked apply skip that reflector exactly).
///
/// With a pool, the two O(l * PANEL) inner loops — applying the fresh
/// reflector to the remaining panel columns, and the `larft` z-dots
/// against the earlier reflectors — fan over the workers.  Both are
/// elementwise-independent across their fan axis (each panel column /
/// each z entry reads the shared reflector plus its own data), so the
/// fan-out is bitwise-invisible, exactly like the trailing-sweep
/// chunking.  The serial T recurrence that remains is O(PANEL^2) per
/// column — noise next to the dots.
fn factor_panel(
    work_t: &mut [f32],
    vs: &mut [f32],
    t: &mut [f32],
    l: usize,
    k0: usize,
    nb: usize,
    pool: Option<&ThreadPool>,
) {
    let mut z = [0.0f32; PANEL];
    for kk in 0..nb {
        let k = k0 + kk;
        // v = masked column k of the working copy (rows >= k)
        let (vs_done, vs_rest) = vs.split_at_mut(k * l);
        let vs_done: &[f32] = vs_done;
        let v = &mut vs_rest[..l];
        v[k..].copy_from_slice(&work_t[k * l + k..(k + 1) * l]);
        let sigma = blas::dot(&v[k..], &v[k..]).sqrt();
        if sigma == 0.0 {
            // zero column below k: null reflector, leave v = 0
            v[k..].fill(0.0);
            continue;
        }
        let alpha = if v[k] >= 0.0 { -sigma } else { sigma } as f32;
        v[k] -= alpha;
        let vnorm = blas::dot(&v[k..], &v[k..]).sqrt();
        if vnorm < 1e-30 {
            v[k..].fill(0.0);
            continue;
        }
        let inv = (1.0 / vnorm) as f32;
        for vi in v[k..].iter_mut() {
            *vi *= inv;
        }
        let vk: &[f32] = &v[k..];
        // panel-internal H_k = I - 2 v v^T over columns k..panel end
        // (column k itself becomes the k-th R column, ~zero below the
        // diagonal); per column one contiguous dot + one contiguous axpy
        let rem = k0 + nb - k;
        let panel_cols = &mut work_t[k * l..(k0 + nb) * l];
        match pool {
            Some(pool)
                if pool.size() > 1
                    && rem > 1
                    && (l - k) * rem >= PANEL_FAN_MIN_WORK =>
            {
                let parts = pool.size().min(rem);
                let chunk = rem.div_ceil(parts);
                pool.scope(|s| {
                    for ch in panel_cols.chunks_mut(chunk * l) {
                        s.spawn(move || {
                            for col in ch.chunks_mut(l) {
                                let w = blas::dot(vk, &col[k..]) as f32;
                                blas::axpy(-2.0 * w, vk, &mut col[k..]);
                            }
                        });
                    }
                });
            }
            _ => {
                for col in panel_cols.chunks_mut(l) {
                    let w = blas::dot(vk, &col[k..]) as f32;
                    blas::axpy(-2.0 * w, vk, &mut col[k..]);
                }
            }
        }
        // larft column kk: z = V[:, 0..kk]^T v (earlier reflectors are
        // zero above their own pivot row <= k, and v is zero above k, so
        // the suffix dot captures every nonzero product), then
        // t[s][kk] = -2 * sum_{r in s..kk} t[s][r] * z[r], t[kk][kk] = 2.
        let zs = &mut z[..kk];
        match pool {
            Some(pool)
                if pool.size() > 1
                    && kk > 1
                    && (l - k) * kk >= PANEL_FAN_MIN_WORK =>
            {
                let parts = pool.size().min(kk);
                let chunk = kk.div_ceil(parts);
                pool.scope(|s| {
                    for (ci, zc) in zs.chunks_mut(chunk).enumerate() {
                        let r0 = ci * chunk;
                        s.spawn(move || {
                            for (o, zr) in zc.iter_mut().enumerate() {
                                let r = k0 + r0 + o;
                                let vr = &vs_done[r * l..(r + 1) * l];
                                *zr = blas::dot(&vr[k..], vk) as f32;
                            }
                        });
                    }
                });
            }
            _ => {
                for (r, zr) in zs.iter_mut().enumerate() {
                    let vr = &vs_done[(k0 + r) * l..(k0 + r + 1) * l];
                    *zr = blas::dot(&vr[k..], vk) as f32;
                }
            }
        }
        for s in 0..kk {
            let mut acc = 0.0f64;
            for r in s..kk {
                acc += t[s * PANEL + r] as f64 * z[r] as f64;
            }
            t[s * PANEL + kk] = (-2.0 * acc) as f32;
        }
        t[kk * PANEL + kk] = 2.0;
    }
}

/// Which accumulated panel operator a sweep applies: triangularization
/// hits the trailing columns with the reflectors first-to-last
/// (`H_{nb-1} .. H_0 = I - V T^T V^T`), the Q1 recovery with the forward
/// product (`H_0 .. H_{nb-1} = I - V T V^T`).
#[derive(Clone, Copy)]
enum Sweep {
    /// `I - V T^T V^T`.
    Adjoint,
    /// `I - V T V^T`.
    Forward,
}

/// Apply one panel's accumulated reflectors to `cols` (column-major,
/// `cols.len() / l` columns) through the packed gemm.
///
/// The reflector block is packed ONCE here, in both orientations —
/// `V^T` (nb x lp, each packed row a contiguous reflector suffix) for
/// the `W = V^T C` sweep, and `V` (lp x nb, a strided transpose view of
/// the same storage) for the `C -= V Y` sweep — then shared read-only
/// by every column chunk.  Chunks go to the pool when one is provided;
/// the chunk-stable packing contract (`blas.rs`) makes the split
/// bit-transparent even though a column's microtile alignment shifts
/// with the chunk boundary.
///
/// Reflector r is zero above row `k0 + r`, so restricting both sweeps
/// to rows `>= k0` keeps every nonzero product; the `r` extra rows per
/// reflector inside the block contribute exact `+-0.0` products only.
#[allow(clippy::too_many_arguments)]
fn apply_block(
    backend: Backend,
    tier: KernelTier,
    v: &[f32],
    t: &[f32],
    l: usize,
    k0: usize,
    nb: usize,
    sweep: Sweep,
    cols: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    let ncols = cols.len() / l.max(1);
    if ncols == 0 {
        return;
    }
    let lp = l - k0;
    let mut vt_pack = vec![0.0f32; blas::packed_a_len(nb, lp)];
    let mut v_pack = vec![0.0f32; blas::packed_a_len(lp, nb)];
    // V^T rows are the contiguous reflector suffixes: row stride l
    blas::pack_a_strided(&v[k0..], l, 1, nb, lp, &mut vt_pack);
    // V itself is the column-major (transpose) view of the same storage
    blas::pack_a_strided(&v[k0..], 1, l, lp, nb, &mut v_pack);
    let vt_pack = &vt_pack[..];
    let v_pack = &v_pack[..];
    match pool {
        Some(pool) if pool.size() > 1 && ncols > 1 => {
            let parts = pool.size().min(ncols);
            let chunk = ncols.div_ceil(parts);
            pool.scope(|s| {
                for ch in cols.chunks_mut(chunk * l) {
                    s.spawn(move || {
                        apply_block_packed(
                            backend,
                            tier,
                            vt_pack,
                            v_pack,
                            t,
                            l,
                            k0,
                            nb,
                            sweep,
                            ch,
                        )
                    });
                }
            });
        }
        _ => apply_block_packed(
            backend,
            tier,
            vt_pack,
            v_pack,
            t,
            l,
            k0,
            nb,
            sweep,
            cols,
        ),
    }
}

/// The per-chunk kernel behind [`apply_block`]: in column blocks of at
/// most [`blas::NC`], pack the chunk's columns, run
/// `W = V^T C` (packed gemm, column-major W scratch), apply `T^T` (or
/// `T`) per column in f64 — unchanged from the pre-packed kernel — then
/// `C -= V Y` (packed gemm, Sub).  Scratch is allocated once per chunk
/// and reused across its column blocks.
#[allow(clippy::too_many_arguments)]
fn apply_block_packed(
    backend: Backend,
    tier: KernelTier,
    vt_pack: &[f32],
    v_pack: &[f32],
    t: &[f32],
    l: usize,
    k0: usize,
    nb: usize,
    sweep: Sweep,
    cols: &mut [f32],
) {
    let lp = l - k0;
    let ncols = cols.len() / l;
    let bw = ncols.min(blas::NC);
    let mut b_pack = vec![0.0f32; blas::packed_b_len(lp, bw)];
    let mut y_pack = vec![0.0f32; blas::packed_b_len(nb, bw)];
    // W and Y, column-major with leading dimension PANEL
    let mut w_buf = vec![0.0f32; PANEL * bw];
    let mut y_buf = vec![0.0f32; PANEL * bw];
    for ch in cols.chunks_mut(bw * l) {
        let nc = ch.len() / l;
        // W = V^T C over rows >= k0: C's (i, j) entry sits at
        // ch[k0 + i + j*l], i.e. rs = 1, cs = l from the k0 offset
        blas::pack_b_strided(&ch[k0..], 1, l, lp, nc, &mut b_pack);
        blas::packed_gemm_into(
            backend,
            tier,
            nb,
            nc,
            lp,
            vt_pack,
            &b_pack,
            blas::Accum::Store,
            &mut w_buf,
            1,
            PANEL,
        );
        // y = T^T w (adjoint) or T w (forward) per column, in f64;
        // T is upper triangular — identical math to the pre-packed sweep
        for j in 0..nc {
            let w = &w_buf[j * PANEL..j * PANEL + nb];
            let y = &mut y_buf[j * PANEL..j * PANEL + nb];
            for s in 0..nb {
                let mut acc = 0.0f64;
                match sweep {
                    Sweep::Adjoint => {
                        for r in 0..=s {
                            acc += t[r * PANEL + s] as f64 * w[r] as f64;
                        }
                    }
                    Sweep::Forward => {
                        for r in s..nb {
                            acc += t[s * PANEL + r] as f64 * w[r] as f64;
                        }
                    }
                }
                y[s] = acc as f32;
            }
        }
        // C -= V Y over the same row window
        blas::pack_b_strided(&y_buf, 1, PANEL, nb, nc, &mut y_pack);
        blas::packed_gemm_into(
            backend,
            tier,
            lp,
            nc,
            nb,
            v_pack,
            &y_pack,
            blas::Accum::Sub,
            &mut ch[k0..],
            1,
            l,
        );
    }
}

/// Apply `Q1^T` to a vector of length l, returning length-n `Q1^T b`.
pub fn qt_mul(f: &QrFactors, b: &[f32]) -> Vec<f32> {
    let n = f.r.cols();
    let mut out = vec![0.0f32; n];
    blas::gemv_t(&f.q1, b, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::{gemm, gemm_tn};
    use crate::rng::seeded;

    fn randm(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut g = seeded(seed);
        Matrix::from_fn(rows, cols, |_, _| g.normal_f32())
    }

    // -----------------------------------------------------------------
    // Reference oracle: the pre-blocking reflector-at-a-time kernel,
    // kept verbatim (modulo the hoisted `w` scratch) so the blocked
    // implementation is always checked against the original arithmetic.
    // -----------------------------------------------------------------

    /// `m[:, col_start..] <- (I - 2 v v^T) m[:, col_start..]`, skipping
    /// the first `k` rows where v is zero.  `w_buf` is caller scratch of
    /// at least `cols - col_start` (hoisted out of the reflector loop).
    fn reference_apply_reflector_left(
        m: &mut Matrix,
        v: &[f32],
        k: usize,
        col_start: usize,
        w_buf: &mut [f32],
    ) {
        let (rows, cols) = m.shape();
        debug_assert_eq!(v.len(), rows);
        let w = &mut w_buf[..cols - col_start];
        w.fill(0.0);
        for i in k..rows {
            let vi = v[i];
            if vi != 0.0 {
                blas::axpy(vi, &m.row(i)[col_start..], w);
            }
        }
        for i in k..rows {
            let c = -2.0 * v[i];
            if c != 0.0 {
                blas::axpy(c, w, &mut m.row_mut(i)[col_start..]);
            }
        }
    }

    /// Reflector-at-a-time reduced QR — the numerical oracle.
    fn reference_qr(a: &Matrix) -> QrFactors {
        let (l, n) = a.shape();
        assert!(l >= n);
        let mut work = a.clone();
        let mut vs = vec![0.0f32; n * l];
        let mut w_buf = vec![0.0f32; n];

        for k in 0..n {
            let v = &mut vs[k * l..(k + 1) * l];
            for i in k..l {
                v[i] = work[(i, k)];
            }
            let sigma = blas::dot(&v[k..], &v[k..]).sqrt();
            if sigma == 0.0 {
                v.fill(0.0);
                continue;
            }
            let alpha = if v[k] >= 0.0 { -sigma } else { sigma } as f32;
            v[k] -= alpha;
            let vnorm = blas::dot(&v[k..], &v[k..]).sqrt();
            if vnorm < 1e-30 {
                v.fill(0.0);
                continue;
            }
            let inv = (1.0 / vnorm) as f32;
            for vi in v[k..].iter_mut() {
                *vi *= inv;
            }
            reference_apply_reflector_left(&mut work, v, k, k, &mut w_buf);
        }

        let mut r = Matrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                r[(i, j)] = work[(i, j)];
            }
        }
        let mut q1 = Matrix::from_fn(l, n, |i, j| if i == j { 1.0 } else { 0.0 });
        for k in (0..n).rev() {
            let v = &vs[k * l..(k + 1) * l];
            reference_apply_reflector_left(&mut q1, v, k, k, &mut w_buf);
        }
        QrFactors { q1, r }
    }

    /// Compare two QR factorizations up to per-column sign: the
    /// Householder sign convention reads the sign of a rounding-sensitive
    /// pivot, so two correct implementations may legitimately flip a row
    /// of R (and the matching column of Q1) when that pivot sits at
    /// rounding noise.
    fn assert_matches_up_to_sign(
        f: &QrFactors,
        o: &QrFactors,
        tol: f32,
        ctx: &str,
    ) {
        let (l, n) = f.q1.shape();
        assert_eq!(o.q1.shape(), (l, n), "{ctx}");
        for i in 0..n {
            let s = if f.r[(i, i)] * o.r[(i, i)] < 0.0 { -1.0f32 } else { 1.0 };
            for j in 0..n {
                let d = (f.r[(i, j)] - s * o.r[(i, j)]).abs();
                assert!(d < tol, "{ctx}: R[{i},{j}] diff {d}");
            }
            for row in 0..l {
                let d = (f.q1[(row, i)] - s * o.q1[(row, i)]).abs();
                assert!(d < tol, "{ctx}: Q1[{row},{i}] diff {d}");
            }
        }
    }

    #[test]
    fn reconstruction() {
        for &(l, n) in &[(4, 4), (16, 8), (64, 32), (33, 7), (100, 100)] {
            let a = randm(l, n, l as u64 * 31 + n as u64);
            let f = householder_qr(&a);
            let recon = gemm(&f.q1, &f.r);
            assert!(recon.max_abs_diff(&a) < 5e-4, "({l},{n})");
        }
    }

    #[test]
    fn orthonormal_columns() {
        let a = randm(48, 20, 7);
        let f = householder_qr(&a);
        let qtq = gemm_tn(&f.q1, &f.q1);
        // the blocked recovery composes reflectors through T, so the
        // orthonormality noise floor is a little above the unblocked one
        assert!(qtq.max_abs_diff(&Matrix::eye(20)) < 2e-4);
    }

    #[test]
    fn r_upper_triangular() {
        let a = randm(30, 12, 9);
        let f = householder_qr(&a);
        for i in 0..12 {
            for j in 0..i {
                assert_eq!(f.r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn zero_column_no_nan() {
        let mut a = Matrix::zeros(10, 4);
        for i in 0..10 {
            a[(i, 0)] = 1.0;
            a[(i, 2)] = i as f32;
        }
        let f = householder_qr(&a);
        assert!(f.q1.as_slice().iter().all(|v| v.is_finite()));
        assert!(f.r.as_slice().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn padded_rows_leave_r_and_qtb_unchanged() {
        // QR([A; 0]) must produce the same R and the same Q1^T [b; 0] —
        // this is what makes shape-bucket padding exact (DESIGN.md §3).
        // Re-asserted here against the panel-blocked kernel: the proof
        // depends only on zero rows contributing nothing to any reflector,
        // which blocking does not change.
        let a = randm(20, 8, 13);
        let mut g = seeded(14);
        let b: Vec<f32> = (0..20).map(|_| g.normal_f32()).collect();
        let f = householder_qr(&a);
        let ap = a.pad_rows(32);
        let mut bp = b.clone();
        bp.resize(32, 0.0);
        let fp = householder_qr(&ap);
        // R unique up to sign of rows; our sign convention is deterministic
        assert!(f.r.max_abs_diff(&fp.r) < 1e-4);
        let qtb = qt_mul(&f, &b);
        let qtbp = qt_mul(&fp, &bp);
        for i in 0..8 {
            assert!((qtb[i] - qtbp[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn property_random_shapes() {
        // hand-rolled property sweep (no proptest offline)
        let mut g = seeded(99);
        for case in 0..25 {
            let n = g.gen_range(1, 24);
            let l = n + g.gen_range(0, 24);
            let a = randm(l, n, 1000 + case);
            let f = householder_qr(&a);
            assert!(gemm(&f.q1, &f.r).max_abs_diff(&a) < 2e-3, "case {case} ({l},{n})");
            let qtq = gemm_tn(&f.q1, &f.q1);
            assert!(qtq.max_abs_diff(&Matrix::eye(n)) < 2e-3, "case {case}");
        }
    }

    #[test]
    fn blocked_matches_reference_oracle_across_panel_boundaries() {
        // shapes below, exactly at, one past, and spanning several PANEL
        // boundaries — including square (empty trailing block on the last
        // panel) and very ragged last panels
        for &(l, n) in &[
            (8, 5),
            (40, 31),
            (40, 32),
            (50, 33),
            (90, 64),
            (120, 70),
            (70, 70),
            (33, 7),
        ] {
            let a = randm(l, n, 7000 + (l * 131 + n) as u64);
            let f = householder_qr(&a);
            let o = reference_qr(&a);
            assert_matches_up_to_sign(&f, &o, 2e-3, &format!("({l},{n})"));
        }
    }

    #[test]
    fn blocked_matches_reference_oracle_across_property_sweep() {
        // the same random-shape sweep as `property_random_shapes`, judged
        // against the reflector-at-a-time oracle instead of the algebraic
        // identities
        let mut g = seeded(99);
        for case in 0..25 {
            let n = g.gen_range(1, 24);
            let l = n + g.gen_range(0, 24);
            let a = randm(l, n, 1000 + case);
            let f = householder_qr(&a);
            let o = reference_qr(&a);
            assert_matches_up_to_sign(
                &f,
                &o,
                2e-3,
                &format!("case {case} ({l},{n})"),
            );
        }
    }

    #[test]
    fn pooled_bitwise_matches_serial_at_any_thread_count() {
        // the contract the engines rely on: the pooled trailing sweeps
        // chunk columns, never reorder arithmetic, so factors are
        // bit-identical to the serial kernel
        for &(l, n) in &[(16, 5), (64, 33), (100, 40), (70, 70)] {
            let a = randm(l, n, 4000 + (l * 7 + n) as u64);
            let serial = householder_qr(&a);
            for threads in [2usize, 3, 4, 5, 8] {
                let pool = ThreadPool::new(threads);
                let pooled = householder_qr_pooled(&a, Some(&pool));
                assert_eq!(
                    serial.q1.as_slice(),
                    pooled.q1.as_slice(),
                    "Q1 ({l},{n}) t={threads}"
                );
                assert_eq!(
                    serial.r.as_slice(),
                    pooled.r.as_slice(),
                    "R ({l},{n}) t={threads}"
                );
            }
        }
    }

    #[test]
    fn tier1_pooled_bitwise_matches_tier1_serial() {
        // the pooled == serial guarantee must survive the fast tier:
        // fused rounding changes WHAT each element computes, never the
        // chunk-stable order it computes it in
        for &(l, n) in &[(64, 33), (100, 40)] {
            let a = randm(l, n, 6000 + (l + n) as u64);
            let serial = householder_qr_tiered(&a, None, KernelTier::Fast);
            for threads in [2usize, 4, 7] {
                let pool = ThreadPool::new(threads);
                let pooled =
                    householder_qr_tiered(&a, Some(&pool), KernelTier::Fast);
                assert_eq!(
                    serial.q1.as_slice(),
                    pooled.q1.as_slice(),
                    "Q1 ({l},{n}) t={threads}"
                );
                assert_eq!(
                    serial.r.as_slice(),
                    pooled.r.as_slice(),
                    "R ({l},{n}) t={threads}"
                );
            }
        }
    }

    #[test]
    fn tier1_factors_stay_accurate() {
        // tier-1 changes rounding, not math: the algebraic identities
        // hold at the same tolerances the tier-0 suite asserts
        let a = randm(90, 40, 77);
        let f = householder_qr_tiered(&a, None, KernelTier::Fast);
        assert!(gemm(&f.q1, &f.r).max_abs_diff(&a) < 5e-4);
        let qtq = gemm_tn(&f.q1, &f.q1);
        assert!(qtq.max_abs_diff(&Matrix::eye(40)) < 2e-4);
    }

    #[test]
    fn zero_columns_match_oracle_too() {
        // null reflectors leave zero T rows/columns; the blocked apply
        // must skip them exactly like the unblocked kernel does
        let mut a = Matrix::zeros(12, 5);
        for i in 0..12 {
            a[(i, 0)] = (i + 1) as f32;
            a[(i, 3)] = 1.0 - i as f32 * 0.25;
        }
        let f = householder_qr(&a);
        let o = reference_qr(&a);
        assert!(f.r.max_abs_diff(&o.r) < 1e-4);
        assert!(f.q1.max_abs_diff(&o.q1) < 1e-4);
    }
}
