//! Thread-local PJRT context: compiles HLO-text artifacts on the CPU
//! client and executes them with host tensors.
//!
//! NOT `Send` (the xla crate's client is `Rc`-based) — cross-thread access
//! goes through [`super::executor::XlaExecutor`].
//!
//! The real implementation needs the vendored `xla` crate and is compiled
//! only with `--features xla`.  The default (offline) build gets a stub
//! with the same surface: it still reads the artifact manifest — so
//! `has_artifact` / `init_buckets` queries and `dapc info` work — but
//! `execute`/`warm` return [`crate::error::DapcError::Xla`].

#[cfg(feature = "xla")]
mod real {
    use std::cell::RefCell;
    use std::collections::HashMap;
    use std::path::Path;

    use crate::error::{DapcError, Result};

    use super::super::manifest::ArtifactManifest;
    use super::super::tensor::Tensor;

    /// Owns the PJRT CPU client, the artifact manifest and a compiled
    /// executable cache keyed by artifact name.
    pub struct PjrtContext {
        client: xla::PjRtClient,
        manifest: ArtifactManifest,
        cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    }

    impl PjrtContext {
        /// Create a CPU-client context over an artifact directory.
        pub fn new(artifacts_dir: &Path) -> Result<Self> {
            let manifest = ArtifactManifest::load(artifacts_dir)?;
            let client = xla::PjRtClient::cpu()?;
            Ok(Self { client, manifest, cache: RefCell::new(HashMap::new()) })
        }

        pub fn manifest(&self) -> &ArtifactManifest {
            &self.manifest
        }

        /// Compile (or fetch from cache) an artifact by name.
        fn ensure_compiled(&self, name: &str) -> Result<()> {
            if self.cache.borrow().contains_key(name) {
                return Ok(());
            }
            let meta = self.manifest.get(name)?;
            let proto = xla::HloModuleProto::from_text_file(&meta.path)
                .map_err(|e| {
                    DapcError::Artifact(format!(
                        "failed to parse {}: {e}",
                        meta.path.display()
                    ))
                })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.borrow_mut().insert(name.to_string(), exe);
            Ok(())
        }

        /// Number of compiled executables currently cached.
        pub fn cached_count(&self) -> usize {
            self.cache.borrow().len()
        }

        /// Pre-compile a set of artifacts (warmup before the timed region).
        pub fn warm(&self, names: &[&str]) -> Result<()> {
            for n in names {
                self.ensure_compiled(n)?;
            }
            Ok(())
        }

        /// Execute an artifact with host tensors; returns the decomposed
        /// output tuple as host tensors.
        ///
        /// Every aot.py artifact is lowered with `return_tuple=True`, so
        /// the single output literal is always a tuple (possibly of one
        /// element).
        pub fn execute(
            &self,
            name: &str,
            inputs: &[Tensor],
        ) -> Result<Vec<Tensor>> {
            self.ensure_compiled(name)?;
            let meta = self.manifest.get(name)?;
            if meta.input_shapes.len() != inputs.len() {
                return Err(DapcError::Shape(format!(
                    "{name}: expected {} inputs, got {}",
                    meta.input_shapes.len(),
                    inputs.len()
                )));
            }
            for (i, (t, want)) in
                inputs.iter().zip(&meta.input_shapes).enumerate()
            {
                if t.shape() != want.as_slice() {
                    return Err(DapcError::Shape(format!(
                        "{name}: input {i} shape {:?} != manifest {:?}",
                        t.shape(),
                        want
                    )));
                }
            }

            let literals: Vec<xla::Literal> =
                inputs.iter().map(to_literal).collect::<Result<_>>()?;
            let cache = self.cache.borrow();
            let exe = cache.get(name).expect("compiled above");
            let result = exe.execute::<xla::Literal>(&literals)?;
            let out = result[0][0].to_literal_sync()?;
            let elems = out.to_tuple()?;
            elems.into_iter().map(|l| from_literal(&l)).collect()
        }
    }

    /// Host tensor -> XLA literal.
    fn to_literal(t: &Tensor) -> Result<xla::Literal> {
        match t {
            Tensor::F32 { shape, data } => {
                let lit = xla::Literal::vec1(data);
                if shape.len() == 1 {
                    Ok(lit)
                } else {
                    let dims: Vec<i64> =
                        shape.iter().map(|&d| d as i64).collect();
                    Ok(lit.reshape(&dims)?)
                }
            }
            Tensor::I32Scalar(v) => Ok(xla::Literal::scalar(*v)),
        }
    }

    /// XLA literal -> host tensor (f32 only; all artifact outputs are f32).
    fn from_literal(l: &xla::Literal) -> Result<Tensor> {
        let shape = l.array_shape()?;
        let dims: Vec<usize> =
            shape.dims().iter().map(|&d| d as usize).collect();
        let data = l.to_vec::<f32>()?;
        Ok(Tensor::F32 { shape: dims, data })
    }
}

#[cfg(feature = "xla")]
pub use real::PjrtContext;

#[cfg(not(feature = "xla"))]
mod stub {
    use std::path::Path;

    use crate::error::{DapcError, Result};

    use super::super::manifest::ArtifactManifest;
    use super::super::tensor::Tensor;

    /// Offline stub: manifest queries work, execution does not.
    pub struct PjrtContext {
        manifest: ArtifactManifest,
    }

    fn unavailable(what: &str) -> DapcError {
        DapcError::Xla(format!(
            "{what} requires the PJRT runtime; this build has no `xla` \
             feature (rebuild with `--features xla` and the vendored xla \
             crate, or use the native engine)"
        ))
    }

    impl PjrtContext {
        /// Load the manifest only; the PJRT client is unavailable.
        pub fn new(artifacts_dir: &Path) -> Result<Self> {
            let manifest = ArtifactManifest::load(artifacts_dir)?;
            Ok(Self { manifest })
        }

        pub fn manifest(&self) -> &ArtifactManifest {
            &self.manifest
        }

        /// Always 0: nothing can be compiled without PJRT.
        pub fn cached_count(&self) -> usize {
            0
        }

        /// Errors: compilation needs the real runtime.
        pub fn warm(&self, _names: &[&str]) -> Result<()> {
            Err(unavailable("artifact warmup"))
        }

        /// Errors: execution needs the real runtime.
        pub fn execute(
            &self,
            name: &str,
            _inputs: &[Tensor],
        ) -> Result<Vec<Tensor>> {
            Err(unavailable(&format!("executing artifact {name:?}")))
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::PjrtContext;

#[cfg(test)]
mod tests {
    //! Hermetic tests use the real artifacts/ directory when present —
    //! they are the integration gate between aot.py and this runtime.
    //! Execution tests additionally need the `xla` feature.
    use super::*;
    use std::path::{Path, PathBuf};

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn missing_manifest_rejected() {
        assert!(PjrtContext::new(Path::new("/nonexistent/xyz")).is_err());
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_reports_unavailable() {
        let Some(dir) = artifacts_dir() else { return };
        let ctx = PjrtContext::new(&dir).unwrap();
        assert_eq!(ctx.cached_count(), 0);
        let err = ctx.execute("mse_n32", &[]).unwrap_err();
        assert!(err.to_string().contains("xla"), "{err}");
    }

    #[cfg(feature = "xla")]
    mod with_runtime {
        use super::*;
        use crate::runtime::tensor::Tensor;

        #[test]
        fn execute_mse_artifact() {
            let Some(dir) = artifacts_dir() else { return };
            let ctx = PjrtContext::new(&dir).unwrap();
            let x = Tensor::vec1(vec![1.0; 32]);
            let y = Tensor::vec1(vec![0.0; 32]);
            let out = ctx.execute("mse_n32", &[x, y]).unwrap();
            assert_eq!(out.len(), 1);
            let v = out[0].f32_data().unwrap();
            assert!((v[0] - 1.0).abs() < 1e-6);
        }

        #[test]
        fn input_validation() {
            let Some(dir) = artifacts_dir() else { return };
            let ctx = PjrtContext::new(&dir).unwrap();
            // wrong arity
            assert!(ctx
                .execute("mse_n32", &[Tensor::vec1(vec![0.0; 32])])
                .is_err());
            // wrong shape
            assert!(ctx
                .execute(
                    "mse_n32",
                    &[Tensor::vec1(vec![0.0; 16]), Tensor::vec1(vec![0.0; 32])]
                )
                .is_err());
            // unknown artifact
            assert!(ctx.execute("nope", &[]).is_err());
        }

        #[test]
        fn executable_cache_reused() {
            let Some(dir) = artifacts_dir() else { return };
            let ctx = PjrtContext::new(&dir).unwrap();
            assert_eq!(ctx.cached_count(), 0);
            let x = Tensor::vec1(vec![1.0; 32]);
            let y = Tensor::vec1(vec![2.0; 32]);
            ctx.execute("mse_n32", &[x.clone(), y.clone()]).unwrap();
            assert_eq!(ctx.cached_count(), 1);
            ctx.execute("mse_n32", &[x, y]).unwrap();
            assert_eq!(ctx.cached_count(), 1);
        }
    }
}
