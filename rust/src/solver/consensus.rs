//! The two APC solver facades over the unified consensus driver.
//!
//! Both variants run the identical epoch loop (eqs. (5)-(7)) — which
//! lives once, in [`super::driver`] — and differ only in the worker
//! initialization: QR + backward substitution for the paper's decomposed
//! variant, Gram inverse for classical APC.

use crate::error::Result;
use crate::sparse::CsrMatrix;

use super::driver::{drive_apc, InProcessBackend};
use super::engine::ComputeEngine;
use super::report::{SolveOptions, SolveReport};
use super::Solver;

/// Which APC initialization a consensus solver uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ApcVariant {
    /// This paper: QR + backward substitution (O(l n^2), no inversion).
    Decomposed,
    /// Classical APC: Gram matrix + O(n^3) Gauss-Jordan inverse.
    Classical,
}

/// The paper's solver (decomposed APC).
#[derive(Debug, Clone)]
pub struct DapcSolver {
    pub options: SolveOptions,
}

impl DapcSolver {
    pub fn new(options: SolveOptions) -> Self {
        Self { options }
    }
}

/// Classical APC baseline.
#[derive(Debug, Clone)]
pub struct ApcClassicalSolver {
    pub options: SolveOptions,
}

impl ApcClassicalSolver {
    pub fn new(options: SolveOptions) -> Self {
        Self { options }
    }
}

impl Solver for DapcSolver {
    fn solve<E: ComputeEngine>(
        &self,
        engine: &E,
        a: &CsrMatrix,
        b: &[f32],
        j: usize,
    ) -> Result<SolveReport> {
        let mut backend = InProcessBackend::new(engine, j);
        drive_apc(&mut backend, a, b, ApcVariant::Decomposed, &self.options)
    }

    fn name(&self) -> &'static str {
        "dapc-decomposed"
    }
}

impl Solver for ApcClassicalSolver {
    fn solve<E: ComputeEngine>(
        &self,
        engine: &E,
        a: &CsrMatrix,
        b: &[f32],
        j: usize,
    ) -> Result<SolveReport> {
        let mut backend = InProcessBackend::new(engine, j);
        drive_apc(&mut backend, a, b, ApcVariant::Classical, &self.options)
    }

    fn name(&self) -> &'static str {
        "apc-classical"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms;
    use crate::solver::engine::NativeEngine;
    use crate::sparse::generate::GeneratorConfig;

    fn opts(epochs: usize, x_true: Option<Vec<f32>>) -> SolveOptions {
        SolveOptions { epochs, eta: 0.9, gamma: 0.9, x_true, ..Default::default() }
    }

    #[test]
    fn decomposed_converges_on_augmented_system() {
        let ds = GeneratorConfig::small_demo(32, 3).generate(1);
        let e = NativeEngine::new();
        let solver = DapcSolver::new(opts(40, Some(ds.x_true.clone())));
        let report = solver.solve(&e, &ds.matrix, &ds.rhs, 3).unwrap();
        let mse = report.final_mse(&ds.x_true);
        assert!(mse < 1e-6, "mse = {mse}");
        let tr = report.trace.as_ref().unwrap();
        assert_eq!(tr.points.len(), 41);
        assert!(tr.final_mse().unwrap() <= tr.initial_mse().unwrap());
    }

    #[test]
    fn classical_converges_and_matches_decomposed() {
        let ds = GeneratorConfig::small_demo(24, 2).generate(2);
        let e = NativeEngine::new();
        let d = DapcSolver::new(opts(30, None))
            .solve(&e, &ds.matrix, &ds.rhs, 2)
            .unwrap();
        let c = ApcClassicalSolver::new(opts(30, None))
            .solve(&e, &ds.matrix, &ds.rhs, 2)
            .unwrap();
        assert!(d.final_mse(&ds.x_true) < 1e-6);
        assert!(c.final_mse(&ds.x_true) < 1e-4);
        // both variants converge to (approximately) the same solution
        assert!(norms::mse(&d.xbar, &c.xbar) < 1e-5);
    }

    #[test]
    fn fat_regime_selected_automatically() {
        // J so large the blocks go fat: original-APC projector path
        let ds = GeneratorConfig::small_demo(16, 1).generate(3);
        // matrix is 32x16; J=4 gives l=8 < n=16 => fat
        let e = NativeEngine::new();
        let solver = DapcSolver::new(SolveOptions {
            epochs: 300,
            eta: 0.6,
            gamma: 0.9,
            x_true: Some(ds.x_true.clone()),
            ..Default::default()
        });
        let report = solver.solve(&e, &ds.matrix, &ds.rhs, 4).unwrap();
        // fat-regime consensus genuinely iterates; should approach x_true
        let tr = report.trace.unwrap();
        assert!(
            tr.final_mse().unwrap() < tr.initial_mse().unwrap() * 0.5,
            "fat consensus did not reduce MSE: {:?} -> {:?}",
            tr.initial_mse(),
            tr.final_mse()
        );
    }

    #[test]
    fn mismatched_rhs_rejected() {
        let ds = GeneratorConfig::small_demo(8, 1).generate(4);
        let e = NativeEngine::new();
        let r = DapcSolver::new(opts(1, None)).solve(&e, &ds.matrix, &ds.rhs[..3], 1);
        assert!(r.is_err());
    }

    #[test]
    fn single_partition_is_direct_solve() {
        let ds = GeneratorConfig::small_demo(16, 1).generate(5);
        let e = NativeEngine::new();
        let report = DapcSolver::new(opts(1, None))
            .solve(&e, &ds.matrix, &ds.rhs, 1)
            .unwrap();
        // J=1: init already solves the (overdetermined, consistent) system
        assert!(report.final_mse(&ds.x_true) < 1e-6);
    }
}
