//! Library-wide error type.

use thiserror::Error;

/// Errors surfaced by the DAPC library.
#[derive(Error, Debug)]
pub enum DapcError {
    /// Shape/dimension mismatches.
    #[error("shape error: {0}")]
    Shape(String),

    /// Numerical failures (singular matrices, divergence, NaNs).
    #[error("numeric error: {0}")]
    Numeric(String),

    /// Parse failures (MatrixMarket, manifest JSON, config, CLI).
    #[error("parse error: {0}")]
    Parse(String),

    /// Artifact/manifest lookup failures.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Coordinator/transport failures.
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// Configuration errors (invalid hyper-parameters etc.).
    #[error("config error: {0}")]
    Config(String),

    /// I/O wrapper.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// XLA/PJRT wrapper.
    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for DapcError {
    fn from(e: xla::Error) -> Self {
        DapcError::Xla(e.to_string())
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, DapcError>;
