//! Amortization telemetry for a [`super::SolverSession`].

use std::time::Duration;

/// Per-session counters separating the one-time registration cost from
/// the amortized per-RHS serving cost.
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    /// One-time registration wall time (partitioning + factorization +
    /// retaining the seed state) — the cost a cold solve pays per solve.
    pub register_time: Duration,
    /// `solve`/`solve_batch` calls served by this session.
    pub solve_calls: u64,
    /// Right-hand sides served (a batch of k counts k).
    pub rhs_served: u64,
    /// Largest batch width served so far.
    pub max_batch: usize,
    /// Total wall time across all solves (seeding + epochs).
    pub solve_time: Duration,
    /// Per-partition bytes of RHS-independent state retained for warm
    /// serving: the f32 block, the projector plus its prepacked
    /// A-panels, and the seed factors
    /// ([`crate::solver::resident_partition_bytes`]).  Empty for
    /// sessions that retain no factorization (DGD).
    pub resident_partition_bytes: Vec<u64>,
}

impl ServiceStats {
    pub(crate) fn record(&mut self, k: usize, elapsed: Duration) {
        self.solve_calls += 1;
        self.rhs_served += k as u64;
        self.max_batch = self.max_batch.max(k);
        self.solve_time += elapsed;
    }

    /// Mean wall time per served right-hand side, or `None` before the
    /// first solve.
    ///
    /// Computed in f64 seconds: `Duration / u32` would force the u64
    /// counter through a clamping cast, silently inflating the reported
    /// mean once a long-lived session serves more than `u32::MAX`
    /// right-hand sides.
    pub fn amortized_per_rhs(&self) -> Option<Duration> {
        if self.rhs_served == 0 {
            return None;
        }
        Some(Duration::from_secs_f64(
            self.solve_time.as_secs_f64() / self.rhs_served as f64,
        ))
    }

    /// Total resident-factorization bytes across all partitions.
    pub fn resident_bytes_total(&self) -> u64 {
        self.resident_partition_bytes.iter().sum()
    }

    /// One summary line for logs: cold registration cost vs the
    /// amortized warm per-RHS cost, plus the resident-factorization
    /// memory the warm path pays for (when the session retains any).
    pub fn summary(&self) -> String {
        let amortized = match self.amortized_per_rhs() {
            Some(d) => format!("{:.6}s", d.as_secs_f64()),
            None => "n/a".into(),
        };
        let resident = if self.resident_partition_bytes.is_empty() {
            String::new()
        } else {
            format!(
                ", resident {} B across {} partitions",
                self.resident_bytes_total(),
                self.resident_partition_bytes.len(),
            )
        };
        format!(
            "session: register(cold init)={:.6}s, {} solve calls / {} rhs \
             served (max batch {}), amortized {amortized}/rhs{resident}",
            self.register_time.as_secs_f64(),
            self.solve_calls,
            self.rhs_served,
            self.max_batch,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_amortization() {
        let mut s = ServiceStats::default();
        assert!(s.amortized_per_rhs().is_none());
        assert!(s.summary().contains("n/a"));
        s.record(1, Duration::from_millis(10));
        s.record(4, Duration::from_millis(30));
        assert_eq!(s.solve_calls, 2);
        assert_eq!(s.rhs_served, 5);
        assert_eq!(s.max_batch, 4);
        assert_eq!(s.amortized_per_rhs(), Some(Duration::from_millis(8)));
        assert!(s.summary().contains("2 solve calls / 5 rhs"));
    }

    #[test]
    fn summary_reports_resident_bytes() {
        let mut s = ServiceStats::default();
        assert!(!s.summary().contains("resident"));
        s.resident_partition_bytes = vec![100, 28];
        assert_eq!(s.resident_bytes_total(), 128);
        assert!(s.summary().contains("resident 128 B across 2 partitions"));
    }

    #[test]
    fn amortization_survives_counters_past_u32() {
        // a long-lived session: 2^33 rhs served in 2^33 seconds is
        // exactly 1s/rhs.  The old clamped `Duration / u32::MAX` divisor
        // reported ~2s — off by rhs_served / u32::MAX — and the error
        // grew without bound as the session kept serving.
        let s = ServiceStats {
            register_time: Duration::ZERO,
            solve_calls: 1,
            rhs_served: 1u64 << 33,
            max_batch: 1,
            solve_time: Duration::from_secs(1u64 << 33),
            resident_partition_bytes: Vec::new(),
        };
        let per = s.amortized_per_rhs().unwrap().as_secs_f64();
        assert!((per - 1.0).abs() < 1e-9, "amortized {per}s, want 1s");
    }
}
