//! `dapc audit` — a std-only static-analysis pass over the repo's own
//! sources, enforcing the determinism and unsafety contracts that the
//! dynamic suites (`simd_lane_contract`, `packing_contract`,
//! `distributed_equivalence`, …) can only check on specific shapes.
//!
//! The paper's equivalence guarantees (APC backends interchangeable
//! bit-for-bit; the accelerated variant preserving the fixed point)
//! survive in this codebase as *bitwise* contracts: pooled == serial,
//! SIMD == scalar, cluster == in-process.  Those contracts die through
//! mundane edits — a `HashMap` iteration feeding wire output, a float
//! `.sum()` outside the lane-structured kernels, an undocumented
//! `unsafe` block — so the audit turns each one into a named rule and
//! CI runs `dapc audit --ci` on every leg:
//!
//! | rule | contract |
//! |------|----------|
//! | `unsafe-confined`     | `unsafe` only in `linalg/simd.rs` + `parallel/pool.rs`, every site under `// SAFETY:` |
//! | `no-hashmap`          | `HashMap`/`HashSet` only under the xla-gated `runtime/`; BTree* is the house type |
//! | `no-fused-float`      | `mul_add`/`fmadd` only inside `linalg/simd.rs` |
//! | `fixed-order-reduce`  | typed float `.sum()` / float-seeded `.fold(` only inside `linalg/` |
//! | `env-registry`        | `DAPC_*` env reads only through [`crate::config::envvars`] |
//! | `wire-pairing`        | every `Message` variant appears in an encode *and* a decode arm |
//!
//! `// audit:allow(rule-id): reason` on the offending line (or in the
//! comment block directly above it) suppresses a finding; the
//! justification is mandatory — a bare `audit:allow` still reports.  Rationale for each rule lives in
//! `CONTRIBUTING.md` ("The determinism contract, statically").
//!
//! No `syn`, no `regex` (offline, zero registry deps): a
//! comment/string-aware line lexer ([`lexer`]) plus token rules and a
//! little brace tracking for the wire rule.

mod lexer;

pub use lexer::{has_token, lex, Line};

use std::fs;
use std::path::{Path, PathBuf};

use crate::error::Result;

/// The six audited contracts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    UnsafeConfined,
    NoHashmap,
    NoFusedFloat,
    FixedOrderReduce,
    EnvRegistry,
    WirePairing,
}

impl Rule {
    pub const ALL: [Rule; 6] = [
        Rule::UnsafeConfined,
        Rule::NoHashmap,
        Rule::NoFusedFloat,
        Rule::FixedOrderReduce,
        Rule::EnvRegistry,
        Rule::WirePairing,
    ];

    /// Stable identifier used in findings, JSON, and `audit:allow(...)`.
    pub fn id(self) -> &'static str {
        match self {
            Rule::UnsafeConfined => "unsafe-confined",
            Rule::NoHashmap => "no-hashmap",
            Rule::NoFusedFloat => "no-fused-float",
            Rule::FixedOrderReduce => "fixed-order-reduce",
            Rule::EnvRegistry => "env-registry",
            Rule::WirePairing => "wire-pairing",
        }
    }

    /// One-line statement of the contract (printed by `dapc audit`).
    pub fn summary(self) -> &'static str {
        match self {
            Rule::UnsafeConfined => {
                "unsafe only in linalg/simd.rs and parallel/pool.rs, every \
                 site documented with a SAFETY comment"
            }
            Rule::NoHashmap => {
                "HashMap/HashSet only under the xla-gated runtime/ \
                 (iteration order is nondeterministic; BTree* is the \
                 house type)"
            }
            Rule::NoFusedFloat => {
                "mul_add/fmadd only inside linalg/simd.rs (fusing changes \
                 rounding, breaking scalar==simd bitwise equality)"
            }
            Rule::FixedOrderReduce => {
                "typed float sums and float-seeded folds only inside \
                 linalg/ (reductions must use the fixed 8-lane tree)"
            }
            Rule::EnvRegistry => {
                "DAPC_* environment reads only through config::envvars"
            }
            Rule::WirePairing => {
                "every Message variant must appear in both an encode and \
                 a decode arm of coordinator/message.rs"
            }
        }
    }
}

/// One rule violation at a source location.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Root-relative path with forward slashes.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: Rule,
    /// What is wrong at this site.
    pub message: String,
    /// The offending source line, trimmed.
    pub excerpt: String,
}

impl Finding {
    /// `file:line: [rule] message — excerpt` (one terminal line).
    pub fn render(&self) -> String {
        format!(
            "{}:{}: [{}] {} — `{}`",
            self.file,
            self.line,
            self.rule.id(),
            self.message,
            self.excerpt
        )
    }
}

/// Result of auditing a file set.
#[derive(Debug)]
pub struct AuditReport {
    /// Unsuppressed findings, ordered by (file, line, rule).
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    /// Findings silenced by a justified `audit:allow`.
    pub suppressed: usize,
}

impl AuditReport {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }
}

// ---------------------------------------------------------------------------
// File-set walk
// ---------------------------------------------------------------------------

/// Audit every `.rs` file under `<root>/rust/src`, `<root>/rust/tests`,
/// and `<root>/benches`.  `rust/tests/audit_fixtures/` is excluded: it
/// holds *seeded violations* that `rust/tests/audit.rs` feeds through
/// [`scan_source`] to prove each rule fires.
pub fn audit_root(root: &Path) -> Result<AuditReport> {
    let mut files: Vec<(PathBuf, String)> = Vec::new();
    for top in ["rust/src", "rust/tests", "benches"] {
        let dir = root.join(top);
        if dir.is_dir() {
            collect_rs(&dir, top, &mut files)?;
        }
    }
    // read_dir order is platform-dependent; sort by relative path so
    // the report (and its JSON artifact) is deterministic
    files.sort_by(|a, b| a.1.cmp(&b.1));
    let mut findings = Vec::new();
    let mut suppressed = 0usize;
    for (abs, rel) in &files {
        let src = fs::read_to_string(abs)?;
        let (mut f, s) = scan_source(rel, &src);
        findings.append(&mut f);
        suppressed += s;
    }
    Ok(AuditReport { findings, files_scanned: files.len(), suppressed })
}

fn collect_rs(
    dir: &Path,
    rel: &str,
    out: &mut Vec<(PathBuf, String)>,
) -> Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        let name = entry.file_name().to_string_lossy().into_owned();
        if path.is_dir() {
            if name == "audit_fixtures" {
                continue;
            }
            collect_rs(&path, &format!("{rel}/{name}"), out)?;
        } else if name.ends_with(".rs") {
            out.push((path, format!("{rel}/{name}")));
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Per-file scan
// ---------------------------------------------------------------------------

/// Scan one file's text under its root-relative path (which decides
/// which rules apply where).  Returns (unsuppressed findings, count of
/// justified suppressions).  Public so the fixture self-test can scan
/// seeded violations under pretend paths.
pub fn scan_source(rel: &str, src: &str) -> (Vec<Finding>, usize) {
    let lines = lexer::lex(src);
    let mut raw: Vec<Finding> = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        rule_unsafe_confined(rel, &lines, idx, &mut raw);
        rule_no_hashmap(rel, line, idx, &mut raw);
        rule_no_fused_float(rel, line, idx, &mut raw);
        rule_fixed_order_reduce(rel, line, idx, &mut raw);
        rule_env_registry(rel, line, idx, &mut raw);
    }
    if rel.ends_with("coordinator/message.rs") {
        rule_wire_pairing(rel, &lines, &mut raw);
    }

    let mut findings = Vec::new();
    let mut suppressed = 0usize;
    for mut f in raw {
        match allow_marker(&lines, f.line - 1, f.rule) {
            Allow::Justified => suppressed += 1,
            Allow::MissingReason => {
                f.message.push_str(
                    " (audit:allow without a `: reason` does not suppress)",
                );
                findings.push(f);
            }
            Allow::None => findings.push(f),
        }
    }
    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    (findings, suppressed)
}

fn push(
    out: &mut Vec<Finding>,
    rel: &str,
    line: &Line,
    line_no: usize,
    rule: Rule,
    message: String,
) {
    let trimmed = line.raw.trim();
    let mut excerpt: String = trimmed.chars().take(96).collect();
    if excerpt.len() < trimmed.len() {
        excerpt.push('…');
    }
    out.push(Finding { file: rel.to_string(), line: line_no, rule, message, excerpt });
}

// ---------------------------------------------------------------------------
// Suppression markers
// ---------------------------------------------------------------------------

enum Allow {
    None,
    Justified,
    MissingReason,
}

/// Look for `audit:allow(<rule-id>)` in the comments of the finding's
/// line or the contiguous pure-comment block directly above it (so a
/// justification may wrap onto several comment lines).  Only a marker
/// followed by `: <nonempty reason>` suppresses — the justification is
/// the point.
fn allow_marker(lines: &[Line], idx: usize, rule: Rule) -> Allow {
    let marker = format!("audit:allow({})", rule.id());
    let mut best = Allow::None;
    let mut j = idx;
    loop {
        let line = &lines[j];
        if let Some(pos) = line.comment.find(&marker) {
            let rest = line.comment[pos + marker.len()..].trim_start();
            match rest.strip_prefix(':') {
                Some(reason) if !reason.trim().is_empty() => {
                    return Allow::Justified;
                }
                _ => best = Allow::MissingReason,
            }
        }
        if j == 0 {
            break;
        }
        let above = &lines[j - 1];
        let pure_comment = above.code.trim().is_empty()
            && !above.comment.trim().is_empty();
        if !pure_comment {
            break;
        }
        j -= 1;
    }
    best
}

// ---------------------------------------------------------------------------
// Rules 1–5: token rules over the code channel
// ---------------------------------------------------------------------------

const UNSAFE_FILES: [&str; 2] =
    ["rust/src/linalg/simd.rs", "rust/src/parallel/pool.rs"];

fn rule_unsafe_confined(
    rel: &str,
    lines: &[Line],
    idx: usize,
    out: &mut Vec<Finding>,
) {
    if !lexer::has_token(&lines[idx].code, "unsafe") {
        return;
    }
    if !UNSAFE_FILES.contains(&rel) {
        push(
            out,
            rel,
            &lines[idx],
            idx + 1,
            Rule::UnsafeConfined,
            "`unsafe` outside the audited kernel/pool files".to_string(),
        );
    } else if !safety_documented(lines, idx) {
        push(
            out,
            rel,
            &lines[idx],
            idx + 1,
            Rule::UnsafeConfined,
            "`unsafe` site without an immediately-preceding SAFETY comment"
                .to_string(),
        );
    }
}

/// An `unsafe` site counts as documented when a `SAFETY:` comment sits
/// on the same line or in the contiguous comment/attribute block
/// directly above it (doc comments and `#[...]` attributes may
/// intervene; a blank line or other code breaks the chain).
fn safety_documented(lines: &[Line], idx: usize) -> bool {
    if lines[idx].comment.contains("SAFETY:") {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let line = &lines[j];
        if line.comment.contains("SAFETY:") {
            return true;
        }
        let code = line.code.trim();
        let pure_comment = code.is_empty() && !line.comment.trim().is_empty();
        let attribute = code.starts_with("#[") || code.starts_with("#!");
        if !(pure_comment || attribute) {
            return false;
        }
    }
    false
}

fn rule_no_hashmap(rel: &str, line: &Line, idx: usize, out: &mut Vec<Finding>) {
    if rel.starts_with("rust/src/runtime/") {
        return;
    }
    for t in ["HashMap", "HashSet"] {
        if lexer::has_token(&line.code, t) {
            push(
                out,
                rel,
                line,
                idx + 1,
                Rule::NoHashmap,
                format!("{t} outside runtime/ — iteration order is \
                         nondeterministic; use the BTree equivalent"),
            );
        }
    }
}

fn rule_no_fused_float(
    rel: &str,
    line: &Line,
    idx: usize,
    out: &mut Vec<Finding>,
) {
    if rel == "rust/src/linalg/simd.rs" {
        return;
    }
    let fused = lexer::has_token(&line.code, "mul_add")
        || line.code.contains("fmadd");
    if fused {
        push(
            out,
            rel,
            line,
            idx + 1,
            Rule::NoFusedFloat,
            "fused multiply-add outside simd.rs — fusing changes rounding \
             and breaks scalar==simd bitwise equality"
                .to_string(),
        );
    }
}

fn rule_fixed_order_reduce(
    rel: &str,
    line: &Line,
    idx: usize,
    out: &mut Vec<Finding>,
) {
    if rel.starts_with("rust/src/linalg/") {
        return;
    }
    let typed_sum = line.code.contains(".sum::<f32>")
        || line.code.contains(".sum::<f64>");
    let message = if typed_sum {
        "order-sensitive float sum outside linalg/ — route reductions \
         through the fixed 8-lane kernels"
    } else if float_seeded_fold(&line.code) {
        "float-seeded fold outside linalg/ — reduction order must be the \
         fixed 8-lane tree"
    } else {
        return;
    };
    push(out, rel, line, idx + 1, Rule::FixedOrderReduce, message.to_string());
}

/// Does the code channel contain `.fold(` whose first argument starts
/// with a float literal (`0.0`, `1.5f32`, `2e-3`, …)?  Integer seeds,
/// tuple seeds, and named constants (`f64::INFINITY`) are deliberately
/// out of scope — those sites are order-insensitive or integer folds.
fn float_seeded_fold(code: &str) -> bool {
    let needle = ".fold(";
    let mut start = 0;
    while let Some(p) = code[start..].find(needle) {
        let arg = code[start + p + needle.len()..].trim_start();
        if leads_with_float_literal(arg) {
            return true;
        }
        start += p + needle.len();
    }
    false
}

fn leads_with_float_literal(s: &str) -> bool {
    let s = s.strip_prefix('-').unwrap_or(s);
    let digits =
        s.chars().take_while(|c| c.is_ascii_digit() || *c == '_').count();
    if digits == 0 {
        return false;
    }
    let rest: String = s.chars().skip(digits).collect();
    let decimal_point = rest.starts_with('.')
        && rest.chars().nth(1).map(|c| c.is_ascii_digit()).unwrap_or(false);
    decimal_point
        || rest.starts_with("f32")
        || rest.starts_with("f64")
        || rest.starts_with('e')
        || rest.starts_with('E')
}

fn rule_env_registry(
    rel: &str,
    line: &Line,
    idx: usize,
    out: &mut Vec<Finding>,
) {
    if rel == "rust/src/config/envvars.rs" {
        return;
    }
    let reads_env = line.code.contains("env::var")
        || line.code.contains("var_os")
        || line.code.contains("option_env!");
    if reads_env && line.strings.iter().any(|s| s.starts_with("DAPC_")) {
        push(
            out,
            rel,
            line,
            idx + 1,
            Rule::EnvRegistry,
            "raw DAPC_* environment read — go through config::envvars"
                .to_string(),
        );
    }
}

// ---------------------------------------------------------------------------
// Rule 6: wire pairing (brace-tracking over coordinator/message.rs)
// ---------------------------------------------------------------------------

fn rule_wire_pairing(rel: &str, lines: &[Line], out: &mut Vec<Finding>) {
    let Some(enum_line) = lines
        .iter()
        .position(|l| lexer::has_token(&l.code, "enum") && lexer::has_token(&l.code, "Message"))
    else {
        out.push(Finding {
            file: rel.to_string(),
            line: 1,
            rule: Rule::WirePairing,
            message: "no `enum Message` found to audit".to_string(),
            excerpt: String::new(),
        });
        return;
    };
    let variants = enum_variants(lines, enum_line);
    if variants.is_empty() {
        out.push(Finding {
            file: rel.to_string(),
            line: enum_line + 1,
            rule: Rule::WirePairing,
            message: "`enum Message` has no parseable variants".to_string(),
            excerpt: lines[enum_line].raw.trim().to_string(),
        });
        return;
    }
    let encode_body = fn_bodies(lines, "encode");
    let decode_body = fn_bodies(lines, "decode");
    for (name, line_no) in &variants {
        let qualified = format!("Message::{name}");
        let self_form = format!("Self::{name}");
        let in_enc = lexer::has_token(&encode_body, &qualified)
            || lexer::has_token(&encode_body, &self_form);
        let in_dec = lexer::has_token(&decode_body, &qualified)
            || lexer::has_token(&decode_body, &self_form);
        for (ok, side) in [(in_enc, "an encode"), (in_dec, "a decode")] {
            if !ok {
                push(
                    out,
                    rel,
                    &lines[line_no - 1],
                    *line_no,
                    Rule::WirePairing,
                    format!("variant `{name}` never appears in {side} arm"),
                );
            }
        }
    }
}

/// Collect `(variant name, 1-based line)` for identifiers declared at
/// depth 1 of the brace block opened on `start`'s line.  Assumes one
/// variant per line (the repo style rustfmt enforces).
fn enum_variants(lines: &[Line], start: usize) -> Vec<(String, usize)> {
    let mut depth = 0usize;
    let mut opened = false;
    let mut variants = Vec::new();
    for (li, line) in lines.iter().enumerate().skip(start) {
        if opened && depth == 1 {
            let name: String = line
                .code
                .trim_start()
                .chars()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect();
            if name.chars().next().map(|c| c.is_ascii_uppercase()).unwrap_or(false)
            {
                variants.push((name, li + 1));
            }
        }
        for c in line.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => {
                    depth = depth.saturating_sub(1);
                    if opened && depth == 0 {
                        return variants;
                    }
                }
                _ => {}
            }
        }
    }
    variants
}

/// Concatenated code of every `fn` whose name starts with `prefix`
/// (`encode` matches `encode`, `encode_into`, `encoded_len`; the union
/// is what the pairing check searches).
fn fn_bodies(lines: &[Line], prefix: &str) -> String {
    let mut out = String::new();
    let mut i = 0;
    while i < lines.len() {
        if !declares_fn(&lines[i].code, prefix) {
            i += 1;
            continue;
        }
        let mut depth = 0usize;
        let mut opened = false;
        while i < lines.len() {
            for c in lines[i].code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth = depth.saturating_sub(1),
                    _ => {}
                }
            }
            out.push_str(&lines[i].code);
            out.push('\n');
            i += 1;
            if opened && depth == 0 {
                break;
            }
        }
    }
    out
}

fn declares_fn(code: &str, prefix: &str) -> bool {
    let mut start = 0;
    while let Some(p) = code[start..].find("fn ") {
        let abs = start + p;
        let boundary = code[..abs]
            .chars()
            .last()
            .map(|c| !(c.is_alphanumeric() || c == '_'))
            .unwrap_or(true);
        let name = code[abs + 3..].trim_start();
        if boundary && name.starts_with(prefix) {
            return true;
        }
        start = abs + 3;
    }
    false
}

// ---------------------------------------------------------------------------
// JSON rendering (std-only, mirrors benchkit's hand-rolled style)
// ---------------------------------------------------------------------------

/// Render the report as a JSON document (the `--json PATH` artifact CI
/// uploads from every leg).
pub fn render_json(report: &AuditReport) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!(
        "  \"files_scanned\": {},\n  \"suppressed\": {},\n  \"findings\": [\n",
        report.files_scanned, report.suppressed
    ));
    for (i, f) in report.findings.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"file\": {}, \"line\": {}, \"rule\": {}, \
             \"message\": {}, \"excerpt\": {}}}{}\n",
            json_str(&f.file),
            f.line,
            json_str(f.rule.id()),
            json_str(&f.message),
            json_str(&f.excerpt),
            if i + 1 < report.findings.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule.id()).collect()
    }

    #[test]
    fn unsafe_outside_allowed_files_fires() {
        let src = "fn f() {\n    unsafe { danger() }\n}\n";
        let (f, _) = scan_source("rust/src/solver/engine.rs", src);
        assert_eq!(rules_of(&f), vec!["unsafe-confined"]);
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn documented_unsafe_in_simd_is_clean() {
        let src = "fn f() {\n    // SAFETY: caller checked avx2\n    unsafe { go() }\n}\n";
        let (f, _) = scan_source("rust/src/linalg/simd.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn undocumented_unsafe_in_simd_fires() {
        let src = "fn f() {\n    unsafe { go() }\n}\n";
        let (f, _) = scan_source("rust/src/linalg/simd.rs", src);
        assert_eq!(rules_of(&f), vec!["unsafe-confined"]);
    }

    #[test]
    fn safety_comment_skips_doc_and_attributes() {
        let src = "/// Docs.\n\
                   // SAFETY: lanes checked\n\
                   #[target_feature(enable = \"avx2\")]\n\
                   unsafe fn k() {}\n";
        let (f, _) = scan_source("rust/src/linalg/simd.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn blank_line_breaks_the_safety_chain() {
        let src = "// SAFETY: stale\n\nunsafe fn k() {}\n";
        let (f, _) = scan_source("rust/src/linalg/simd.rs", src);
        assert_eq!(rules_of(&f), vec!["unsafe-confined"]);
    }

    #[test]
    fn the_word_unsafe_in_comments_and_strings_is_ignored() {
        let src = "// totally unsafe idea\nlet s = \"unsafe\";\n";
        let (f, _) = scan_source("rust/src/solver/engine.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn hashmap_fires_outside_runtime_only() {
        let src = "use std::collections::HashMap;\n";
        let (f, _) = scan_source("rust/src/coordinator/leader.rs", src);
        assert_eq!(rules_of(&f), vec!["no-hashmap"]);
        let (f, _) = scan_source("rust/src/runtime/pjrt.rs", src);
        assert!(f.is_empty());
    }

    #[test]
    fn fused_float_fires_outside_simd_only() {
        let src = "let y = a.mul_add(b, c);\n";
        let (f, _) = scan_source("rust/src/linalg/blas.rs", src);
        assert_eq!(rules_of(&f), vec!["no-fused-float"]);
        let (f, _) = scan_source("rust/src/linalg/simd.rs", src);
        assert!(f.is_empty());
    }

    #[test]
    fn typed_float_sum_fires_outside_linalg_only() {
        let src = "let t = xs.iter().sum::<f64>();\n";
        let (f, _) = scan_source("rust/src/metrics/timer.rs", src);
        assert_eq!(rules_of(&f), vec!["fixed-order-reduce"]);
        let (f, _) = scan_source("rust/src/linalg/norms.rs", src);
        assert!(f.is_empty());
    }

    #[test]
    fn float_seeded_fold_fires_but_integer_and_const_seeds_do_not() {
        let fires = "let m = xs.iter().fold(0.0f32, f32::max);\n";
        let (f, _) = scan_source("rust/src/sparse/generate.rs", fires);
        assert_eq!(rules_of(&f), vec!["fixed-order-reduce"]);
        let quiet = "let a = xs.iter().fold(0, |s, x| s + x);\n\
                     let b = xs.iter().fold((0, 0), |s, _| s);\n\
                     let c = xs.iter().fold(f64::INFINITY, f64::min);\n";
        let (f, _) = scan_source("rust/src/sparse/generate.rs", quiet);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn raw_env_read_fires_outside_envvars_only() {
        let src = "let v = std::env::var(\"DAPC_QUICK\").ok();\n";
        let (f, _) = scan_source("rust/src/benchkit/mod.rs", src);
        assert_eq!(rules_of(&f), vec!["env-registry"]);
        let (f, _) = scan_source("rust/src/config/envvars.rs", src);
        assert!(f.is_empty());
    }

    #[test]
    fn non_dapc_env_reads_are_fine() {
        let src = "let home = std::env::var(\"HOME\").ok();\n";
        let (f, _) = scan_source("rust/src/main.rs", src);
        assert!(f.is_empty());
    }

    #[test]
    fn wire_pairing_catches_a_decode_only_and_an_encode_only_variant() {
        let src = "\
pub enum Message {\n\
    Ping,\n\
    Pong,\n\
    Lost,\n\
}\n\
impl Message {\n\
    pub fn encode_into(&self, b: &mut Vec<u8>) {\n\
        match self {\n\
            Message::Ping => b.push(0),\n\
            Message::Lost => b.push(2),\n\
            _ => {}\n\
        }\n\
    }\n\
    pub fn decode(b: &[u8]) -> Option<Message> {\n\
        match b[0] {\n\
            0 => Some(Message::Ping),\n\
            1 => Some(Message::Pong),\n\
            _ => None,\n\
        }\n\
    }\n\
}\n";
        let (f, _) = scan_source("rust/src/coordinator/message.rs", src);
        let mut got: Vec<String> =
            f.iter().map(|x| x.message.clone()).collect();
        got.sort();
        assert_eq!(got.len(), 2, "{got:?}");
        assert!(got[0].contains("`Lost` never appears in a decode"));
        assert!(got[1].contains("`Pong` never appears in an encode arm"));
    }

    #[test]
    fn justified_allow_suppresses_and_is_counted() {
        let src = "// audit:allow(no-hashmap): scratch set, never iterated\n\
                   use std::collections::HashSet;\n";
        let (f, suppressed) = scan_source("rust/src/rng/xoshiro.rs", src);
        assert!(f.is_empty(), "{f:?}");
        assert_eq!(suppressed, 1);
    }

    #[test]
    fn allow_marker_reaches_through_a_wrapped_comment_block() {
        // the marker sits two comment lines above the finding — the
        // justification wraps, as the in-tree suppressions do
        let src = "// audit:allow(no-hashmap): scratch set, never\n\
                   // iterated; only membership is queried\n\
                   use std::collections::HashSet;\n";
        let (f, suppressed) = scan_source("rust/src/rng/xoshiro.rs", src);
        assert!(f.is_empty(), "{f:?}");
        assert_eq!(suppressed, 1);
        // a blank line breaks the block: the marker no longer applies
        let broken = "// audit:allow(no-hashmap): stale marker\n\
                      \n\
                      use std::collections::HashSet;\n";
        let (f, suppressed) = scan_source("rust/src/rng/xoshiro.rs", broken);
        assert_eq!(f.len(), 1);
        assert_eq!(suppressed, 0);
    }

    #[test]
    fn allow_without_reason_does_not_suppress() {
        let src = "// audit:allow(no-hashmap)\n\
                   use std::collections::HashSet;\n";
        let (f, suppressed) = scan_source("rust/src/rng/xoshiro.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(suppressed, 0);
        assert!(f[0].message.contains("does not suppress"));
    }

    #[test]
    fn allow_for_a_different_rule_does_not_suppress() {
        let src = "// audit:allow(no-fused-float): wrong rule\n\
                   use std::collections::HashSet;\n";
        let (f, _) = scan_source("rust/src/rng/xoshiro.rs", src);
        assert_eq!(rules_of(&f), vec!["no-hashmap"]);
    }

    #[test]
    fn json_report_is_well_formed_enough_to_round_trip_keys() {
        let report = AuditReport {
            findings: vec![Finding {
                file: "rust/src/a.rs".into(),
                line: 3,
                rule: Rule::NoHashmap,
                message: "msg with \"quotes\"".into(),
                excerpt: "let x = 1;".into(),
            }],
            files_scanned: 7,
            suppressed: 2,
        };
        let json = render_json(&report);
        assert!(json.contains("\"files_scanned\": 7"));
        assert!(json.contains("\"suppressed\": 2"));
        assert!(json.contains("\"rule\": \"no-hashmap\""));
        assert!(json.contains("msg with \\\"quotes\\\""));
        // crate's own parser accepts it
        assert!(crate::config::json::Json::parse(&json).is_ok());
    }
}
