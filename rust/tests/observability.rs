//! Observability contract tests (integration level).
//!
//! Two guarantees the `obs` subsystem must hold across the whole crate:
//!
//! 1. **Lossless counting under concurrency** — pool jobs recorded from
//!    many worker threads at once never drop a count; the `pool.jobs`
//!    counter and the per-job histograms agree exactly with the number
//!    of jobs spawned.
//! 2. **Metrics never touch numerics** — the full warm-session suite
//!    (cold one-shot, warm streamed, batched, in-process and cluster
//!    backends) produces BIT-IDENTICAL `xbar`/`residual` with metrics
//!    enabled vs disabled.  Recording happens strictly outside the
//!    kernels, so `assert_eq!` — not a tolerance — is the right check.
//!
//! The registry and the enabled flag are process-global, so every test
//! here serializes on a local lock and reads counter *deltas* against a
//! baseline rather than absolute values.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use dapc::coordinator::LocalCluster;
use dapc::linalg::Matrix;
use dapc::obs;
use dapc::parallel::ThreadPool;
use dapc::rng::seeded;
use dapc::service::{SessionAlgorithm, SessionConfig, SolverSession};
use dapc::solver::{
    drive_apc, ApcVariant, InProcessBackend, NativeEngine, SolveOptions,
    SolveReport,
};
use dapc::sparse::CsrMatrix;

/// Serializes tests that flip the process-global enabled flag.  (The
/// crate-internal test lock is `pub(crate)`; this binary is a separate
/// process from the unit tests, so a local lock is sufficient.)
static OBS_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn consistent_system(m: usize, n: usize, seed: u64) -> (CsrMatrix, Vec<f32>) {
    let mut g = seeded(seed);
    let dense = Matrix::from_fn(m, n, |i, j| {
        if (i + j) % 7 == 0 {
            0.0
        } else {
            g.normal_f32()
        }
    });
    let x: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
    let mut b = vec![0.0f32; m];
    dapc::linalg::blas::gemv(&dense, &x, &mut b);
    (CsrMatrix::from_dense(&dense), b)
}

fn rhs_stream(a: &CsrMatrix, k: usize, seed: u64) -> Vec<Vec<f32>> {
    (0..k)
        .map(|i| {
            let mut g = seeded(seed + i as u64);
            let x: Vec<f32> =
                (0..a.cols()).map(|_| g.normal_f32()).collect();
            let mut b = vec![0.0f32; a.rows()];
            a.spmv_into(&x, &mut b);
            b
        })
        .collect()
}

#[test]
fn pool_concurrent_increments_lose_no_counts() {
    let _g = lock();
    obs::set_enabled(true);
    let jobs0 = obs::counter("pool.jobs").get();
    let wait0 = obs::histogram("pool.queue_wait_ns").count();
    let run0 = obs::histogram("pool.run_ns").count();

    const JOBS: usize = 512;
    let pool = ThreadPool::new(8);
    let ran = AtomicUsize::new(0);
    pool.scope(|s| {
        for _ in 0..JOBS {
            s.spawn(|| {
                ran.fetch_add(1, Ordering::SeqCst);
            });
        }
    });
    assert_eq!(ran.load(Ordering::SeqCst), JOBS);

    // every job is counted exactly once, on all three instruments, even
    // with 8 workers racing on the shared atomics
    let jobs = obs::counter("pool.jobs").get() - jobs0;
    let waits = obs::histogram("pool.queue_wait_ns").count() - wait0;
    let runs = obs::histogram("pool.run_ns").count() - run0;
    assert_eq!(jobs, JOBS as u64, "pool.jobs dropped counts");
    assert_eq!(waits, JOBS as u64, "queue_wait_ns dropped samples");
    assert_eq!(runs, JOBS as u64, "run_ns dropped samples");
    obs::set_enabled(false);
}

/// The warm-session suite as one deterministic run: cold per-rhs
/// solves, a warm streamed session, and one k-sized batch, over both
/// the in-process and the local-cluster backend.
fn run_suite(a: &CsrMatrix, bs: &[Vec<f32>]) -> Vec<SolveReport> {
    let variant = ApcVariant::Decomposed;
    let algo = SessionAlgorithm::Apc(variant);
    let opts = SolveOptions { epochs: 20, ..Default::default() };
    let engine = NativeEngine::new();
    let j = 3;
    let mut out = Vec::new();

    for b in bs {
        let mut backend = InProcessBackend::new(&engine, j);
        out.push(drive_apc(&mut backend, a, b, variant, &opts).unwrap());
    }

    let config = SessionConfig::new(algo).options(opts.clone());
    let mut backend = InProcessBackend::new(&engine, j);
    let mut session =
        SolverSession::register(&mut backend, a.clone(), config.clone())
            .unwrap();
    for b in bs {
        out.push(session.solve(b).unwrap());
    }
    out.extend(session.solve_batch(bs).unwrap());
    drop(session);

    let mut cluster = LocalCluster::spawn(j, NativeEngine::new).unwrap();
    let mut dist = SolverSession::register(
        cluster.leader.backend_mut(),
        a.clone(),
        config,
    )
    .unwrap();
    for b in bs {
        out.push(dist.solve(b).unwrap());
    }
    out.extend(dist.solve_batch(bs).unwrap());
    out
}

#[test]
fn metrics_on_is_bitwise_identical_to_metrics_off() {
    let _g = lock();
    let (a, _) = consistent_system(103, 10, 91);
    let bs = rhs_stream(&a, 3, 9100);

    obs::set_enabled(false);
    let off = run_suite(&a, &bs);
    obs::set_enabled(true);
    let on = run_suite(&a, &bs);
    obs::set_enabled(false);

    assert_eq!(off.len(), on.len());
    for (i, (o, n)) in off.iter().zip(&on).enumerate() {
        // bitwise, not approximate: recording must never enter a kernel
        assert_eq!(o.xbar, n.xbar, "xbar diverged at report {i}");
        assert_eq!(o.residual, n.residual, "residual diverged at {i}");
        assert_eq!(o.epochs, n.epochs);
    }
}

#[test]
fn cluster_session_populates_per_rhs_and_gather_instruments() {
    let _g = lock();
    obs::set_enabled(true);
    let warm0 = obs::histogram("service.warm_rhs_ns").count();
    let batch0 = obs::histogram("service.batch_rhs_ns").count();
    let served0 = obs::counter("service.rhs_served").get();
    let gather0 = obs::histogram("cluster.gather_ns.w0").count();
    let seed0 = obs::histogram("driver.seed_ns").count();

    let (a, _) = consistent_system(96, 10, 92);
    let bs = rhs_stream(&a, 3, 9200);
    let mut cluster = LocalCluster::spawn(3, NativeEngine::new).unwrap();
    let mut session = SolverSession::register(
        cluster.leader.backend_mut(),
        a.clone(),
        SessionConfig::apc(ApcVariant::Decomposed).epochs(10),
    )
    .unwrap();
    session.solve(&bs[0]).unwrap();
    session.solve_batch(&bs).unwrap();
    drop(session);

    let warm = obs::histogram("service.warm_rhs_ns").count() - warm0;
    let batch = obs::histogram("service.batch_rhs_ns").count() - batch0;
    let served = obs::counter("service.rhs_served").get() - served0;
    assert_eq!(warm, 1, "one warm single-rhs solve");
    assert_eq!(batch, 3, "k=3 batch records one sample per rhs");
    // the validator cross-check contract: counter == histogram counts
    assert_eq!(served, warm + batch);
    assert!(
        obs::histogram("cluster.gather_ns.w0").count() > gather0,
        "cluster gather latency must be sampled per worker"
    );
    assert!(
        obs::histogram("driver.seed_ns").count() > seed0,
        "driver phase spans must cover the session seed phase"
    );
    // a full registry dump round-trips through the JSON validator
    let json = obs::global().render_json();
    let n = dapc::obs::export::validate_metrics_text(&json).unwrap();
    assert!(n > 0, "registry dump must carry at least one metric");
    obs::set_enabled(false);
}
