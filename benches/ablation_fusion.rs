//! Ablation: PJRT call-granularity for the consensus hot path.
//!
//! Three executions of the same T epochs on the XLA engine:
//!   * per-op    — one PJRT call per eq. (6) update + native average
//!   * fused     — one `round_*` artifact call per epoch (update+average)
//!   * loop      — ONE `solve_*` artifact call for all T epochs
//!
//! Quantifies how much of the epoch cost is call/transfer overhead vs
//! compute — the L2 optimization lever recorded in EXPERIMENTS.md §Perf.
//! Requires `make artifacts`. Skips gracefully when absent.

use std::path::Path;

use dapc::benchkit::{black_box, quick_mode, Bench};
use dapc::linalg::Matrix;
use dapc::metrics::TableBuilder;
use dapc::rng::seeded;
use dapc::runtime::executor::XlaExecutorHost;
use dapc::solver::{ComputeEngine, XlaEngine};

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("ablation_fusion: artifacts not built; run `make artifacts` first");
        return;
    }
    let host = XlaExecutorHost::spawn(dir).expect("pjrt");
    let sizes: &[usize] = if quick_mode() { &[32] } else { &[32, 128, 512] };
    let t_epochs = if quick_mode() { 10 } else { 50 };
    let j = 2;
    let bench = Bench::default();
    let mut table =
        TableBuilder::new(&["n", "per-op", "fused round", "fused loop", "best vs per-op"]);

    println!("=== Ablation: PJRT call granularity (J={j}, T={t_epochs}) ===");
    for &n in sizes {
        let mut g = seeded(n as u64);
        let xs: Vec<Vec<f32>> = (0..j)
            .map(|_| (0..n).map(|_| g.normal_f32()).collect())
            .collect();
        let xbar: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
        let ps: Vec<Matrix> = (0..j)
            .map(|_| Matrix::from_fn(n, n, |_, _| 0.02 * g.normal_f32()))
            .collect();

        let mut per_op = XlaEngine::new(host.executor());
        per_op.fused_rounds = false;
        let fused = XlaEngine::new(host.executor());
        let mut looped = XlaEngine::new(host.executor());
        looped.fused_loop = true;

        // warm compile caches outside the timed region
        let _ = per_op.round(&xs, &xbar, &ps, 0.5, 0.5).unwrap();
        let _ = fused.round(&xs, &xbar, &ps, 0.5, 0.5).unwrap();
        let _ = looped.solve_loop(&xs, &xbar, &ps, 0.5, 0.5, 1).unwrap();

        let r_perop = bench.run(&format!("per-op      n={n}"), || {
            let (mut cx, mut cb) = (xs.clone(), xbar.clone());
            for _ in 0..t_epochs {
                let (a, b) = per_op.round(&cx, &cb, &ps, 0.5, 0.5).unwrap();
                cx = a;
                cb = b;
            }
            black_box(cb[0]);
        });
        let r_fused = bench.run(&format!("fused round n={n}"), || {
            let (mut cx, mut cb) = (xs.clone(), xbar.clone());
            for _ in 0..t_epochs {
                let (a, b) = fused.round(&cx, &cb, &ps, 0.5, 0.5).unwrap();
                cx = a;
                cb = b;
            }
            black_box(cb[0]);
        });
        let r_loop = bench.run(&format!("fused loop  n={n}"), || {
            let out = looped
                .solve_loop(&xs, &xbar, &ps, 0.5, 0.5, t_epochs)
                .unwrap()
                .expect("solve artifact");
            black_box(out.1[0]);
        });

        let best = r_fused.stats.median().min(r_loop.stats.median());
        table.row(&[
            n.to_string(),
            format!("{:.2}ms", r_perop.stats.median() * 1e3),
            format!("{:.2}ms", r_fused.stats.median() * 1e3),
            format!("{:.2}ms", r_loop.stats.median() * 1e3),
            format!("{:.2}x", r_perop.stats.median() / best),
        ]);
    }
    println!("\n{}", table.render());
}
