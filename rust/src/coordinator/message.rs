//! Wire protocol between leader and workers.
//!
//! Hand-rolled binary framing (serde unavailable offline):
//!
//! ```text
//! frame   := u32 header (LE) | u32 payload_len (LE) | payload
//! header  := 0x4450_0000 | WIRE_VERSION   ("DP" magic + version)
//! payload := u8 tag | fields in declaration order
//! vec<f32>:= u64 len | f32 * len        (LE)
//! matrix  := u64 rows | u64 cols | f32 * rows*cols (row-major)
//! string  := u64 len | utf8 bytes
//! ```
//!
//! The frame header is added by stream transports (see
//! [`super::transport`]); it makes old/new peer mixes fail LOUDLY at the
//! first frame instead of mis-decoding each other's bytes.  Bump
//! [`WIRE_VERSION`] whenever the payload encoding changes.
//!
//! The protocol is deliberately small: projectors are computed worker-side
//! and never serialized; per-epoch traffic is one n-vector each way per
//! worker (the paper's communication pattern).  DGD initialization uses
//! [`InitKindWire::GradOnly`], which ships the block but skips the
//! worker-side factorization entirely.

use crate::error::{DapcError, Result};
use crate::linalg::Matrix;
use crate::solver::InitKind;

/// Version of the payload encoding; carried in every stream frame header.
///
/// v1 was the unversioned PR-0 framing (`u32 len | payload`); v2 added the
/// magic/version header and `InitKindWire::GradOnly`.
pub const WIRE_VERSION: u32 = 2;

/// Protocol messages (both directions).
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Leader -> worker: here is your partition; run init.
    InitPartition {
        worker_id: u32,
        kind: InitKindWire,
        a: Matrix,
        b: Vec<f32>,
        /// Padded solution width the consensus loop runs at.
        n_target: u32,
    },
    /// Worker -> leader: init finished, here is x_j(0) (empty for
    /// [`InitKindWire::GradOnly`] — DGD starts from x = 0).
    InitDone { worker_id: u32, x0: Vec<f32> },
    /// Leader -> worker: consensus epoch t with the current average.
    RunUpdate { epoch: u32, gamma: f32, xbar: Vec<f32> },
    /// Worker -> leader: updated estimate x_j(t+1).
    UpdateDone { worker_id: u32, x: Vec<f32> },
    /// Leader -> worker: DGD gradient request at the current iterate.
    RunGrad { epoch: u32, x: Vec<f32> },
    /// Worker -> leader: local gradient.
    GradDone { worker_id: u32, grad: Vec<f32> },
    /// Worker -> leader: failure (leader aborts the run).
    WorkerError { worker_id: u32, message: String },
    /// Leader -> worker: done, exit the loop.
    Shutdown,
}

/// InitKind twin that is wire-encodable, plus the gradient-only mode that
/// has no engine-side factorization at all.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InitKindWire {
    Qr = 0,
    Classical = 1,
    Fat = 2,
    /// Store the block for DGD gradients only: no QR, no Gram inverse,
    /// no projector — worker init is O(nnz) instead of O(l n^2).
    GradOnly = 3,
}

impl InitKindWire {
    /// The engine-side factorization this wire kind requests, or `None`
    /// for [`Self::GradOnly`] (the worker stores the block and returns).
    pub fn engine_kind(self) -> Option<InitKind> {
        match self {
            Self::Qr => Some(InitKind::Qr),
            Self::Classical => Some(InitKind::Classical),
            Self::Fat => Some(InitKind::Fat),
            Self::GradOnly => None,
        }
    }
}

impl From<InitKind> for InitKindWire {
    fn from(k: InitKind) -> Self {
        match k {
            InitKind::Qr => Self::Qr,
            InitKind::Classical => Self::Classical,
            InitKind::Fat => Self::Fat,
        }
    }
}

// --- encoding ---------------------------------------------------------------

struct Enc<'a> {
    buf: &'a mut Vec<u8>,
}

impl<'a> Enc<'a> {
    fn new(buf: &'a mut Vec<u8>, tag: u8) -> Self {
        buf.push(tag);
        Self { buf }
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn vec_f32(&mut self, v: &[f32]) {
        self.buf.extend_from_slice(&(v.len() as u64).to_le_bytes());
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn matrix(&mut self, m: &Matrix) {
        self.buf.extend_from_slice(&(m.rows() as u64).to_le_bytes());
        self.buf.extend_from_slice(&(m.cols() as u64).to_le_bytes());
        for x in m.as_slice() {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn string(&mut self, s: &str) {
        self.buf.extend_from_slice(&(s.len() as u64).to_le_bytes());
        self.buf.extend_from_slice(s.as_bytes());
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(DapcError::Parse("truncated message".into()));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn vec_f32(&mut self) -> Result<Vec<f32>> {
        let len = self.u64()? as usize;
        let bytes = self.take(len * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn matrix(&mut self) -> Result<Matrix> {
        let rows = self.u64()? as usize;
        let cols = self.u64()? as usize;
        let bytes = self.take(rows * cols * 4)?;
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Matrix::from_vec(rows, cols, data))
    }

    fn string(&mut self) -> Result<String> {
        let len = self.u64()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| DapcError::Parse("invalid utf8 in message".into()))
    }

    fn finish(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(DapcError::Parse("trailing bytes in message".into()));
        }
        Ok(())
    }
}

const VEC_HEADER: usize = 8; // u64 length prefix
const MAT_HEADER: usize = 16; // u64 rows + u64 cols

impl Message {
    /// Append the tagged payload (no frame header) to `buf` — the
    /// transports' reused-send-buffer path.
    pub fn encode_into(&self, buf: &mut Vec<u8>) {
        match self {
            Message::InitPartition { worker_id, kind, a, b, n_target } => {
                let mut e = Enc::new(buf, 0);
                e.u32(*worker_id);
                e.buf.push(*kind as u8);
                e.matrix(a);
                e.vec_f32(b);
                e.u32(*n_target);
            }
            Message::InitDone { worker_id, x0 } => {
                let mut e = Enc::new(buf, 1);
                e.u32(*worker_id);
                e.vec_f32(x0);
            }
            Message::RunUpdate { epoch, gamma, xbar } => {
                let mut e = Enc::new(buf, 2);
                e.u32(*epoch);
                e.f32(*gamma);
                e.vec_f32(xbar);
            }
            Message::UpdateDone { worker_id, x } => {
                let mut e = Enc::new(buf, 3);
                e.u32(*worker_id);
                e.vec_f32(x);
            }
            Message::RunGrad { epoch, x } => {
                let mut e = Enc::new(buf, 4);
                e.u32(*epoch);
                e.vec_f32(x);
            }
            Message::GradDone { worker_id, grad } => {
                let mut e = Enc::new(buf, 5);
                e.u32(*worker_id);
                e.vec_f32(grad);
            }
            Message::WorkerError { worker_id, message } => {
                let mut e = Enc::new(buf, 6);
                e.u32(*worker_id);
                e.string(message);
            }
            Message::Shutdown => buf.push(7),
        }
    }

    /// Encode to a fresh tagged payload (no frame header).
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(self.encoded_len());
        self.encode_into(&mut buf);
        buf
    }

    /// Exact payload size [`Self::encode`] produces, without encoding —
    /// used for wire-byte accounting on in-process transports.
    pub fn encoded_len(&self) -> usize {
        match self {
            Message::InitPartition { a, b, .. } => {
                1 + 4
                    + 1
                    + MAT_HEADER
                    + 4 * a.rows() * a.cols()
                    + VEC_HEADER
                    + 4 * b.len()
                    + 4
            }
            Message::InitDone { x0, .. } => 1 + 4 + VEC_HEADER + 4 * x0.len(),
            Message::RunUpdate { xbar, .. } => {
                1 + 4 + 4 + VEC_HEADER + 4 * xbar.len()
            }
            Message::UpdateDone { x, .. } => 1 + 4 + VEC_HEADER + 4 * x.len(),
            Message::RunGrad { x, .. } => 1 + 4 + VEC_HEADER + 4 * x.len(),
            Message::GradDone { grad, .. } => {
                1 + 4 + VEC_HEADER + 4 * grad.len()
            }
            Message::WorkerError { message, .. } => {
                1 + 4 + VEC_HEADER + message.len()
            }
            Message::Shutdown => 1,
        }
    }

    /// Decode from a tagged payload.
    pub fn decode(buf: &[u8]) -> Result<Message> {
        let mut d = Dec { buf, pos: 0 };
        let tag = d.u8()?;
        let msg = match tag {
            0 => {
                let worker_id = d.u32()?;
                let kind = match d.u8()? {
                    0 => InitKindWire::Qr,
                    1 => InitKindWire::Classical,
                    2 => InitKindWire::Fat,
                    3 => InitKindWire::GradOnly,
                    k => {
                        return Err(DapcError::Parse(format!(
                            "bad init kind {k}"
                        )))
                    }
                };
                let a = d.matrix()?;
                let b = d.vec_f32()?;
                let n_target = d.u32()?;
                Message::InitPartition { worker_id, kind, a, b, n_target }
            }
            1 => Message::InitDone { worker_id: d.u32()?, x0: d.vec_f32()? },
            2 => Message::RunUpdate {
                epoch: d.u32()?,
                gamma: d.f32()?,
                xbar: d.vec_f32()?,
            },
            3 => Message::UpdateDone { worker_id: d.u32()?, x: d.vec_f32()? },
            4 => Message::RunGrad { epoch: d.u32()?, x: d.vec_f32()? },
            5 => Message::GradDone { worker_id: d.u32()?, grad: d.vec_f32()? },
            6 => Message::WorkerError {
                worker_id: d.u32()?,
                message: d.string()?,
            },
            7 => Message::Shutdown,
            other => {
                return Err(DapcError::Parse(format!("unknown tag {other}")))
            }
        };
        d.finish()?;
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn variants() -> Vec<Message> {
        vec![
            Message::InitPartition {
                worker_id: 3,
                kind: InitKindWire::Qr,
                a: Matrix::from_fn(4, 3, |i, j| (i * 3 + j) as f32 * 0.5),
                b: vec![1.0, -2.0, 3.0, 0.25],
                n_target: 3,
            },
            Message::InitPartition {
                worker_id: 1,
                kind: InitKindWire::GradOnly,
                a: Matrix::from_fn(2, 2, |i, j| (i + j) as f32),
                b: vec![1.0, 2.0],
                n_target: 2,
            },
            Message::InitDone { worker_id: 1, x0: vec![0.1, 0.2] },
            Message::RunUpdate { epoch: 9, gamma: 0.75, xbar: vec![5.0; 7] },
            Message::UpdateDone { worker_id: 0, x: vec![] },
            Message::RunGrad { epoch: 2, x: vec![1.0] },
            Message::GradDone { worker_id: 4, grad: vec![-1.5, 2.5] },
            Message::WorkerError {
                worker_id: 2,
                message: "qr failed: naïve".into(),
            },
            Message::Shutdown,
        ]
    }

    #[test]
    fn all_variants_roundtrip() {
        for m in variants() {
            let enc = m.encode();
            let dec = Message::decode(&enc).unwrap();
            assert_eq!(m, dec);
        }
    }

    #[test]
    fn encoded_len_is_exact() {
        for m in variants() {
            assert_eq!(m.encoded_len(), m.encode().len(), "{m:?}");
        }
    }

    #[test]
    fn encode_into_appends() {
        let m = Message::RunGrad { epoch: 2, x: vec![1.0] };
        let mut buf = vec![0xAA, 0xBB];
        m.encode_into(&mut buf);
        assert_eq!(&buf[..2], &[0xAA, 0xBB]);
        assert_eq!(Message::decode(&buf[2..]).unwrap(), m);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Message::decode(&[]).is_err());
        assert!(Message::decode(&[99]).is_err());
        // truncated InitDone
        let mut enc = Message::InitDone { worker_id: 1, x0: vec![1.0, 2.0] }.encode();
        enc.truncate(enc.len() - 2);
        assert!(Message::decode(&enc).is_err());
        // trailing bytes
        let mut enc2 = Message::Shutdown.encode();
        enc2.push(0);
        assert!(Message::decode(&enc2).is_err());
        // bad init kind
        let mut enc3 = Message::InitPartition {
            worker_id: 0,
            kind: InitKindWire::Qr,
            a: Matrix::zeros(1, 1),
            b: vec![0.0],
            n_target: 1,
        }
        .encode();
        enc3[5] = 9; // kind byte
        assert!(Message::decode(&enc3).is_err());
    }

    #[test]
    fn init_kind_conversion() {
        for k in [InitKind::Qr, InitKind::Classical, InitKind::Fat] {
            let w: InitKindWire = k.into();
            assert_eq!(w.engine_kind(), Some(k));
        }
        assert_eq!(InitKindWire::GradOnly.engine_kind(), None);
    }
}
