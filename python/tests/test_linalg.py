"""Pure-HLO linalg (kernels/linalg.py) vs LAPACK oracles.

These are the correctness gates for everything that ends up in an init
artifact: Householder QR, triangular solves, Gauss-Jordan inverse.
Hypothesis sweeps shapes; fixed-seed cases pin the numerics.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import linalg, ref

F32 = np.float32


def _rand(rng, *shape):
    return rng.normal(size=shape).astype(F32)


class TestHouseholderQR:
    @pytest.mark.parametrize("l,n", [(8, 8), (16, 8), (64, 32), (33, 7), (128, 128)])
    def test_reconstruction(self, rng, l, n):
        a = _rand(rng, l, n)
        q1, r = linalg.householder_qr(jnp.asarray(a))
        np.testing.assert_allclose(np.asarray(q1 @ r), a, atol=5e-5)

    @pytest.mark.parametrize("l,n", [(16, 8), (64, 32), (50, 50)])
    def test_orthonormal_columns(self, rng, l, n):
        a = _rand(rng, l, n)
        q1, _ = linalg.householder_qr(jnp.asarray(a))
        np.testing.assert_allclose(
            np.asarray(q1.T @ q1), np.eye(n), atol=5e-5
        )

    def test_r_upper_triangular(self, rng):
        a = _rand(rng, 40, 24)
        _, r = linalg.householder_qr(jnp.asarray(a))
        r = np.asarray(r)
        assert np.allclose(r, np.triu(r))

    def test_r_diagonal_matches_lapack_magnitude(self, rng):
        # R is unique up to column signs; |diag| must match LAPACK's.
        a = _rand(rng, 32, 16)
        _, r = linalg.householder_qr(jnp.asarray(a))
        _, r_ref = ref.qr_ref(a)
        np.testing.assert_allclose(
            np.abs(np.diag(np.asarray(r))), np.abs(np.diag(r_ref)), rtol=1e-4
        )

    def test_rank_deficient_column_no_nan(self):
        # A zero column must not produce NaNs (guarded reflector).
        a = np.zeros((10, 4), dtype=F32)
        a[:, 0] = 1.0
        a[:, 2] = np.arange(10)
        q1, r = linalg.householder_qr(jnp.asarray(a))
        assert np.isfinite(np.asarray(q1)).all()
        assert np.isfinite(np.asarray(r)).all()

    @settings(deadline=None, max_examples=25)
    @given(
        l=st.integers(min_value=2, max_value=48),
        n=st.integers(min_value=1, max_value=24),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_property_reconstruction_and_orthogonality(self, l, n, seed):
        if l < n:
            l = n  # tall or square only
        a = np.random.default_rng(seed).normal(size=(l, n)).astype(F32)
        q1, r = linalg.householder_qr(jnp.asarray(a))
        np.testing.assert_allclose(np.asarray(q1 @ r), a, atol=1e-3)
        np.testing.assert_allclose(
            np.asarray(q1.T @ q1), np.eye(n), atol=1e-3
        )


class TestTriangularSolves:
    @pytest.mark.parametrize("n", [1, 2, 8, 32, 100])
    def test_back_substitution(self, rng, n):
        # diagonally dominant => well-conditioned; the oracle comparison
        # then isolates algorithmic error from f32 conditioning blow-up
        r = np.triu(_rand(rng, n, n)) / np.sqrt(n) + 3.0 * np.eye(n, dtype=F32)
        r = r.astype(F32)
        c = _rand(rng, n)
        x = linalg.back_substitution(jnp.asarray(r), jnp.asarray(c))
        np.testing.assert_allclose(
            np.asarray(x), ref.back_substitution_ref(r, c), atol=1e-4
        )

    @pytest.mark.parametrize("n", [1, 2, 8, 32, 100])
    def test_forward_substitution(self, rng, n):
        lo = np.tril(_rand(rng, n, n)) / np.sqrt(n) + 3.0 * np.eye(n, dtype=F32)
        lo = lo.astype(F32)
        c = _rand(rng, n)
        x = linalg.forward_substitution(jnp.asarray(lo), jnp.asarray(c))
        np.testing.assert_allclose(
            np.asarray(x), ref.forward_substitution_ref(lo, c), atol=1e-4
        )

    @settings(deadline=None, max_examples=25)
    @given(
        n=st.integers(min_value=1, max_value=40),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_property_residual(self, n, seed):
        g = np.random.default_rng(seed)
        r = (np.triu(g.normal(size=(n, n))) / max(np.sqrt(n), 1.0)).astype(
            F32
        ) + 2.5 * np.eye(n, dtype=F32)
        c = g.normal(size=(n,)).astype(F32)
        x = np.asarray(linalg.back_substitution(jnp.asarray(r), jnp.asarray(c)))
        assert np.abs(r @ x - c).max() < 1e-2


class TestGaussJordanInverse:
    @pytest.mark.parametrize("n", [1, 2, 8, 32, 64])
    def test_inverse_spd(self, rng, n):
        a = _rand(rng, n + 4, n)
        g = a.T @ a + np.eye(n, dtype=F32)
        gi = linalg.gauss_jordan_inverse(jnp.asarray(g))
        np.testing.assert_allclose(
            np.asarray(gi) @ g, np.eye(n), atol=1e-3
        )

    def test_inverse_needs_pivoting(self):
        # Zero on the leading diagonal: fails without partial pivoting.
        a = np.array([[0.0, 1.0], [1.0, 0.0]], dtype=F32)
        ai = np.asarray(linalg.gauss_jordan_inverse(jnp.asarray(a)))
        np.testing.assert_allclose(ai, a, atol=1e-6)

    def test_matches_numpy(self, rng):
        a = _rand(rng, 16, 16) + 4.0 * np.eye(16, dtype=F32)
        gi = linalg.gauss_jordan_inverse(jnp.asarray(a))
        np.testing.assert_allclose(
            np.asarray(gi), ref.inverse_ref(a), rtol=1e-2, atol=1e-3
        )

    @settings(deadline=None, max_examples=20)
    @given(
        n=st.integers(min_value=1, max_value=24),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_property_left_right_inverse(self, n, seed):
        g = np.random.default_rng(seed)
        a = g.normal(size=(n, n)).astype(F32) + n * np.eye(n, dtype=F32)
        ai = np.asarray(linalg.gauss_jordan_inverse(jnp.asarray(a)))
        assert np.abs(ai @ a - np.eye(n)).max() < 5e-2
        assert np.abs(a @ ai - np.eye(n)).max() < 5e-2


class TestReflectorHelpers:
    def test_apply_reflectors_matches_qt(self, rng):
        # Q^T b computed via stored reflectors == Q1^T b for square A.
        n = 12
        a = _rand(rng, n, n)
        q1, r = linalg.householder_qr(jnp.asarray(a))
        b = _rand(rng, n)
        qtb = np.asarray(q1).T @ b
        x = linalg.back_substitution(r, jnp.asarray(qtb))
        np.testing.assert_allclose(a @ np.asarray(x), b, atol=1e-3)
