//! The distributed consensus backend and the leader facade.
//!
//! The epoch loop itself lives in [`crate::solver::driver`] — this module
//! only implements *where* the rounds execute: [`ClusterBackend`]
//! scatters each round over `Vec<Transport>` (pipelined: all J requests
//! go out before the first reply is awaited), gathers replies
//! out-of-order keyed on the embedded `worker_id` (a straggler in slot 0
//! no longer serializes reply processing), and streams the fixed-order
//! f64 accumulation the driver's eq. (7) mixing consumes.
//!
//! The leader owns only n-length vectors; all O(l n) / O(n^2) state stays
//! on the workers.  Per-worker estimate slots are reused across epochs,
//! so steady-state leader traffic causes no per-epoch memory growth.

use crate::error::{DapcError, Result};
use crate::partition::PartitionPlan;
use crate::solver::driver::{
    accumulate_sum, accumulate_sum_batch, ConsensusBackend, RoundOutcome,
};
use crate::solver::{
    drive_apc, drive_dgd, ApcVariant, InitKind, SessionBackend, SolveOptions,
    SolveReport,
};
use crate::sparse::CsrMatrix;

use super::message::{InitKindWire, Message};
use super::transport::Transport;

/// Fruitless polling passes over all pending workers before the gather
/// falls back to a blocking receive on the first straggler (avoids a
/// busy-wait on quiet TCP links while keeping the common case lock-step
/// free).
const GATHER_SPIN_PASSES: usize = 256;

/// Every reply slot must be claimed by a DISTINCT worker id: a duplicate
/// would silently clobber one slot and leave another holding the previous
/// epoch's stale estimate — wrong results with no error.
fn mark_seen(seen: &mut [bool], wid: usize) -> Result<()> {
    if wid >= seen.len() {
        return Err(DapcError::Coordinator(format!(
            "reply from unknown worker id {wid} (cluster has {})",
            seen.len()
        )));
    }
    if seen[wid] {
        return Err(DapcError::Coordinator(format!(
            "duplicate reply for worker id {wid}: two connections claim \
             the same worker (same address listed twice?)"
        )));
    }
    seen[wid] = true;
    Ok(())
}

/// Poll every pending worker, dispatching replies in ARRIVAL order; the
/// caller's `on_msg` keys state on the reply's own `worker_id` and
/// returns it so each id is verified to answer exactly once.  Falls back
/// to a blocking receive once nothing has arrived for a while.
fn gather<T, F>(
    workers: &mut [T],
    done: &mut Vec<bool>,
    seen: &mut Vec<bool>,
    mut on_msg: F,
) -> Result<()>
where
    T: Transport,
    F: FnMut(Message) -> Result<u32>,
{
    let j = workers.len();
    done.clear();
    done.resize(j, false);
    seen.clear();
    seen.resize(j, false);
    let mut remaining = j;
    let mut idle_passes = 0usize;
    while remaining > 0 {
        let mut progressed = false;
        for (i, w) in workers.iter_mut().enumerate() {
            if done[i] {
                continue;
            }
            if let Some(msg) = w.try_recv()? {
                let wid = on_msg(msg)?;
                mark_seen(seen, wid as usize)?;
                done[i] = true;
                remaining -= 1;
                progressed = true;
            }
        }
        if remaining == 0 {
            break;
        }
        if progressed {
            idle_passes = 0;
            continue;
        }
        idle_passes += 1;
        if idle_passes < GATHER_SPIN_PASSES {
            std::thread::yield_now();
            continue;
        }
        // nothing arriving: block on the first pending worker; whoever
        // finished meanwhile is drained by the next polling pass
        let i = done.iter().position(|d| !d).expect("remaining > 0");
        let msg = workers[i].recv()?;
        let wid = on_msg(msg)?;
        mark_seen(seen, wid as usize)?;
        done[i] = true;
        remaining -= 1;
        idle_passes = 0;
    }
    Ok(())
}

/// Validate a worker's batched session reply: exactly `k` columns, each
/// of width `n` — shared by every v3 gather so the error shape (and any
/// future tightening) lives once.
fn check_reply_columns(
    worker_id: u32,
    what: &str,
    cols: &[Vec<f32>],
    k: usize,
    n: usize,
) -> Result<()> {
    if cols.len() != k || cols.iter().any(|c| c.len() != n) {
        return Err(DapcError::Coordinator(format!(
            "worker {worker_id} returned {} {what} columns (lengths {:?}) \
             != {k} columns of n = {n}",
            cols.len(),
            cols.iter().map(Vec::len).collect::<Vec<_>>()
        )));
    }
    Ok(())
}

/// [`ConsensusBackend`] over J connected worker transports.
pub struct ClusterBackend<T: Transport> {
    workers: Vec<T>,
    /// Per-worker estimate slots, reused across epochs (the only
    /// per-worker state the leader holds).
    xs: Vec<Vec<f32>>,
    /// Per-worker per-column estimate slots for batched session solves
    /// (`batch_xs[worker][column]`), reused across epochs.
    batch_xs: Vec<Vec<Vec<f32>>>,
    /// Reused gather bookkeeping (per-transport completion, per-id
    /// uniqueness).
    done: Vec<bool>,
    seen: Vec<bool>,
    epoch: u32,
    n_target: usize,
}

impl<T: Transport> ClusterBackend<T> {
    /// Backend over the given worker connections; rejects an empty
    /// cluster up front (every later step would need `J >= 1`).
    pub fn new(workers: Vec<T>) -> Result<Self> {
        if workers.is_empty() {
            return Err(DapcError::Coordinator(
                "cluster needs at least one worker (got 0): there is no \
                 worker to hold a partition"
                    .into(),
            ));
        }
        let j = workers.len();
        Ok(Self {
            workers,
            xs: vec![Vec::new(); j],
            batch_xs: vec![Vec::new(); j],
            done: Vec::new(),
            seen: Vec::new(),
            epoch: 0,
            n_target: 0,
        })
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Total wire traffic so far as `(bytes_sent, bytes_received)`,
    /// summed over all worker links (framing included).
    pub fn wire_bytes(&self) -> (u64, u64) {
        self.workers.iter().fold((0, 0), |(s, r), w| {
            (s + w.bytes_sent(), r + w.bytes_received())
        })
    }

    /// Send shutdown to all workers (best-effort).
    pub fn shutdown(&mut self) {
        for w in self.workers.iter_mut() {
            let _ = w.send(&Message::Shutdown);
        }
    }

    /// Pipelined scatter of per-worker partition blocks.
    fn scatter_blocks(
        &mut self,
        kind: InitKindWire,
        plan: &PartitionPlan,
        a: &CsrMatrix,
        b: &[f32],
    ) -> Result<()> {
        for (i, w) in self.workers.iter_mut().enumerate() {
            let (sub, rhs) = plan.extract(a, b, i);
            w.send(&Message::InitPartition {
                worker_id: i as u32,
                kind,
                a: sub,
                b: rhs,
                n_target: plan.n as u32,
            })?;
        }
        Ok(())
    }

    /// Session registration: scatter `RegisterMatrix` blocks (workers
    /// factorize once and keep the state) and gather the acks.
    fn register_wire(
        &mut self,
        kind: InitKindWire,
        plan: &PartitionPlan,
        a: &CsrMatrix,
    ) -> Result<()> {
        self.n_target = plan.n;
        for (i, w) in self.workers.iter_mut().enumerate() {
            let blk = plan.blocks[i];
            let sub = a.slice_rows_dense(blk.start, blk.end);
            w.send(&Message::RegisterMatrix {
                worker_id: i as u32,
                kind,
                a: sub,
                n_target: plan.n as u32,
            })?;
        }
        gather(&mut self.workers, &mut self.done, &mut self.seen, |msg| {
            match msg {
                Message::MatrixRegistered { worker_id } => Ok(worker_id),
                Message::WorkerError { worker_id, message } => {
                    Err(DapcError::Coordinator(format!(
                        "worker {worker_id} registration failed: {message}"
                    )))
                }
                other => Err(DapcError::Coordinator(format!(
                    "unexpected reply {other:?}"
                ))),
            }
        })
    }

    /// Pipelined scatter of per-worker rhs column slices: one
    /// `SolveRhs` frame for a single rhs, one `SolveBatch` for k > 1.
    fn scatter_rhs(
        &mut self,
        plan: &PartitionPlan,
        bs: &[&[f32]],
    ) -> Result<()> {
        let m = plan.blocks.last().map(|b| b.end).unwrap_or(0);
        for b in bs {
            if b.len() != m {
                return Err(DapcError::Shape(format!(
                    "rhs length {} != matrix rows {m}",
                    b.len()
                )));
            }
        }
        for (i, w) in self.workers.iter_mut().enumerate() {
            let blk = plan.blocks[i];
            if let [b] = bs {
                w.send(&Message::SolveRhs {
                    b: b[blk.start..blk.end].to_vec(),
                })?;
            } else {
                let cols: Vec<Vec<f32>> = bs
                    .iter()
                    .map(|b| b[blk.start..blk.end].to_vec())
                    .collect();
                w.send(&Message::SolveBatch { bs: cols })?;
            }
        }
        Ok(())
    }
}

impl<T: Transport> ConsensusBackend for ClusterBackend<T> {
    fn partitions(&self) -> usize {
        self.workers.len()
    }

    fn init_partitions(
        &mut self,
        kind: InitKind,
        plan: &PartitionPlan,
        a: &CsrMatrix,
        b: &[f32],
        acc: &mut Vec<f64>,
    ) -> Result<usize> {
        let n = plan.n;
        self.n_target = n;
        self.scatter_blocks(kind.into(), plan, a, b)?;
        let xs = &mut self.xs;
        gather(&mut self.workers, &mut self.done, &mut self.seen, |msg| {
            match msg {
                Message::InitDone { worker_id, x0 } => {
                    let slot =
                        xs.get_mut(worker_id as usize).ok_or_else(|| {
                            DapcError::Coordinator(format!(
                                "InitDone from unknown worker {worker_id}"
                            ))
                        })?;
                    if x0.len() != n {
                        return Err(DapcError::Coordinator(format!(
                            "worker {worker_id} returned x0 of length {} \
                             != n = {n}",
                            x0.len()
                        )));
                    }
                    *slot = x0;
                    Ok(worker_id)
                }
                Message::WorkerError { worker_id, message } => {
                    Err(DapcError::Coordinator(format!(
                        "worker {worker_id} init failed: {message}"
                    )))
                }
                other => Err(DapcError::Coordinator(format!(
                    "unexpected reply {other:?}"
                ))),
            }
        })?;
        acc.clear();
        acc.resize(n, 0.0);
        accumulate_sum(&self.xs, acc);
        Ok(n)
    }

    fn run_round(
        &mut self,
        gamma: f32,
        _eta: f32,
        xbar: &mut [f32],
        acc: &mut [f64],
    ) -> Result<RoundOutcome> {
        let msg = Message::RunUpdate {
            epoch: self.epoch,
            gamma,
            xbar: xbar.to_vec(),
        };
        self.epoch = self.epoch.wrapping_add(1);
        // pipelined scatter: workers compute eq. (6) concurrently
        for w in self.workers.iter_mut() {
            w.send(&msg)?;
        }
        let n = self.n_target;
        let xs = &mut self.xs;
        gather(&mut self.workers, &mut self.done, &mut self.seen, |msg| {
            match msg {
                Message::UpdateDone { worker_id, x } => {
                    let slot =
                        xs.get_mut(worker_id as usize).ok_or_else(|| {
                            DapcError::Coordinator(format!(
                                "UpdateDone from unknown worker {worker_id}"
                            ))
                        })?;
                    if x.len() != n {
                        return Err(DapcError::Coordinator(format!(
                            "worker {worker_id} returned estimate of \
                             length {} != n = {n}",
                            x.len()
                        )));
                    }
                    *slot = x;
                    Ok(worker_id)
                }
                Message::WorkerError { worker_id, message } => {
                    Err(DapcError::Coordinator(format!(
                        "worker {worker_id} update failed: {message}"
                    )))
                }
                other => Err(DapcError::Coordinator(format!(
                    "unexpected reply {other:?}"
                ))),
            }
        })?;
        // fixed-order f64 reduction; the driver applies eq. (7)
        accumulate_sum(&self.xs, acc);
        Ok(RoundOutcome::Accumulated)
    }

    fn init_grad(
        &mut self,
        plan: &PartitionPlan,
        a: &CsrMatrix,
        b: &[f32],
    ) -> Result<()> {
        self.n_target = plan.n;
        // GradOnly: workers store their block and skip the (for DGD
        // useless) O(l n^2) factorization entirely
        self.scatter_blocks(InitKindWire::GradOnly, plan, a, b)?;
        gather(&mut self.workers, &mut self.done, &mut self.seen, |msg| {
            match msg {
                Message::InitDone { worker_id, .. } => Ok(worker_id),
                Message::WorkerError { worker_id, message } => {
                    Err(DapcError::Coordinator(format!(
                        "worker {worker_id} init failed: {message}"
                    )))
                }
                other => Err(DapcError::Coordinator(format!(
                    "unexpected reply {other:?}"
                ))),
            }
        })
    }

    fn grad_round(&mut self, x: &[f32], acc: &mut [f64]) -> Result<()> {
        let msg = Message::RunGrad { epoch: self.epoch, x: x.to_vec() };
        self.epoch = self.epoch.wrapping_add(1);
        for w in self.workers.iter_mut() {
            w.send(&msg)?;
        }
        let n = self.n_target;
        let xs = &mut self.xs;
        gather(&mut self.workers, &mut self.done, &mut self.seen, |msg| {
            match msg {
                Message::GradDone { worker_id, grad } => {
                    let slot =
                        xs.get_mut(worker_id as usize).ok_or_else(|| {
                            DapcError::Coordinator(format!(
                                "GradDone from unknown worker {worker_id}"
                            ))
                        })?;
                    if grad.len() != n {
                        return Err(DapcError::Coordinator(format!(
                            "worker {worker_id} returned gradient of \
                             length {} != n = {n}",
                            grad.len()
                        )));
                    }
                    *slot = grad;
                    Ok(worker_id)
                }
                Message::WorkerError { worker_id, message } => {
                    Err(DapcError::Coordinator(format!(
                        "worker {worker_id} grad failed: {message}"
                    )))
                }
                other => Err(DapcError::Coordinator(format!(
                    "unexpected reply {other:?}"
                ))),
            }
        })?;
        accumulate_sum(&self.xs, acc);
        Ok(())
    }

    fn x_parts(&mut self) -> Result<Vec<Vec<f32>>> {
        Ok(self.xs.clone())
    }

    fn backend_name(&self) -> &'static str {
        "distributed"
    }
}

impl<T: Transport> SessionBackend for ClusterBackend<T> {
    fn register_matrix(
        &mut self,
        kind: InitKind,
        plan: &PartitionPlan,
        a: &CsrMatrix,
    ) -> Result<usize> {
        self.register_wire(kind.into(), plan, a)?;
        Ok(plan.n)
    }

    fn register_grad(
        &mut self,
        plan: &PartitionPlan,
        a: &CsrMatrix,
    ) -> Result<()> {
        self.register_wire(InitKindWire::GradOnly, plan, a)
    }

    fn seed_rhs(
        &mut self,
        plan: &PartitionPlan,
        bs: &[&[f32]],
        accs: &mut [Vec<f64>],
    ) -> Result<()> {
        let n = self.n_target;
        let k = bs.len();
        self.scatter_rhs(plan, bs)?;
        let xs = &mut self.batch_xs;
        gather(&mut self.workers, &mut self.done, &mut self.seen, |msg| {
            match msg {
                Message::RhsSeeded { worker_id, x0s } => {
                    let slot =
                        xs.get_mut(worker_id as usize).ok_or_else(|| {
                            DapcError::Coordinator(format!(
                                "RhsSeeded from unknown worker {worker_id}"
                            ))
                        })?;
                    check_reply_columns(worker_id, "seeded", &x0s, k, n)?;
                    *slot = x0s;
                    Ok(worker_id)
                }
                Message::WorkerError { worker_id, message } => {
                    Err(DapcError::Coordinator(format!(
                        "worker {worker_id} seed failed: {message}"
                    )))
                }
                other => Err(DapcError::Coordinator(format!(
                    "unexpected reply {other:?}"
                ))),
            }
        })?;
        for acc in accs.iter_mut() {
            acc.clear();
            acc.resize(n, 0.0);
        }
        accumulate_sum_batch(&self.batch_xs, accs);
        Ok(())
    }

    fn seed_grad_rhs(
        &mut self,
        plan: &PartitionPlan,
        bs: &[&[f32]],
    ) -> Result<()> {
        let k = bs.len();
        self.scatter_rhs(plan, bs)?;
        gather(&mut self.workers, &mut self.done, &mut self.seen, |msg| {
            match msg {
                Message::RhsSeeded { worker_id, x0s } => {
                    // gradient-only sessions return k empty columns
                    if x0s.len() != k {
                        return Err(DapcError::Coordinator(format!(
                            "worker {worker_id} acknowledged {} rhs \
                             columns, expected {k}",
                            x0s.len()
                        )));
                    }
                    Ok(worker_id)
                }
                Message::WorkerError { worker_id, message } => {
                    Err(DapcError::Coordinator(format!(
                        "worker {worker_id} seed failed: {message}"
                    )))
                }
                other => Err(DapcError::Coordinator(format!(
                    "unexpected reply {other:?}"
                ))),
            }
        })
    }

    fn run_round_batch(
        &mut self,
        gamma: f32,
        _eta: f32,
        xbars: &mut [Vec<f32>],
        accs: &mut [Vec<f64>],
    ) -> Result<RoundOutcome> {
        let msg = Message::RunUpdateBatch {
            epoch: self.epoch,
            gamma,
            xbars: xbars.to_vec(),
        };
        self.epoch = self.epoch.wrapping_add(1);
        for w in self.workers.iter_mut() {
            w.send(&msg)?;
        }
        let n = self.n_target;
        let k = xbars.len();
        let xs = &mut self.batch_xs;
        gather(&mut self.workers, &mut self.done, &mut self.seen, |msg| {
            match msg {
                Message::UpdateBatchDone { worker_id, xs: cols } => {
                    let slot =
                        xs.get_mut(worker_id as usize).ok_or_else(|| {
                            DapcError::Coordinator(format!(
                                "UpdateBatchDone from unknown worker \
                                 {worker_id}"
                            ))
                        })?;
                    check_reply_columns(worker_id, "estimate", &cols, k, n)?;
                    *slot = cols;
                    Ok(worker_id)
                }
                Message::WorkerError { worker_id, message } => {
                    Err(DapcError::Coordinator(format!(
                        "worker {worker_id} batched update failed: \
                         {message}"
                    )))
                }
                other => Err(DapcError::Coordinator(format!(
                    "unexpected reply {other:?}"
                ))),
            }
        })?;
        // fixed-order f64 reduction per column; the driver mixes eq. (7)
        accumulate_sum_batch(&self.batch_xs, accs);
        Ok(RoundOutcome::Accumulated)
    }

    fn grad_round_batch(
        &mut self,
        xs_cols: &[Vec<f32>],
        accs: &mut [Vec<f64>],
    ) -> Result<()> {
        let msg = Message::RunGradBatch {
            epoch: self.epoch,
            xs: xs_cols.to_vec(),
        };
        self.epoch = self.epoch.wrapping_add(1);
        for w in self.workers.iter_mut() {
            w.send(&msg)?;
        }
        let n = self.n_target;
        let k = xs_cols.len();
        let xs = &mut self.batch_xs;
        gather(&mut self.workers, &mut self.done, &mut self.seen, |msg| {
            match msg {
                Message::GradBatchDone { worker_id, grads } => {
                    let slot =
                        xs.get_mut(worker_id as usize).ok_or_else(|| {
                            DapcError::Coordinator(format!(
                                "GradBatchDone from unknown worker \
                                 {worker_id}"
                            ))
                        })?;
                    check_reply_columns(worker_id, "gradient", &grads, k, n)?;
                    *slot = grads;
                    Ok(worker_id)
                }
                Message::WorkerError { worker_id, message } => {
                    Err(DapcError::Coordinator(format!(
                        "worker {worker_id} batched gradient failed: \
                         {message}"
                    )))
                }
                other => Err(DapcError::Coordinator(format!(
                    "unexpected reply {other:?}"
                ))),
            }
        })?;
        accumulate_sum_batch(&self.batch_xs, accs);
        Ok(())
    }
}

/// Leader over J connected workers — an ergonomic facade that runs the
/// shared driver over a [`ClusterBackend`].
pub struct Leader<T: Transport> {
    backend: ClusterBackend<T>,
}

impl<T: Transport> Leader<T> {
    /// Leader over the given worker connections (`J >= 1`).
    pub fn new(workers: Vec<T>) -> Result<Self> {
        Ok(Self { backend: ClusterBackend::new(workers)? })
    }

    pub fn worker_count(&self) -> usize {
        self.backend.worker_count()
    }

    /// The underlying backend, for driving
    /// [`crate::solver::drive_apc`]/[`crate::solver::drive_dgd`] directly.
    pub fn backend_mut(&mut self) -> &mut ClusterBackend<T> {
        &mut self.backend
    }

    /// Total `(sent, received)` wire bytes across all worker links.
    pub fn wire_bytes(&self) -> (u64, u64) {
        self.backend.wire_bytes()
    }

    /// Run the APC consensus algorithm distributed over the workers.
    pub fn solve_apc(
        &mut self,
        a: &CsrMatrix,
        b: &[f32],
        variant: ApcVariant,
        opts: &SolveOptions,
    ) -> Result<SolveReport> {
        drive_apc(&mut self.backend, a, b, variant, opts)
    }

    /// Distributed gradient descent over the same workers (step size
    /// from [`SolveOptions::dgd_step`]; `<= 0` selects the automatic
    /// Gershgorin bound).
    pub fn solve_dgd(
        &mut self,
        a: &CsrMatrix,
        b: &[f32],
        opts: &SolveOptions,
    ) -> Result<SolveReport> {
        drive_dgd(&mut self.backend, a, b, opts)
    }

    /// Send shutdown to all workers (best-effort).
    pub fn shutdown(&mut self) {
        self.backend.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::transport::{channel_pair, ChannelTransport};
    use crate::linalg::Matrix;

    #[test]
    fn duplicate_worker_ids_rejected() {
        // two connections claiming the same worker id would silently
        // leave one slot stale; the gather must refuse instead
        let (l0, mut w0) = channel_pair();
        let (l1, mut w1) = channel_pair();
        let n = 4;
        w0.send(&Message::InitDone { worker_id: 0, x0: vec![0.0; n] })
            .unwrap();
        w1.send(&Message::InitDone { worker_id: 0, x0: vec![0.0; n] })
            .unwrap();

        let mut backend = ClusterBackend::new(vec![l0, l1]).unwrap();
        let a = CsrMatrix::from_dense(&Matrix::from_fn(8, n, |i, j| {
            (i + j) as f32 + 1.0
        }));
        let b = vec![1.0f32; 8];
        let plan = PartitionPlan::contiguous(8, n, 2).unwrap();
        let mut acc = Vec::new();
        let err = backend
            .init_partitions(InitKind::Qr, &plan, &a, &b, &mut acc)
            .unwrap_err();
        assert!(
            err.to_string().contains("duplicate reply"),
            "unexpected error: {err}"
        );
        drop((w0, w1));
    }

    #[test]
    fn zero_worker_cluster_rejected_with_coordinator_error() {
        // used to panic deep inside the solve (`xs[0]` on an empty vec);
        // now both entry points refuse up front with a clear message
        for result in [
            ClusterBackend::<ChannelTransport>::new(vec![]).map(|_| ()),
            Leader::<ChannelTransport>::new(vec![]).map(|_| ()),
        ] {
            match result {
                Err(DapcError::Coordinator(msg)) => {
                    assert!(msg.contains("at least one worker"), "{msg}")
                }
                other => panic!("expected Coordinator error, got {other:?}"),
            }
        }
    }
}
