//! Solve options and the report returned by every solver.

use std::time::Duration;

use crate::linalg::norms;
use crate::linalg::simd::KernelTier;
use crate::metrics::ConvergenceTrace;
use crate::sparse::CsrMatrix;

/// `||A x - b||_2` through the allocation-free CSR
/// [`CsrMatrix::spmv_into`] path (one scratch vector, reused internally).
///
/// A NaN anywhere in `x` or `b` propagates into the returned residual
/// (and from there into [`SolveReport::summary`]): a poisoned iterate
/// must surface as `residual=NaN`, never as a small number.
pub fn residual_norm(a: &CsrMatrix, b: &[f32], x: &[f32]) -> f64 {
    let mut ax = vec![0.0f32; a.rows()];
    a.spmv_into(x, &mut ax);
    ax.iter()
        .zip(b)
        .map(|(axi, bi)| {
            let d = (*axi as f64) - (*bi as f64);
            d * d
        })
        // audit:allow(fixed-order-reduce): convergence reporting — the
        // residual norm is displayed/thresholded, not part of the iterate
        .sum::<f64>()
        .sqrt()
}

/// Hyper-parameters and run controls shared by all solvers.
#[derive(Debug, Clone)]
pub struct SolveOptions {
    /// Consensus epochs T (or gradient steps for DGD).
    pub epochs: usize,
    /// Eq. (7) mixing weight.
    pub eta: f32,
    /// Eq. (6) projection step.
    pub gamma: f32,
    /// DGD step size.
    pub dgd_step: f32,
    /// Record a per-epoch MSE trace against `x_true` (Fig. 2); requires
    /// `x_true`.
    pub x_true: Option<Vec<f32>>,
    /// Try the engine's whole-loop fused path (single executable for all
    /// T epochs). Ignored when a trace is requested.
    pub fused_loop: bool,
    /// Copy the per-partition final estimates into
    /// [`SolveReport::x_parts`].  Off by default: the driver then never
    /// retains J extra n-vectors on the leader.
    pub collect_x_parts: bool,
    /// Per-solve f32 kernel-tier override for the in-process native
    /// engines (`None` = the process default read from
    /// `DAPC_KERNEL_TIER`).  Consumed at engine construction; see the
    /// two-tier contract in `linalg::simd`.
    pub kernel_tier: Option<KernelTier>,
}

impl Default for SolveOptions {
    fn default() -> Self {
        Self {
            epochs: 80,
            eta: 0.9,
            gamma: 0.9,
            dgd_step: 1e-3,
            x_true: None,
            fused_loop: false,
            collect_x_parts: false,
            kernel_tier: None,
        }
    }
}

/// Result of a solver run.
#[derive(Debug, Clone)]
pub struct SolveReport {
    /// Averaged solution vector (paper's output, eq. (7) at epoch T).
    pub xbar: Vec<f32>,
    /// Per-partition final estimates; empty unless
    /// [`SolveOptions::collect_x_parts`] was set.
    pub x_parts: Vec<Vec<f32>>,
    /// MSE-per-epoch trace when `x_true` was provided.
    pub trace: Option<ConvergenceTrace>,
    /// Final residual `||A xbar - b||_2` when the solver computed it.
    pub residual: Option<f64>,
    /// Initialization wall time (QR / inversion phase).
    pub init_time: Duration,
    /// Consensus-iteration wall time.
    pub iterate_time: Duration,
    /// Algorithm label.
    pub algorithm: &'static str,
    /// Engine label.
    pub engine: &'static str,
    /// Epochs actually run.
    pub epochs: usize,
}

impl SolveReport {
    /// Total solver wall time.
    pub fn total_time(&self) -> Duration {
        self.init_time + self.iterate_time
    }

    /// MSE of the averaged solution against a reference.
    pub fn final_mse(&self, x_true: &[f32]) -> f64 {
        norms::mse(&self.xbar, x_true)
    }

    /// MAE between two successive solutions (paper §5 sanity check).
    pub fn mae_against(&self, other: &[f32]) -> f64 {
        norms::mae(&self.xbar, other)
    }

    /// One summary line for logs.
    pub fn summary(&self) -> String {
        let residual = match self.residual {
            Some(r) => format!(" residual={r:.3e}"),
            None => String::new(),
        };
        format!(
            "{} [{}] epochs={} init={:.3}s iterate={:.3}s total={:.3}s{}",
            self.algorithm,
            self.engine,
            self.epochs,
            self.init_time.as_secs_f64(),
            self.iterate_time.as_secs_f64(),
            self.total_time().as_secs_f64(),
            residual,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let o = SolveOptions::default();
        assert!(o.eta > 0.0 && o.eta <= 1.0);
        assert!(o.gamma > 0.0 && o.gamma <= 1.0);
        assert!(o.epochs > 0);
    }

    #[test]
    fn report_accessors() {
        let r = SolveReport {
            xbar: vec![1.0, 1.0],
            x_parts: vec![],
            trace: None,
            residual: None,
            init_time: Duration::from_millis(500),
            iterate_time: Duration::from_millis(1500),
            algorithm: "dapc-decomposed",
            engine: "native",
            epochs: 10,
        };
        assert_eq!(r.total_time(), Duration::from_secs(2));
        assert!((r.final_mse(&[0.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!((r.mae_against(&[0.0, 2.0]) - 1.0).abs() < 1e-12);
        assert!(r.summary().contains("dapc-decomposed"));
        assert!(!r.summary().contains("residual"));
        let with_res = SolveReport { residual: Some(1e-5), ..r };
        assert!(with_res.summary().contains("residual=1.000e-5"));
    }

    #[test]
    fn residual_norm_zero_at_solution() {
        use crate::linalg::Matrix;
        // A = [[2, 0], [0, 3], [1, 1]], x = [1, 2] => b = [2, 6, 3]
        let a = CsrMatrix::from_dense(&Matrix::from_vec(
            3,
            2,
            vec![2.0, 0.0, 0.0, 3.0, 1.0, 1.0],
        ));
        let x = [1.0f32, 2.0];
        let b = [2.0f32, 6.0, 3.0];
        assert!(residual_norm(&a, &b, &x) < 1e-12);
        // off-by-one in the last component => residual exactly 1
        let b_off = [2.0f32, 6.0, 4.0];
        assert!((residual_norm(&a, &b_off, &x) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn residual_norm_propagates_nan_iterates() {
        use crate::linalg::Matrix;
        let a = CsrMatrix::from_dense(&Matrix::from_vec(
            2,
            2,
            vec![1.0, 0.0, 0.0, 1.0],
        ));
        let b = [1.0f32, 1.0];
        // one poisoned entry or a fully poisoned iterate: NaN out
        assert!(residual_norm(&a, &b, &[f32::NAN, 1.0]).is_nan());
        assert!(residual_norm(&a, &b, &[f32::NAN, f32::NAN]).is_nan());
    }

    #[test]
    fn summary_surfaces_nan_residual() {
        let r = SolveReport {
            xbar: vec![f32::NAN, f32::NAN],
            x_parts: vec![],
            trace: None,
            residual: Some(f64::NAN),
            init_time: Duration::from_millis(1),
            iterate_time: Duration::from_millis(1),
            algorithm: "dapc-decomposed",
            engine: "native",
            epochs: 1,
        };
        // the poisoned solve must be visible in the one-line summary
        assert!(r.summary().contains("residual=NaN"), "{}", r.summary());
    }
}
