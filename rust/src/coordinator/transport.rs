//! Message transports: in-process channels (threaded local cluster) and
//! length-framed TCP streams (multi-process cluster), behind one trait so
//! the leader/worker code is transport-agnostic.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc;

use crate::error::{DapcError, Result};

use super::message::Message;

/// Bidirectional message endpoint.
pub trait Transport: Send {
    fn send(&mut self, msg: &Message) -> Result<()>;
    fn recv(&mut self) -> Result<Message>;
}

// --- in-process -------------------------------------------------------------

/// One side of an in-process duplex channel.
pub struct ChannelTransport {
    tx: mpsc::Sender<Message>,
    rx: mpsc::Receiver<Message>,
}

/// Create a connected pair (leader side, worker side).
pub fn channel_pair() -> (ChannelTransport, ChannelTransport) {
    let (tx_a, rx_b) = mpsc::channel();
    let (tx_b, rx_a) = mpsc::channel();
    (
        ChannelTransport { tx: tx_a, rx: rx_a },
        ChannelTransport { tx: tx_b, rx: rx_b },
    )
}

impl Transport for ChannelTransport {
    fn send(&mut self, msg: &Message) -> Result<()> {
        self.tx
            .send(msg.clone())
            .map_err(|_| DapcError::Coordinator("peer hung up".into()))
    }

    fn recv(&mut self) -> Result<Message> {
        self.rx
            .recv()
            .map_err(|_| DapcError::Coordinator("peer hung up".into()))
    }
}

// --- TCP --------------------------------------------------------------------

/// Length-framed messages over a TCP stream (`u32 LE length | payload`).
pub struct TcpTransport {
    stream: TcpStream,
}

impl TcpTransport {
    pub fn new(stream: TcpStream) -> Result<Self> {
        stream
            .set_nodelay(true)
            .map_err(|e| DapcError::Coordinator(e.to_string()))?;
        Ok(Self { stream })
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, msg: &Message) -> Result<()> {
        let payload = msg.encode();
        let len = (payload.len() as u32).to_le_bytes();
        self.stream.write_all(&len)?;
        self.stream.write_all(&payload)?;
        self.stream.flush()?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Message> {
        let mut len_buf = [0u8; 4];
        self.stream.read_exact(&mut len_buf)?;
        let len = u32::from_le_bytes(len_buf) as usize;
        // guard against absurd frames (corrupted stream)
        if len > 1 << 30 {
            return Err(DapcError::Coordinator(format!(
                "frame length {len} exceeds 1 GiB sanity limit"
            )));
        }
        let mut payload = vec![0u8; len];
        self.stream.read_exact(&mut payload)?;
        Message::decode(&payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn channel_pair_duplex() {
        let (mut a, mut b) = channel_pair();
        a.send(&Message::Shutdown).unwrap();
        assert_eq!(b.recv().unwrap(), Message::Shutdown);
        b.send(&Message::InitDone { worker_id: 1, x0: vec![1.0] }).unwrap();
        assert_eq!(
            a.recv().unwrap(),
            Message::InitDone { worker_id: 1, x0: vec![1.0] }
        );
    }

    #[test]
    fn channel_detects_hangup() {
        let (mut a, b) = channel_pair();
        drop(b);
        assert!(a.send(&Message::Shutdown).is_err());
        assert!(a.recv().is_err());
    }

    #[test]
    fn tcp_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            let msg = t.recv().unwrap();
            t.send(&msg).unwrap(); // echo
        });
        let mut client =
            TcpTransport::new(TcpStream::connect(addr).unwrap()).unwrap();
        let msg = Message::RunUpdate {
            epoch: 5,
            gamma: 0.5,
            xbar: (0..100).map(|i| i as f32).collect(),
        };
        client.send(&msg).unwrap();
        assert_eq!(client.recv().unwrap(), msg);
        server.join().unwrap();
    }

    #[test]
    fn tcp_detects_closed_stream() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            drop(stream); // close immediately
        });
        let mut client =
            TcpTransport::new(TcpStream::connect(addr).unwrap()).unwrap();
        server.join().unwrap();
        assert!(client.recv().is_err());
    }
}
