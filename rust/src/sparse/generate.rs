//! Synthetic Schenk_IBMNA-like dataset generator.
//!
//! The paper evaluates on SuiteSparse `c-*` matrices (n x n, ~99.85%
//! sparse, heavy diagonal) *augmented* with rows that are linear
//! combinations of the base system (paper §4, eq. (8)) so the
//! overdetermined system stays consistent with a known solution `x`.
//! SuiteSparse is unreachable in this environment, so this module builds
//! the closest synthetic equivalent (DESIGN.md §2):
//!
//! 1. base `A0` (n x n): nonzero diagonal + a few off-diagonal normal
//!    entries per row — full rank by diagonal dominance, sparsity matched
//!    to the paper's ~99.85%;
//! 2. known `x_true ~ N(0, 1)`, `b0 = A0 x_true`;
//! 3. augmented rows `D_A = C A0`, `D_b = C b0` where each row of `C`
//!    mixes its own cyclic pivot row (coefficient ~1) with `combo_k`
//!    random rows — guaranteeing every contiguous block of >= n rows has
//!    full column rank (required by Algorithm 1's partition assumption).

use crate::error::{DapcError, Result};
use crate::linalg::norms;
use crate::rng::{seeded, Xoshiro256};

use super::{CooMatrix, CsrMatrix};

/// A generated consistent overdetermined system with known solution.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// `[A0; D_A]`, shape (m_total x n).
    pub matrix: CsrMatrix,
    /// `[b0; D_b]`, length m_total.
    pub rhs: Vec<f32>,
    /// The exact solution the system was built from.
    pub x_true: Vec<f32>,
    /// Rows of the square base system.
    pub base_n: usize,
}

impl Dataset {
    /// Residual `max |A x - b|` at the true solution (sanity metric).
    pub fn residual_at_truth(&self) -> f32 {
        let mut ax = vec![0.0f32; self.matrix.rows()];
        self.matrix.spmv(&self.x_true, &mut ax);
        ax.iter()
            .zip(&self.rhs)
            .map(|(a, b)| (a - b).abs())
            // audit:allow(fixed-order-reduce): max is order-insensitive
            // (NaN-free by construction); diagnostic output only
            .fold(0.0f32, f32::max)
    }

    /// MSE of an estimate against the known solution.
    pub fn mse(&self, x: &[f32]) -> f64 {
        norms::mse(x, &self.x_true)
    }
}

/// Configuration for the synthetic generator.
#[derive(Debug, Clone)]
pub struct GeneratorConfig {
    /// Solution dimension (columns of A).
    pub n: usize,
    /// Total rows of the augmented system `[A0; D_A]` (>= n).
    pub m_total: usize,
    /// Off-diagonal nonzeros per base row (paper's c-27 has ~7/row at
    /// 99.85% sparsity).
    pub offdiag_per_row: usize,
    /// Std-dev of off-diagonal values (c-27: sigma ~ 24.31).
    pub value_sigma: f32,
    /// Diagonal magnitude floor keeping A0 full-rank.
    pub diag_min: f32,
    /// How many base rows each augmented row mixes in (beyond its pivot).
    pub combo_k: usize,
}

impl GeneratorConfig {
    /// Paper-like preset: m = 4n, ~7 off-diagonal nnz/row, sigma 24.31.
    pub fn schenk_like(n: usize) -> Self {
        Self {
            n,
            m_total: 4 * n,
            offdiag_per_row: 6,
            value_sigma: 24.31,
            diag_min: 1.0,
            combo_k: 4,
        }
    }

    /// Small well-conditioned preset for tests/examples: J partitions of
    /// roughly 2n/J extra rows each.
    pub fn small_demo(n: usize, j: usize) -> Self {
        Self {
            n,
            m_total: (j.max(1) + 1) * n,
            offdiag_per_row: 4.min(n.saturating_sub(1)),
            value_sigma: 1.0,
            diag_min: 2.0,
            combo_k: 3,
        }
    }

    /// Exact paper Table-1 shape (m x n already includes augmentation).
    pub fn table1(m: usize, n: usize) -> Self {
        Self {
            n,
            m_total: m,
            offdiag_per_row: 6,
            value_sigma: 24.31,
            diag_min: 1.0,
            combo_k: 4,
        }
    }

    fn validate(&self) -> Result<()> {
        if self.n == 0 {
            return Err(DapcError::Config("n must be positive".into()));
        }
        if self.m_total < self.n {
            return Err(DapcError::Config(format!(
                "m_total {} < n {} (system must be square or overdetermined)",
                self.m_total, self.n
            )));
        }
        if self.offdiag_per_row >= self.n && self.n > 1 {
            return Err(DapcError::Config(
                "offdiag_per_row must be < n".into(),
            ));
        }
        Ok(())
    }

    /// Generate the dataset with a deterministic seed.
    pub fn generate(&self, seed: u64) -> Dataset {
        self.try_generate(seed).expect("invalid GeneratorConfig")
    }

    /// Fallible generation (validates the config).
    pub fn try_generate(&self, seed: u64) -> Result<Dataset> {
        self.validate()?;
        let n = self.n;
        let mut g = seeded(seed);

        // 1. base square system
        let base = self.base_matrix(&mut g);
        let x_true: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
        let mut b0 = vec![0.0f32; n];
        base.spmv(&x_true, &mut b0);

        // 2. augmentation rows D_A = C A0 (sparse combos of base rows)
        let m_extra = self.m_total - n;
        let mut coo = CooMatrix::new(m_extra, n);
        let mut d_b = vec![0.0f32; m_extra];
        // dense scratch for one combined row
        let mut rowbuf = vec![0.0f32; n];
        for i in 0..m_extra {
            rowbuf.fill(0.0);
            let mut bsum = 0.0f64;
            // pivot row keeps every contiguous >= n row block full-rank
            let pivot = i % n;
            let add_row = |r: usize, w: f32, rowbuf: &mut [f32], bsum: &mut f64| {
                let (idx, vals) = base.row(r);
                for (&j, &v) in idx.iter().zip(vals) {
                    rowbuf[j] += w * v;
                }
                *bsum += w as f64 * b0[r] as f64;
            };
            let wp = 1.0 + 0.25 * g.normal_f32().abs();
            add_row(pivot, wp, &mut rowbuf, &mut bsum);
            for _ in 0..self.combo_k {
                let r = g.gen_range(0, n);
                let w = 0.5 * g.normal_f32();
                add_row(r, w, &mut rowbuf, &mut bsum);
            }
            for (j, &v) in rowbuf.iter().enumerate() {
                if v != 0.0 {
                    coo.push(i, j, v)?;
                }
            }
            d_b[i] = bsum as f32;
        }
        let d_a = coo.to_csr();

        // 3. assemble [A0; D_A], [b0; D_b]
        let matrix = base.vstack(&d_a)?;
        let mut rhs = b0;
        rhs.extend_from_slice(&d_b);
        Ok(Dataset { matrix, rhs, x_true, base_n: n })
    }

    fn base_matrix(&self, g: &mut Xoshiro256) -> CsrMatrix {
        let n = self.n;
        let mut coo = CooMatrix::new(n, n);
        for i in 0..n {
            // heavy nonzero diagonal (sign random, magnitude >= diag_min)
            let sign = if g.uniform_f64() < 0.5 { -1.0 } else { 1.0 };
            let d = sign * (self.diag_min + g.uniform_f32() * self.value_sigma);
            coo.push(i, i, d).expect("in bounds");
            if n > 1 {
                for _ in 0..self.offdiag_per_row {
                    let mut j = g.gen_range(0, n - 1);
                    if j >= i {
                        j += 1; // skip the diagonal
                    }
                    coo.push(i, j, g.normal_f32() * self.value_sigma)
                        .expect("in bounds");
                }
            }
        }
        coo.to_csr()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn consistent_system() {
        let ds = GeneratorConfig::small_demo(32, 4).generate(1);
        assert_eq!(ds.matrix.shape(), (160, 32));
        assert_eq!(ds.rhs.len(), 160);
        // consistency: b = A x_true within f32 rounding
        assert!(ds.residual_at_truth() < 1e-2, "{}", ds.residual_at_truth());
    }

    #[test]
    fn deterministic() {
        let c = GeneratorConfig::small_demo(16, 2);
        let a = c.generate(7);
        let b = c.generate(7);
        assert_eq!(a.matrix, b.matrix);
        assert_eq!(a.rhs, b.rhs);
        let d = c.generate(8);
        assert_ne!(a.rhs, d.rhs);
    }

    #[test]
    fn schenk_like_sparsity_matches_paper() {
        let ds = GeneratorConfig::schenk_like(512).generate(3);
        let pct = ds.matrix.sparsity_pct();
        // paper: 99.85% for c-27 at n=4563; the relative density scales as
        // 1/n (fixed nnz/row), so at n=512 expect ~95% — assert the "very
        // sparse" regime and the 1/n scaling toward the paper's figure
        assert!(pct > 90.0, "sparsity {pct}");
        let big = GeneratorConfig::schenk_like(2048).generate(3);
        assert!(big.matrix.sparsity_pct() > pct);
        assert_eq!(ds.matrix.shape(), (2048, 512));
    }

    #[test]
    fn blocks_are_full_rank() {
        // every contiguous block of >= n rows must be full column rank
        // (Algorithm 1's partition assumption) — verify via QR diagonal
        let n = 24;
        let ds = GeneratorConfig::small_demo(n, 3).generate(11);
        let m = ds.matrix.rows();
        let j = 3;
        let l = m / j;
        assert!(l >= n);
        for blk in 0..j {
            let lo = blk * l;
            let hi = if blk == j - 1 { m } else { lo + l };
            let dense = ds.matrix.slice_rows_dense(lo, hi);
            let f = crate::linalg::qr::householder_qr(&dense);
            let min_diag = (0..n)
                .map(|i| f.r[(i, i)].abs())
                .fold(f32::INFINITY, f32::min);
            assert!(min_diag > 1e-4, "block {blk} rank-deficient ({min_diag})");
        }
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = GeneratorConfig::small_demo(8, 2);
        c.m_total = 4;
        assert!(c.try_generate(0).is_err());
        let mut c2 = GeneratorConfig::small_demo(8, 2);
        c2.n = 0;
        assert!(c2.try_generate(0).is_err());
        let mut c3 = GeneratorConfig::small_demo(8, 2);
        c3.offdiag_per_row = 8;
        assert!(c3.try_generate(0).is_err());
    }

    #[test]
    fn table1_preset_shapes() {
        let c = GeneratorConfig::table1(9308, 2327);
        assert_eq!(c.m_total, 9308);
        assert_eq!(c.n, 2327);
    }
}
