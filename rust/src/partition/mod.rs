//! Row partitioning of `[A; D_A]` into per-worker blocks (Algorithm 1,
//! step 1) plus the shape-bucketing that maps arbitrary datasets onto the
//! AOT artifact manifest.

pub mod bucket;
mod plan;

pub use bucket::{pad_to_bucket, BucketedBlock};
pub use plan::{PartitionPlan, PartitionRegime, RowBlock};
