"""AOT compiler: lower every Layer-2 graph to HLO text + manifest.json.

This is the only place python touches the artifacts the rust runtime loads.
Run via ``make artifacts`` (no-op when inputs are unchanged) — NEVER at
request time.

Interchange format is HLO *text*: jax >= 0.5 serializes HloModuleProto with
64-bit instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).  Lowering goes through stablehlo
-> XlaComputation with ``return_tuple=True``; the rust side unwraps the
tuple.

Usage:
    python -m compile.aot --out ../artifacts [--full] [--only PATTERN]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import re
import sys

import jax

# f64 must be available before any graph is traced: the classical-APC init
# computes its Gram inverse in double precision (see model.init_classical).
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, shapes

F32 = jnp.float32
I32 = jnp.int32


def spec(*shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (portable interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def graph_entries(full: bool) -> list[dict]:
    """Enumerate every artifact: name, callable, example args, params."""
    entries: list[dict] = []
    seen: set[str] = set()

    def add(name, fn, args, params, outputs):
        if name in seen:
            return
        seen.add(name)
        entries.append(
            dict(name=name, fn=fn, args=args, params=params, outputs=outputs)
        )

    for pb in shapes.problems(full):
        j, l, n = pb.j, pb.l, pb.n
        if pb.tall:
            add(
                f"init_qr_l{l}_n{n}",
                model.init_qr,
                (spec(l, n), spec(l)),
                dict(kind="init_qr", l=l, n=n),
                [[n], [n, n]],
            )
            add(
                f"init_classical_l{l}_n{n}",
                model.init_classical,
                (spec(l, n), spec(l)),
                dict(kind="init_classical", l=l, n=n),
                [[n], [n, n]],
            )
        else:
            add(
                f"init_fat_l{l}_n{n}",
                model.init_fat,
                (spec(l, n), spec(l)),
                dict(kind="init_fat", l=l, n=n),
                [[n], [n, n]],
            )
        add(
            f"update_n{n}",
            model.update,
            (spec(n), spec(n), spec(n, n), spec()),
            dict(kind="update", n=n),
            [[n]],
        )
        add(
            f"average_j{j}_n{n}",
            model.average,
            (spec(j, n), spec(n), spec()),
            dict(kind="average", j=j, n=n),
            [[n]],
        )
        add(
            f"round_j{j}_n{n}",
            model.consensus_round,
            (spec(j, n), spec(n), spec(j, n, n), spec(), spec()),
            dict(kind="round", j=j, n=n),
            [[j, n], [n]],
        )
        add(
            f"solve_j{j}_n{n}",
            model.solve_loop,
            (spec(j, n), spec(n), spec(j, n, n), spec(), spec(),
             spec(dtype=I32)),
            dict(kind="solve", j=j, n=n),
            [[j, n], [n]],
        )
        add(
            f"dgd_grad_l{l}_n{n}",
            model.dgd_grad,
            (spec(l, n), spec(n), spec(l)),
            dict(kind="dgd_grad", l=l, n=n),
            [[n]],
        )
        add(
            f"mse_n{n}",
            model.mse,
            (spec(n), spec(n)),
            dict(kind="mse", n=n),
            [[]],
        )
    return entries


def lower_entry(entry: dict, out_dir: str) -> dict:
    lowered = jax.jit(entry["fn"]).lower(*entry["args"])
    text = to_hlo_text(lowered)
    fname = f"{entry['name']}.hlo.txt"
    path = os.path.join(out_dir, fname)
    with open(path, "w") as f:
        f.write(text)
    inputs = [
        dict(shape=list(s.shape), dtype=str(s.dtype)) for s in entry["args"]
    ]
    return dict(
        name=entry["name"],
        file=fname,
        params=entry["params"],
        inputs=inputs,
        outputs=[dict(shape=s, dtype="float32") for s in entry["outputs"]],
        sha256=hashlib.sha256(text.encode()).hexdigest()[:16],
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--full", action="store_true",
                    help="also build paper-scale Table-1 shapes")
    ap.add_argument("--only", default=None,
                    help="regex filter on artifact names")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    entries = graph_entries(args.full)
    if args.only:
        rx = re.compile(args.only)
        entries = [e for e in entries if rx.search(e["name"])]

    manifest = []
    for i, e in enumerate(entries):
        sys.stderr.write(f"[{i + 1}/{len(entries)}] {e['name']}\n")
        manifest.append(lower_entry(e, args.out))

    # Fix output dtypes for the i32 epoch counter input of solve graphs.
    mpath = os.path.join(args.out, "manifest.json")
    # Merge with an existing manifest (e.g. default build then --full).
    if os.path.exists(mpath):
        with open(mpath) as f:
            old = {m["name"]: m for m in json.load(f)}
        for m in manifest:
            old[m["name"]] = m
        manifest = sorted(old.values(), key=lambda m: m["name"])
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest)} artifacts to {args.out}")


if __name__ == "__main__":
    main()
