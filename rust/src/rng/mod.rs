//! Deterministic PRNG substrate (no external `rand` crate available
//! offline): xoshiro256++ with normal/uniform distributions.
//!
//! Used by the synthetic dataset generator (`sparse::generate`), the
//! property-test harness (`benchkit::prop`) and the benches — everything
//! that needs reproducible randomness across runs and platforms.

mod xoshiro;

pub use xoshiro::Xoshiro256;

/// Convenience: a generator seeded from a u64 via splitmix64.
pub fn seeded(seed: u64) -> Xoshiro256 {
    Xoshiro256::seed_from_u64(seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = seeded(42);
        let mut b = seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = seeded(1);
        let mut b = seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_unit_range_and_moments() {
        let mut g = seeded(7);
        let n = 200_000;
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        for _ in 0..n {
            let v = g.uniform_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 5e-3, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var {var}");
    }

    #[test]
    fn normal_moments() {
        let mut g = seeded(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let v = g.normal_f64();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 1e-2, "mean {mean}");
        assert!((var - 1.0).abs() < 2e-2, "var {var}");
    }

    #[test]
    fn gen_range_bounds() {
        let mut g = seeded(3);
        for _ in 0..10_000 {
            let v = g.gen_range(10, 20);
            assert!((10..20).contains(&v));
        }
        // degenerate single-value range
        assert_eq!(g.gen_range(5, 6), 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut g = seeded(9);
        let mut v: Vec<u32> = (0..100).collect();
        g.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // overwhelmingly likely
    }
}
