"""Layer-1 kernels + pure-HLO linalg + jnp oracles."""
