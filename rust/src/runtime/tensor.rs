//! Plain-data tensors exchanged with the PJRT executor thread.

use crate::error::{DapcError, Result};
use crate::linalg::Matrix;

/// A host tensor: f32 data of arbitrary rank, or an i32 scalar (the
/// `solve_*` artifacts take the epoch count as i32[]).
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32Scalar(i32),
}

impl Tensor {
    /// Rank-0 f32 scalar.
    pub fn scalar_f32(v: f32) -> Self {
        Tensor::F32 { shape: vec![], data: vec![v] }
    }

    /// Rank-1 vector.
    pub fn vec1(data: Vec<f32>) -> Self {
        Tensor::F32 { shape: vec![data.len()], data }
    }

    /// Rank-2 from a dense matrix (row-major, matching HLO default layout).
    pub fn from_matrix(m: &Matrix) -> Self {
        Tensor::F32 {
            shape: vec![m.rows(), m.cols()],
            data: m.as_slice().to_vec(),
        }
    }

    /// Rank-3 stack of equally-shaped matrices (J x n x n projector stack).
    pub fn from_matrices(ms: &[Matrix]) -> Result<Self> {
        let first = ms
            .first()
            .ok_or_else(|| DapcError::Shape("empty matrix stack".into()))?;
        let (r, c) = first.shape();
        let mut data = Vec::with_capacity(ms.len() * r * c);
        for m in ms {
            if m.shape() != (r, c) {
                return Err(DapcError::Shape(
                    "ragged matrix stack".into(),
                ));
            }
            data.extend_from_slice(m.as_slice());
        }
        Ok(Tensor::F32 { shape: vec![ms.len(), r, c], data })
    }

    /// Rank-2 from stacked rows (J x n estimate stack).
    pub fn from_rows(rows: &[Vec<f32>]) -> Result<Self> {
        let first = rows
            .first()
            .ok_or_else(|| DapcError::Shape("empty row stack".into()))?;
        let n = first.len();
        let mut data = Vec::with_capacity(rows.len() * n);
        for r in rows {
            if r.len() != n {
                return Err(DapcError::Shape("ragged row stack".into()));
            }
            data.extend_from_slice(r);
        }
        Ok(Tensor::F32 { shape: vec![rows.len(), n], data })
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } => shape,
            Tensor::I32Scalar(_) => &[],
        }
    }

    pub fn element_count(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32Scalar(_) => 1,
        }
    }

    /// Consume into a flat f32 vector.
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            Tensor::I32Scalar(_) => {
                Err(DapcError::Shape("expected f32 tensor, got i32".into()))
            }
        }
    }

    /// Borrow the f32 data.
    pub fn f32_data(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            Tensor::I32Scalar(_) => {
                Err(DapcError::Shape("expected f32 tensor, got i32".into()))
            }
        }
    }

    /// View a rank-2 tensor as a Matrix (copies).
    pub fn to_matrix(&self) -> Result<Matrix> {
        match self {
            Tensor::F32 { shape, data } if shape.len() == 2 => Ok(
                Matrix::from_vec(shape[0], shape[1], data.clone()),
            ),
            Tensor::F32 { shape, .. } => Err(DapcError::Shape(format!(
                "expected rank-2 tensor, got rank {}",
                shape.len()
            ))),
            Tensor::I32Scalar(_) => {
                Err(DapcError::Shape("expected f32 tensor, got i32".into()))
            }
        }
    }

    /// Split a rank-2 (J x n) tensor into J row vectors.
    pub fn into_rows(self) -> Result<Vec<Vec<f32>>> {
        match self {
            Tensor::F32 { shape, data } if shape.len() == 2 => {
                let (j, n) = (shape[0], shape[1]);
                Ok((0..j).map(|i| data[i * n..(i + 1) * n].to_vec()).collect())
            }
            other => Err(DapcError::Shape(format!(
                "expected rank-2 tensor, got shape {:?}",
                other.shape()
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_and_shapes() {
        assert_eq!(Tensor::scalar_f32(1.0).shape(), &[] as &[usize]);
        assert_eq!(Tensor::vec1(vec![1.0, 2.0]).shape(), &[2]);
        let m = Matrix::from_fn(2, 3, |i, j| (i * 3 + j) as f32);
        let t = Tensor::from_matrix(&m);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.to_matrix().unwrap(), m);
    }

    #[test]
    fn stacks() {
        let a = Matrix::eye(2);
        let b = Matrix::zeros(2, 2);
        let t = Tensor::from_matrices(&[a, b]).unwrap();
        assert_eq!(t.shape(), &[2, 2, 2]);
        assert_eq!(t.f32_data().unwrap(), &[1.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0]);

        let rows = Tensor::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        assert_eq!(rows.shape(), &[2, 2]);
        assert_eq!(
            rows.into_rows().unwrap(),
            vec![vec![1.0, 2.0], vec![3.0, 4.0]]
        );
    }

    #[test]
    fn ragged_rejected() {
        assert!(Tensor::from_rows(&[vec![1.0], vec![1.0, 2.0]]).is_err());
        assert!(Tensor::from_matrices(&[]).is_err());
        assert!(
            Tensor::from_matrices(&[Matrix::eye(2), Matrix::eye(3)]).is_err()
        );
    }

    #[test]
    fn i32_conversions_guarded() {
        let t = Tensor::I32Scalar(5);
        assert!(t.f32_data().is_err());
        assert!(t.clone().into_f32().is_err());
        assert!(t.to_matrix().is_err());
        assert_eq!(t.element_count(), 1);
    }
}
