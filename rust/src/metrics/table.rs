//! Markdown/plaintext table formatting for bench output (Table 1 rows).

/// Accumulates rows and renders an aligned markdown table.
#[derive(Debug, Default, Clone)]
pub struct TableBuilder {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TableBuilder {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch"
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as a column-aligned markdown table.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let parts: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            format!("| {} |\n", parts.join(" | "))
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let sep: Vec<String> =
            (0..ncols).map(|i| "-".repeat(widths[i])).collect();
        out.push_str(&format!("|-{}-|\n", sep.join("-|-")));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Render as CSV.
    pub fn render_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = TableBuilder::new(&["shape", "time"]);
        t.row(&["(9308 x 2327)".into(), "12.2s".into()]);
        t.row(&["(15188 x 3797)".into(), "31.6s".into()]);
        let md = t.render();
        let lines: Vec<&str> = md.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("shape"));
        assert!(lines[1].starts_with("|-"));
        // all lines equal width
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    fn csv_output() {
        let mut t = TableBuilder::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.render_csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = TableBuilder::new(&["a", "b"]);
        t.row(&["1".into()]);
    }
}
