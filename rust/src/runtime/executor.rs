//! Cross-thread PJRT executor.
//!
//! The PJRT client cannot leave its thread (`Rc` internals), but the
//! coordinator runs J worker threads that all need to execute artifacts.
//! [`XlaExecutor`] spawns one dedicated runtime thread owning a
//! [`PjrtContext`] and serves execution requests over an mpsc channel;
//! handles are cheap to clone and `Send`.
//!
//! On CPU the per-call channel overhead is ~1µs — negligible against the
//! O(n^2) matvecs each consensus call performs (measured in §Perf).

use std::path::Path;
use std::sync::mpsc;
use std::thread::JoinHandle;

use crate::error::{DapcError, Result};

use super::pjrt::PjrtContext;
use super::tensor::Tensor;

enum Request {
    Execute {
        name: String,
        inputs: Vec<Tensor>,
        reply: mpsc::Sender<Result<Vec<Tensor>>>,
    },
    Warm {
        names: Vec<String>,
        reply: mpsc::Sender<Result<()>>,
    },
    HasArtifact {
        name: String,
        reply: mpsc::Sender<bool>,
    },
    InitBuckets {
        kind: String,
        reply: mpsc::Sender<Vec<(usize, usize)>>,
    },
    Shutdown,
}

/// Clonable, `Send` handle to the PJRT runtime thread.
#[derive(Clone)]
pub struct XlaExecutor {
    tx: mpsc::Sender<Request>,
}

/// Owns the runtime thread; dropping it shuts the thread down.
pub struct XlaExecutorHost {
    executor: XlaExecutor,
    handle: Option<JoinHandle<()>>,
}

impl XlaExecutorHost {
    /// Spawn the runtime thread over an artifact directory.
    pub fn spawn(artifacts_dir: &Path) -> Result<Self> {
        let dir = artifacts_dir.to_path_buf();
        let (tx, rx) = mpsc::channel::<Request>();
        // Creation errors must surface to the caller: the thread sends its
        // init result back before entering the serve loop.
        let (init_tx, init_rx) = mpsc::channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("dapc-pjrt".into())
            .spawn(move || {
                let ctx = match PjrtContext::new(&dir) {
                    Ok(c) => {
                        let _ = init_tx.send(Ok(()));
                        c
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        return;
                    }
                };
                serve(ctx, rx);
            })
            .map_err(|e| DapcError::Coordinator(e.to_string()))?;
        init_rx
            .recv()
            .map_err(|_| DapcError::Coordinator("pjrt thread died".into()))??;
        Ok(Self { executor: XlaExecutor { tx }, handle: Some(handle) })
    }

    pub fn executor(&self) -> XlaExecutor {
        self.executor.clone()
    }
}

impl Drop for XlaExecutorHost {
    fn drop(&mut self) {
        let _ = self.executor.tx.send(Request::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve(ctx: PjrtContext, rx: mpsc::Receiver<Request>) {
    while let Ok(req) = rx.recv() {
        match req {
            Request::Execute { name, inputs, reply } => {
                let _ = reply.send(ctx.execute(&name, &inputs));
            }
            Request::Warm { names, reply } => {
                let refs: Vec<&str> =
                    names.iter().map(String::as_str).collect();
                let _ = reply.send(ctx.warm(&refs));
            }
            Request::HasArtifact { name, reply } => {
                let _ = reply.send(ctx.manifest().contains(&name));
            }
            Request::InitBuckets { kind, reply } => {
                let _ = reply.send(ctx.manifest().init_buckets(&kind));
            }
            Request::Shutdown => break,
        }
    }
}

impl XlaExecutor {
    /// Execute an artifact by name (blocking).
    pub fn execute(&self, name: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Execute { name: name.into(), inputs, reply })
            .map_err(|_| dead())?;
        rx.recv().map_err(|_| dead())?
    }

    /// Pre-compile artifacts.
    pub fn warm(&self, names: &[&str]) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Warm {
                names: names.iter().map(|s| s.to_string()).collect(),
                reply,
            })
            .map_err(|_| dead())?;
        rx.recv().map_err(|_| dead())?
    }

    /// Whether the manifest has an artifact.
    pub fn has_artifact(&self, name: &str) -> Result<bool> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::HasArtifact { name: name.into(), reply })
            .map_err(|_| dead())?;
        rx.recv().map_err(|_| dead())
    }

    /// (l, n) buckets available for an init kind.
    pub fn init_buckets(&self, kind: &str) -> Result<Vec<(usize, usize)>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::InitBuckets { kind: kind.into(), reply })
            .map_err(|_| dead())?;
        rx.recv().map_err(|_| dead())
    }
}

fn dead() -> DapcError {
    DapcError::Coordinator("pjrt executor thread is gone".into())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn spawn_fails_on_missing_dir() {
        assert!(XlaExecutorHost::spawn(Path::new("/nonexistent/xyz")).is_err());
    }

    #[test]
    fn execute_from_multiple_threads() {
        let Some(dir) = artifacts_dir() else { return };
        let host = XlaExecutorHost::spawn(&dir).unwrap();
        let mut joins = Vec::new();
        for t in 0..4 {
            let ex = host.executor();
            joins.push(std::thread::spawn(move || {
                let x = Tensor::vec1(vec![t as f32; 32]);
                let y = Tensor::vec1(vec![0.0; 32]);
                let out = ex.execute("mse_n32", vec![x, y]).unwrap();
                let v = out[0].f32_data().unwrap()[0];
                assert!((v - (t as f32).powi(2)).abs() < 1e-5);
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn buckets_roundtrip() {
        let Some(dir) = artifacts_dir() else { return };
        let host = XlaExecutorHost::spawn(&dir).unwrap();
        let ex = host.executor();
        assert!(ex.has_artifact("update_n32").unwrap());
        assert!(!ex.has_artifact("bogus").unwrap());
        let buckets = ex.init_buckets("init_qr").unwrap();
        assert!(buckets.contains(&(64, 32)));
    }
}
