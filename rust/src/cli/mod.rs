//! Hand-rolled CLI argument parser (clap is unavailable offline).
//!
//! Supports subcommands with `--flag value`, `--flag=value` and boolean
//! `--flag` forms, plus positional arguments; generates usage text from
//! the declared options.

use std::collections::BTreeMap;

use crate::error::{DapcError, Result};

/// Declared option for usage/validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
}

/// Parsed command line: subcommand + options + positionals.
#[derive(Debug, Default)]
pub struct ParsedArgs {
    pub command: Option<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl ParsedArgs {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s.parse::<T>().map(Some).map_err(|_| {
                DapcError::Parse(format!("invalid value for --{name}: {s:?}"))
            }),
        }
    }
}

/// Parse argv (without the program name) against a set of declared specs.
pub fn parse(args: &[String], specs: &[OptSpec]) -> Result<ParsedArgs> {
    let mut out = ParsedArgs::default();
    let mut i = 0;
    // first non-flag token is the subcommand
    if i < args.len() && !args[i].starts_with('-') {
        out.command = Some(args[i].clone());
        i += 1;
    }
    while i < args.len() {
        let arg = &args[i];
        if let Some(rest) = arg.strip_prefix("--") {
            let (name, inline_val) = match rest.split_once('=') {
                Some((n, v)) => (n.to_string(), Some(v.to_string())),
                None => (rest.to_string(), None),
            };
            let spec = specs.iter().find(|s| s.name == name).ok_or_else(|| {
                DapcError::Parse(format!(
                    "unknown option --{name}\n\n{}",
                    usage(specs)
                ))
            })?;
            if spec.takes_value {
                let val = match inline_val {
                    Some(v) => v,
                    None => {
                        i += 1;
                        args.get(i)
                            .cloned()
                            .ok_or_else(|| {
                                DapcError::Parse(format!(
                                    "option --{name} requires a value"
                                ))
                            })?
                    }
                };
                out.options.insert(name, val);
            } else {
                if inline_val.is_some() {
                    return Err(DapcError::Parse(format!(
                        "option --{name} does not take a value"
                    )));
                }
                out.flags.push(name);
            }
        } else {
            out.positionals.push(arg.clone());
        }
        i += 1;
    }
    Ok(out)
}

/// Render usage text from the declared specs.
pub fn usage(specs: &[OptSpec]) -> String {
    let mut out = String::from("options:\n");
    for s in specs {
        let arg = if s.takes_value {
            format!("--{} <value>", s.name)
        } else {
            format!("--{}", s.name)
        };
        out.push_str(&format!("  {:<24} {}\n", arg, s.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "epochs", help: "T", takes_value: true },
            OptSpec { name: "verbose", help: "chatty", takes_value: false },
            OptSpec { name: "eta", help: "mix", takes_value: true },
        ]
    }

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn subcommand_and_options() {
        let p = parse(&sv(&["solve", "--epochs", "80", "--verbose", "data.mtx"]), &specs()).unwrap();
        assert_eq!(p.command.as_deref(), Some("solve"));
        assert_eq!(p.get("epochs"), Some("80"));
        assert!(p.has_flag("verbose"));
        assert_eq!(p.positionals, vec!["data.mtx"]);
    }

    #[test]
    fn equals_form() {
        let p = parse(&sv(&["solve", "--eta=0.9"]), &specs()).unwrap();
        assert_eq!(p.get("eta"), Some("0.9"));
    }

    #[test]
    fn typed_parse() {
        let p = parse(&sv(&["x", "--epochs", "12"]), &specs()).unwrap();
        assert_eq!(p.get_parse::<usize>("epochs").unwrap(), Some(12));
        assert_eq!(p.get_parse::<usize>("eta").unwrap(), None);
        let bad = parse(&sv(&["x", "--epochs", "abc"]), &specs()).unwrap();
        assert!(bad.get_parse::<usize>("epochs").is_err());
    }

    #[test]
    fn errors() {
        assert!(parse(&sv(&["--nope"]), &specs()).is_err());
        assert!(parse(&sv(&["--epochs"]), &specs()).is_err());
        assert!(parse(&sv(&["--verbose=1"]), &specs()).is_err());
    }

    #[test]
    fn usage_mentions_all() {
        let u = usage(&specs());
        assert!(u.contains("--epochs <value>"));
        assert!(u.contains("--verbose"));
    }
}
