//! Table 1 reproduction: total execution time of classical vs decomposed
//! APC over the five published matrix shapes, with the acceleration
//! factor.
//!
//! Default shapes are the paper's scaled by 1/8 per dimension (the
//! relative ordering and the growth of the acceleration factor with n are
//! preserved; absolute times differ from the paper's Tryton testbed).
//! Pass `--full` for the exact published shapes.
//!
//! ```sh
//! cargo run --release --example acceleration_table [-- --full]
//! ```

use dapc::metrics::TableBuilder;
use dapc::prelude::*;
use dapc::sparse::generate::GeneratorConfig;

/// (m, n, T) rows from the paper's Table 1.
const TABLE1: [(usize, usize, usize); 5] = [
    (9308, 2327, 80),
    (15188, 3797, 70),
    (18252, 4563, 95),
    (21284, 5321, 85),
    (37084, 9271, 175),
];

fn main() -> Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { 1 } else { 8 };
    let j = 2; // paper: w = 2 workers

    let engine = NativeEngine::new();
    let mut table = TableBuilder::new(&[
        "A matrix shape",
        "T epochs",
        "Classical APC",
        "Decomposed APC",
        "Acceleration",
    ]);

    println!(
        "Table 1 reproduction ({}), J={j} partitions\n",
        if full { "paper-scale shapes" } else { "1/8-scale shapes" }
    );
    for (mi, ni, t) in TABLE1 {
        let (m, n) = (mi / scale, ni / scale);
        let ds = GeneratorConfig::table1(m, n).generate(1000 + n as u64);
        let opts = SolveOptions { epochs: t, ..Default::default() };

        let classical = ApcClassicalSolver::new(opts.clone())
            .solve(&engine, &ds.matrix, &ds.rhs, j)?;
        let decomposed =
            DapcSolver::new(opts).solve(&engine, &ds.matrix, &ds.rhs, j)?;

        // both must actually solve the system
        assert!(classical.final_mse(&ds.x_true) < 1e-2);
        assert!(decomposed.final_mse(&ds.x_true) < 1e-2);

        let tc = classical.total_time().as_secs_f64();
        let td = decomposed.total_time().as_secs_f64();
        table.row(&[
            format!("({m} x {n})"),
            format!("{t}"),
            format!("{tc:.2}s"),
            format!("{td:.2}s"),
            format!("{:.2}", tc / td),
        ]);
        println!(
            "({m} x {n}): classical {tc:.2}s (init {:.2}s) vs decomposed {td:.2}s (init {:.2}s) => {:.2}x",
            classical.init_time.as_secs_f64(),
            decomposed.init_time.as_secs_f64(),
            tc / td
        );
    }

    println!("\n{}", table.render());
    println!(
        "paper reports accelerations 1.24, 1.49, 1.52, 1.68, 1.79 on its \
         Tryton testbed; expect the same 'decomposed wins, gap grows with n' \
         shape here."
    );
    Ok(())
}
