//! Solve service: warm sessions, factorization reuse, batched multi-RHS
//! solves.
//!
//! The paper positions APC as an alternative to one-shot numerical
//! solvers, but its init phase — the per-worker Householder QR of `A_j`
//! (eqs. (1)-(4)) — is O(l n^2) while everything the *right-hand side*
//! touches is O(l n + n^2): `x_j(0) = R^{-1} Q1^T b_j` and nothing else.
//! The projector `P_j = I - Q1^T Q1` that drives every eq. (6) update is
//! built from `A_j` alone, and the eq. (5)/(7) seeding/mixing consume
//! only the per-partition estimates.  A serving layer can therefore
//! register a matrix ONCE and amortize the factorization across
//! thousands of solves — the request-serving shape this module provides.
//!
//! # What state is resident where
//!
//! * **Partitions/workers** retain, per block `j`: the dense `A_j`, the
//!   projector `P_j` *plus its prepacked A-panels* (the pack-once
//!   operand of the wide packed epoch kernel — see
//!   [`crate::linalg::blas::PrepackedPanels`]), and the seed
//!   factorization (QR factors, the f64 Gram inverse, or the fat-regime
//!   `Q`/`R^T` — see [`crate::solver::SeedFactors`]).  This is the
//!   expensive RHS-independent state; it never crosses the wire
//!   (cluster workers build it from their `RegisterMatrix` block and
//!   keep it across solves).  [`ServiceStats`] reports the per-partition
//!   byte cost ([`crate::solver::resident_partition_bytes`]).
//! * **The session (leader side)** retains only the CSR matrix (for
//!   rhs slicing, residuals and the DGD auto step), the partition plan,
//!   and n-length accumulators — the paper's leader-memory guarantee
//!   carries over unchanged.
//!
//! # Request flow
//!
//! ```text
//!   SolverSession::register(backend, A)   -- factorize once (cold cost)
//!       session.solve(b)                  -- seed + epochs   (warm cost)
//!       session.solve_batch(&[b0, .., bk])-- k columns through ONE epoch
//!                                            loop; the prepacked `P_j`
//!                                            panels stream through the
//!                                            wide packed kernel, shared
//!                                            by all k columns
//! ```
//!
//! Warm solves are **bit-identical** to cold solves and batched solves
//! to sequential ones, on the in-process and cluster backends alike:
//! seeding re-runs the exact arithmetic of the cold init against the
//! retained factors, and the packed epoch kernel reproduces `dot`'s
//! lane-deterministic f64 accumulation order per output element
//! (`tests/distributed_equivalence.rs`, `tests/prepacked_equivalence.rs`).
//!
//! [`ServiceStats`] tracks the amortization story: one-time registration
//! cost vs per-RHS solve time and per-session solve counters.
//!
//! # Multi-tenant serving
//!
//! [`SolverSession`] owns ONE matrix; the layer above it scales that to
//! many tenants:
//!
//! * [`SessionConfig`] — the builder every registration goes through
//!   (algorithm, partitions, epochs, kernel tier).
//! * [`SessionManager`] — MANY registered matrices keyed by session id
//!   over one backend, with a configurable resident-memory cap enforced
//!   by LRU eviction.  Eviction is transparent: the next solve against
//!   an evicted id re-factorizes and serves, bit-for-bit identical.
//! * [`serve_connections`] / [`SolveClient`] — the wire-v5 solve
//!   server: many concurrent client connections multiplexed onto one
//!   manager behind a bounded request queue, with credit-granted
//!   admission and explicit `Busy` backpressure.

mod config;
mod manager;
mod server;
mod session;
mod stats;

pub use config::SessionConfig;
pub use manager::SessionManager;
pub use server::{
    serve_connections, ClientReply, ServeOptions, ServeReport, SolveClient,
    SERVER_ERROR_ID,
};
pub use session::{SessionAlgorithm, SolverSession};
pub use stats::ServiceStats;
