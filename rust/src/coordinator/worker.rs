//! Worker loop: receives a partition, initializes locally (QR/inverse +
//! projector, or nothing at all for gradient-only DGD service), then
//! serves consensus-update or gradient requests until shutdown.  The
//! projector `P_j` and the dense block `A_j` never leave the worker —
//! only n-length vectors cross the transport.

use crate::error::Result;
use crate::linalg::Matrix;
use crate::solver::ComputeEngine;

use super::message::Message;
use super::transport::Transport;

/// Run the worker protocol until `Shutdown`.  Errors are reported to the
/// leader as `WorkerError` before returning.
pub fn run_worker<E: ComputeEngine, T: Transport>(
    engine: &E,
    transport: &mut T,
) -> Result<()> {
    let mut state: Option<WorkerState> = None;
    let mut my_id: u32 = u32::MAX;
    loop {
        let msg = transport.recv()?;
        let outcome = handle(engine, &mut state, &mut my_id, msg);
        match outcome {
            Ok(Some(reply)) => transport.send(&reply)?,
            Ok(None) => return Ok(()), // shutdown
            Err(e) => {
                transport.send(&Message::WorkerError {
                    worker_id: my_id,
                    message: e.to_string(),
                })?;
                return Err(e);
            }
        }
    }
}

struct WorkerState {
    x: Vec<f32>,
    /// `None` after a `GradOnly` init: the worker serves gradients only
    /// and never paid for a factorization.
    projector: Option<Matrix>,
    a: Matrix,
    b: Vec<f32>,
}

fn handle<E: ComputeEngine>(
    engine: &E,
    state: &mut Option<WorkerState>,
    my_id: &mut u32,
    msg: Message,
) -> Result<Option<Message>> {
    match msg {
        Message::InitPartition { worker_id, kind, a, b, n_target } => {
            *my_id = worker_id;
            match kind.engine_kind() {
                Some(engine_kind) => {
                    let init =
                        engine.init(engine_kind, &a, &b, n_target as usize)?;
                    let x0 = init.x0.clone();
                    *state = Some(WorkerState {
                        x: init.x0,
                        projector: Some(init.projector),
                        a,
                        b,
                    });
                    Ok(Some(Message::InitDone { worker_id, x0 }))
                }
                None => {
                    // GradOnly: store the block, skip the O(l n^2)
                    // factorization entirely; DGD starts from x = 0 so
                    // there is no estimate to return either
                    *state = Some(WorkerState {
                        x: Vec::new(),
                        projector: None,
                        a,
                        b,
                    });
                    Ok(Some(Message::InitDone { worker_id, x0: Vec::new() }))
                }
            }
        }
        Message::RunUpdate { epoch: _, gamma, xbar } => {
            let st = state.as_mut().ok_or_else(|| {
                crate::error::DapcError::Coordinator(
                    "RunUpdate before InitPartition".into(),
                )
            })?;
            let p = st.projector.as_ref().ok_or_else(|| {
                crate::error::DapcError::Coordinator(
                    "RunUpdate on a grad-only (GradOnly/DGD) worker: no \
                     projector was initialized"
                        .into(),
                )
            })?;
            st.x = engine.update(&st.x, &xbar, p, gamma)?;
            Ok(Some(Message::UpdateDone { worker_id: *my_id, x: st.x.clone() }))
        }
        Message::RunGrad { epoch: _, x } => {
            let st = state.as_ref().ok_or_else(|| {
                crate::error::DapcError::Coordinator(
                    "RunGrad before InitPartition".into(),
                )
            })?;
            let grad = engine.dgd_grad(&st.a, &x, &st.b)?;
            Ok(Some(Message::GradDone { worker_id: *my_id, grad }))
        }
        Message::Shutdown => Ok(None),
        other => Err(crate::error::DapcError::Coordinator(format!(
            "worker received unexpected message {other:?}"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::message::InitKindWire;
    use crate::coordinator::transport::{channel_pair, Transport};
    use crate::rng::seeded;
    use crate::solver::NativeEngine;

    fn consistent(l: usize, n: usize, seed: u64) -> (Matrix, Vec<f32>, Vec<f32>) {
        let mut g = seeded(seed);
        let a = Matrix::from_fn(l, n, |_, _| g.normal_f32());
        let x: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
        let mut b = vec![0.0f32; l];
        crate::linalg::blas::gemv(&a, &x, &mut b);
        (a, b, x)
    }

    #[test]
    fn init_then_update_protocol() {
        let (mut leader, mut worker_side) = channel_pair();
        let handle = std::thread::spawn(move || {
            let engine = NativeEngine::new();
            run_worker(&engine, &mut worker_side)
        });

        let (a, b, x_true) = consistent(24, 8, 3);
        leader
            .send(&Message::InitPartition {
                worker_id: 5,
                kind: InitKindWire::Qr,
                a,
                b,
                n_target: 8,
            })
            .unwrap();
        let Message::InitDone { worker_id, x0 } = leader.recv().unwrap() else {
            panic!("expected InitDone");
        };
        assert_eq!(worker_id, 5);
        for i in 0..8 {
            assert!((x0[i] - x_true[i]).abs() < 1e-2);
        }

        // consensus step with xbar = x0 is a fixed point
        leader
            .send(&Message::RunUpdate { epoch: 0, gamma: 0.9, xbar: x0.clone() })
            .unwrap();
        let Message::UpdateDone { x, .. } = leader.recv().unwrap() else {
            panic!("expected UpdateDone");
        };
        for i in 0..8 {
            assert!((x[i] - x0[i]).abs() < 1e-4);
        }

        leader.send(&Message::Shutdown).unwrap();
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn update_before_init_reports_error() {
        let (mut leader, mut worker_side) = channel_pair();
        let handle = std::thread::spawn(move || {
            let engine = NativeEngine::new();
            let _ = run_worker(&engine, &mut worker_side);
        });
        leader
            .send(&Message::RunUpdate { epoch: 0, gamma: 0.5, xbar: vec![0.0] })
            .unwrap();
        match leader.recv().unwrap() {
            Message::WorkerError { message, .. } => {
                assert!(message.contains("before InitPartition"), "{message}");
            }
            other => panic!("expected WorkerError, got {other:?}"),
        }
        handle.join().unwrap();
    }

    #[test]
    fn grad_protocol() {
        let (mut leader, mut worker_side) = channel_pair();
        let handle = std::thread::spawn(move || {
            let engine = NativeEngine::new();
            run_worker(&engine, &mut worker_side)
        });
        let (a, b, x_true) = consistent(16, 4, 9);
        leader
            .send(&Message::InitPartition {
                worker_id: 0,
                kind: InitKindWire::Qr,
                a,
                b,
                n_target: 4,
            })
            .unwrap();
        let _ = leader.recv().unwrap();
        // gradient at the true solution is ~0
        leader
            .send(&Message::RunGrad { epoch: 0, x: x_true })
            .unwrap();
        let Message::GradDone { grad, .. } = leader.recv().unwrap() else {
            panic!("expected GradDone");
        };
        assert!(crate::linalg::norms::max_abs(&grad) < 1e-3);
        leader.send(&Message::Shutdown).unwrap();
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn grad_only_init_skips_factorization() {
        // timing-independent proof that GradOnly does no init work: the
        // worker returns an EMPTY x0 (a factorizing init always returns
        // an n_target-length estimate) and holds no projector, so a
        // consensus update is impossible while gradients still work.
        let (mut leader, mut worker_side) = channel_pair();
        let handle = std::thread::spawn(move || {
            let engine = NativeEngine::new();
            let _ = run_worker(&engine, &mut worker_side);
        });
        let (a, b, x_true) = consistent(16, 4, 10);
        leader
            .send(&Message::InitPartition {
                worker_id: 2,
                kind: InitKindWire::GradOnly,
                a,
                b,
                n_target: 4,
            })
            .unwrap();
        let Message::InitDone { worker_id, x0 } = leader.recv().unwrap() else {
            panic!("expected InitDone");
        };
        assert_eq!(worker_id, 2);
        assert!(x0.is_empty(), "GradOnly must not compute an initial solve");

        // gradients are served from the stored block
        leader
            .send(&Message::RunGrad { epoch: 0, x: x_true })
            .unwrap();
        let Message::GradDone { grad, .. } = leader.recv().unwrap() else {
            panic!("expected GradDone");
        };
        assert!(crate::linalg::norms::max_abs(&grad) < 1e-3);

        // no projector exists -> consensus updates are rejected loudly
        leader
            .send(&Message::RunUpdate {
                epoch: 0,
                gamma: 0.5,
                xbar: vec![0.0; 4],
            })
            .unwrap();
        match leader.recv().unwrap() {
            Message::WorkerError { message, .. } => {
                assert!(message.contains("grad-only"), "{message}");
            }
            other => panic!("expected WorkerError, got {other:?}"),
        }
        handle.join().unwrap();
    }
}
