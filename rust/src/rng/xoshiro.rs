//! xoshiro256++ (Blackman & Vigna) plus splitmix64 seeding and the
//! distribution helpers the library needs.

/// xoshiro256++ generator: fast, 256-bit state, passes BigCrush.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Xoshiro256 {
    /// Expand a u64 seed into the 256-bit state via splitmix64.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform_f64() as f32
    }

    /// Standard normal via Marsaglia polar method.
    pub fn normal_f64(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.uniform_f64() - 1.0;
            let v = 2.0 * self.uniform_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Standard normal as f32.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal_f64() as f32
    }

    /// Uniform integer in [lo, hi) via Lemire-style rejection-free mapping
    /// (bias negligible for the ranges used here).
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        let span = (hi - lo) as u128;
        lo + ((self.next_u64() as u128 * span) >> 64) as usize
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.gen_range(0, i + 1);
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        // For small k relative to n use a set-based draw, else shuffle.
        // BTreeSet (house type, audit rule no-hashmap): only membership
        // is queried, so the ordered set changes nothing but the lookup
        // constant — and never iteration order.
        if k * 4 < n {
            let mut seen = std::collections::BTreeSet::new();
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let idx = self.gen_range(0, n);
                if seen.insert(idx) {
                    out.push(idx);
                }
            }
            out
        } else {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference values for splitmix64(seed=0) from the public C impl.
        let mut s = 0u64;
        assert_eq!(splitmix64(&mut s), 0xE220A8397B1DCDAF);
        assert_eq!(splitmix64(&mut s), 0x6E789E6AA1B965F4);
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut g = Xoshiro256::seed_from_u64(5);
        for &(n, k) in &[(100usize, 5usize), (50, 40), (10, 10)] {
            let idx = g.sample_indices(n, k);
            assert_eq!(idx.len(), k);
            let set: std::collections::BTreeSet<_> = idx.iter().collect();
            assert_eq!(set.len(), k);
            assert!(idx.iter().all(|&i| i < n));
        }
    }
}
