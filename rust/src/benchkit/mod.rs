//! Custom bench harness (criterion is unavailable offline).
//!
//! `cargo bench` binaries use [`Bench`] for warmup + timed iterations with
//! mean/median/p95 reporting, and honor two environment variables:
//!
//! * `DAPC_FULL=1`   — run paper-scale shapes (Table 1 sizes);
//! * `DAPC_QUICK=1`  — minimum iterations, for CI smoke runs.

use std::time::Instant;

use crate::metrics::TimingStats;

/// One benchmark runner with a fixed iteration budget.
pub struct Bench {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        if quick_mode() {
            Self { warmup_iters: 1, iters: 3 }
        } else {
            Self { warmup_iters: 2, iters: 10 }
        }
    }
}

/// `DAPC_QUICK=1` => smoke-test iteration counts.
pub fn quick_mode() -> bool {
    std::env::var("DAPC_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// `DAPC_FULL=1` => paper-scale workloads.
pub fn full_mode() -> bool {
    std::env::var("DAPC_FULL").map(|v| v == "1").unwrap_or(false)
}

/// A measured result, printable as one bench line.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub stats: TimingStats,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} mean {:>10}  median {:>10}  p95 {:>10}  (n={})",
            self.name,
            fmt_secs(self.stats.mean()),
            fmt_secs(self.stats.median()),
            fmt_secs(self.stats.p95()),
            self.stats.samples.len(),
        )
    }
}

/// Human-readable seconds.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

impl Bench {
    pub fn new(warmup_iters: usize, iters: usize) -> Self {
        Self { warmup_iters, iters }
    }

    /// Run `f` with warmup, returning timing stats.  `f` should perform
    /// one complete unit of work per call.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let res = BenchResult {
            name: name.to_string(),
            stats: TimingStats::from_secs(samples),
        };
        println!("{}", res.line());
        res
    }

    /// Time a single invocation (for long end-to-end runs where repeated
    /// iterations are impractical, e.g. Table-1 paper-scale rows).
    pub fn run_once<F: FnOnce()>(&self, name: &str, f: F) -> BenchResult {
        let t0 = Instant::now();
        f();
        let res = BenchResult {
            name: name.to_string(),
            stats: TimingStats::from_secs(vec![t0.elapsed().as_secs_f64()]),
        };
        println!("{}", res.line());
        res
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_collects_samples() {
        let b = Bench::new(1, 5);
        let mut count = 0usize;
        let res = b.run("noop", || {
            count += 1;
        });
        assert_eq!(count, 6); // warmup + iters
        assert_eq!(res.stats.samples.len(), 5);
        assert!(res.line().contains("noop"));
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }

    #[test]
    fn run_once_single_sample() {
        let res = Bench::default().run_once("one", || {});
        assert_eq!(res.stats.samples.len(), 1);
    }
}
