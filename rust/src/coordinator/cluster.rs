//! Cluster spawn helpers.
//!
//! [`LocalCluster`] — J worker threads over in-process channels (the
//! default, analogous to a single-host Dask LocalCluster).
//! [`serve_tcp_worker`] / [`connect_tcp_workers`] — the multi-process
//! variant: start workers with `dapc worker --listen ADDR`, then point the
//! leader at them (analogous to the paper's SSHCluster).  Either way the
//! returned [`Leader`] runs the shared consensus driver over a
//! `ClusterBackend`.

use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::thread::JoinHandle;

use crate::error::{DapcError, Result};
use crate::solver::ComputeEngine;

use super::leader::Leader;
use super::transport::{channel_pair, ChannelTransport, TcpTransport};
use super::worker::run_worker;

/// A leader plus J in-process worker threads.
pub struct LocalCluster {
    pub leader: Leader<ChannelTransport>,
    handles: Vec<JoinHandle<()>>,
}

impl LocalCluster {
    /// Spawn J workers, each building its engine from `make_engine`
    /// (engines may not be `Send`, e.g. per-thread state, so construction
    /// happens inside the worker thread).
    pub fn spawn<E, F>(j: usize, make_engine: F) -> Result<Self>
    where
        E: ComputeEngine,
        F: Fn() -> E + Send + Sync + Clone + 'static,
    {
        let mut leader_sides = Vec::with_capacity(j);
        let mut handles = Vec::with_capacity(j);
        for i in 0..j {
            let (leader_side, mut worker_side) = channel_pair();
            leader_sides.push(leader_side);
            let mk = make_engine.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("dapc-worker-{i}"))
                    .spawn(move || {
                        let engine = mk();
                        // worker errors are reported over the transport;
                        // a hangup just ends the thread.
                        let _ = run_worker(&engine, &mut worker_side);
                    })
                    .map_err(|e| DapcError::Coordinator(e.to_string()))?,
            );
        }
        // Leader::new rejects j == 0 with a clear Coordinator error
        Ok(Self { leader: Leader::new(leader_sides)?, handles })
    }

    /// Shut down workers and join their threads.
    pub fn join(mut self) {
        self.leader.shutdown();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for LocalCluster {
    fn drop(&mut self) {
        self.leader.shutdown();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Worker side of a TCP cluster: listen on `addr`, accept ONE leader
/// connection and serve the worker protocol until shutdown.
pub fn serve_tcp_worker<E: ComputeEngine>(
    engine: &E,
    addr: impl ToSocketAddrs,
) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    let (stream, peer) = listener.accept()?;
    eprintln!("worker: leader connected from {peer}");
    let mut transport = TcpTransport::new(stream)?;
    run_worker(engine, &mut transport)
}

/// Leader side of a TCP cluster: connect to every worker address.
pub fn connect_tcp_workers(
    addrs: &[String],
) -> Result<Leader<TcpTransport>> {
    let mut transports = Vec::with_capacity(addrs.len());
    for addr in addrs {
        let stream = TcpStream::connect(addr).map_err(|e| {
            DapcError::Coordinator(format!("connect {addr}: {e}"))
        })?;
        transports.push(TcpTransport::new(stream)?);
    }
    Leader::new(transports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::{ApcVariant, NativeEngine, SolveOptions, Solver as _};
    use crate::sparse::generate::GeneratorConfig;

    #[test]
    fn local_cluster_solves() {
        let ds = GeneratorConfig::small_demo(24, 3).generate(21);
        let mut cluster = LocalCluster::spawn(3, NativeEngine::new).unwrap();
        let report = cluster
            .leader
            .solve_apc(
                &ds.matrix,
                &ds.rhs,
                ApcVariant::Decomposed,
                &SolveOptions {
                    epochs: 30,
                    x_true: Some(ds.x_true.clone()),
                    ..Default::default()
                },
            )
            .unwrap();
        assert!(report.final_mse(&ds.x_true) < 1e-6);
        drop(cluster);
    }

    #[test]
    fn distributed_matches_single_process() {
        // the coordinator path must produce the same iterates as the
        // single-process solver (identical math, different topology);
        // tests/distributed_equivalence.rs sharpens this to bit-identity
        let ds = GeneratorConfig::small_demo(16, 2).generate(22);
        let opts = SolveOptions { epochs: 10, ..Default::default() };

        let mut cluster = LocalCluster::spawn(2, NativeEngine::new).unwrap();
        let dist = cluster
            .leader
            .solve_apc(&ds.matrix, &ds.rhs, ApcVariant::Decomposed, &opts)
            .unwrap();

        let local = crate::solver::DapcSolver::new(opts)
            .solve(&NativeEngine::new(), &ds.matrix, &ds.rhs, 2)
            .unwrap();

        assert_eq!(
            dist.xbar, local.xbar,
            "distributed vs local iterates diverged"
        );
    }

    #[test]
    fn local_cluster_dgd() {
        let ds = GeneratorConfig::small_demo(12, 2).generate(23);
        let mut cluster = LocalCluster::spawn(2, NativeEngine::new).unwrap();
        let report = cluster
            .leader
            .solve_dgd(
                &ds.matrix,
                &ds.rhs,
                &SolveOptions {
                    epochs: 200,
                    dgd_step: 1e-3,
                    x_true: Some(ds.x_true.clone()),
                    ..Default::default()
                },
            )
            .unwrap();
        let tr = report.trace.unwrap();
        assert!(tr.final_mse().unwrap() < tr.initial_mse().unwrap());
    }

    #[test]
    fn zero_workers_rejected() {
        assert!(LocalCluster::spawn(0, NativeEngine::new).is_err());
    }

    #[test]
    fn tcp_cluster_end_to_end() {
        use std::net::TcpListener;
        // reserve two ports
        let l1 = TcpListener::bind("127.0.0.1:0").unwrap();
        let a1 = l1.local_addr().unwrap();
        let l2 = TcpListener::bind("127.0.0.1:0").unwrap();
        let a2 = l2.local_addr().unwrap();
        drop((l1, l2));

        let w1 = std::thread::spawn(move || {
            serve_tcp_worker(&NativeEngine::new(), a1).unwrap();
        });
        let w2 = std::thread::spawn(move || {
            serve_tcp_worker(&NativeEngine::new(), a2).unwrap();
        });
        // workers need a beat to bind
        std::thread::sleep(std::time::Duration::from_millis(100));

        let ds = GeneratorConfig::small_demo(16, 2).generate(24);
        let mut leader =
            connect_tcp_workers(&[a1.to_string(), a2.to_string()]).unwrap();
        let report = leader
            .solve_apc(
                &ds.matrix,
                &ds.rhs,
                ApcVariant::Decomposed,
                &SolveOptions { epochs: 15, ..Default::default() },
            )
            .unwrap();
        assert!(report.final_mse(&ds.x_true) < 1e-5);
        // real sockets moved real bytes, symmetric counters
        let (sent, received) = leader.wire_bytes();
        assert!(sent > 0 && received > 0);
        leader.shutdown();
        w1.join().unwrap();
        w2.join().unwrap();
    }
}
