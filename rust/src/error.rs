//! Library-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls — no `thiserror` (the build is
//! fully offline, zero registry dependencies).

use std::fmt;

/// Errors surfaced by the DAPC library.
#[derive(Debug)]
pub enum DapcError {
    /// Shape/dimension mismatches.
    Shape(String),

    /// Numerical failures (singular matrices, divergence, NaNs).
    Numeric(String),

    /// Parse failures (MatrixMarket, manifest JSON, config, CLI).
    Parse(String),

    /// Artifact/manifest lookup failures.
    Artifact(String),

    /// Coordinator/transport failures.
    Coordinator(String),

    /// Configuration errors (invalid hyper-parameters etc.).
    Config(String),

    /// I/O wrapper.
    Io(std::io::Error),

    /// XLA/PJRT wrapper.
    Xla(String),
}

impl fmt::Display for DapcError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DapcError::Shape(m) => write!(f, "shape error: {m}"),
            DapcError::Numeric(m) => write!(f, "numeric error: {m}"),
            DapcError::Parse(m) => write!(f, "parse error: {m}"),
            DapcError::Artifact(m) => write!(f, "artifact error: {m}"),
            DapcError::Coordinator(m) => write!(f, "coordinator error: {m}"),
            DapcError::Config(m) => write!(f, "config error: {m}"),
            DapcError::Io(e) => write!(f, "io error: {e}"),
            DapcError::Xla(m) => write!(f, "xla error: {m}"),
        }
    }
}

impl std::error::Error for DapcError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            DapcError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for DapcError {
    fn from(e: std::io::Error) -> Self {
        DapcError::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for DapcError {
    fn from(e: xla::Error) -> Self {
        DapcError::Xla(e.to_string())
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, DapcError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes() {
        assert_eq!(
            DapcError::Shape("3 != 4".into()).to_string(),
            "shape error: 3 != 4"
        );
        assert!(DapcError::Config("bad".into())
            .to_string()
            .starts_with("config"));
    }

    #[test]
    fn io_source_preserved() {
        let e: DapcError =
            std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.to_string().contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
