//! Distributed coordinator — the Dask-cluster substrate of the paper's
//! pipeline, rebuilt as a Rust leader/worker runtime.
//!
//! # Architecture: backend under the shared driver
//!
//! The consensus epoch loop is NOT here: it lives once, in
//! [`crate::solver::driver`], and this module supplies its distributed
//! backend:
//!
//! ```text
//!   solver::drive_apc / drive_dgd       (the algorithm, topology-free)
//!        |
//!   leader::ClusterBackend              (pipelined scatter, out-of-order
//!        |                               gather keyed on worker_id,
//!        v                               fixed-order f64 accumulation)
//!   transport::{ChannelTransport, TcpTransport}
//!        |                               frame := header | len | payload
//!        v                               header = "DP" magic | WIRE_VERSION
//!   worker::run_worker                  (owns A_j, b_j, P_j, x_j)
//! ```
//!
//! * [`message`] — the wire protocol (hand-framed binary; no serde),
//!   versioned via `message::WIRE_VERSION` (currently v4, which added
//!   the `StatsRequest`/`StatsReport` telemetry frames) so old/new
//!   peer mixes fail loudly at the first frame;
//! * [`transport`] — in-process channels and TCP streams behind one
//!   trait, with wire-byte counters and a non-blocking receive path;
//! * [`worker`] — the worker loop: owns its partition, its projector and
//!   its estimate; only n-length vectors ever cross the wire (the paper's
//!   key communication property: `P_j` never leaves the worker).  DGD
//!   workers initialize with `InitKindWire::GradOnly` and never pay for a
//!   factorization;
//! * [`leader`] — [`ClusterBackend`] (the `ConsensusBackend` impl) plus
//!   the [`Leader`] facade that runs the shared driver over it;
//! * [`cluster`] — spawn helpers for local (threaded) and TCP clusters;
//! * [`graph`] — the lazy task-graph representation + DOT export
//!   (reproduces the paper's Figure 1).
//!
//! `tests/distributed_equivalence.rs` pins the backend to bit-identical
//! results with the in-process backend for APC (both variants) and DGD.

pub mod cluster;
pub mod graph;
pub mod leader;
pub mod message;
pub mod transport;
pub mod worker;

pub use cluster::LocalCluster;
pub use graph::TaskGraph;
pub use leader::{ClusterBackend, Leader, WorkerStats};
pub use message::Message;
