//! Coordinate-format sparse matrix (assembly format; converts to CSR).

use crate::error::{DapcError, Result};

use super::CsrMatrix;

/// COO triplet storage. Duplicate entries are summed on conversion to CSR
/// (MatrixMarket semantics).
#[derive(Debug, Clone, Default)]
pub struct CooMatrix {
    rows: usize,
    cols: usize,
    entries: Vec<(usize, usize, f32)>,
}

impl CooMatrix {
    /// Empty matrix of the given shape.
    pub fn new(rows: usize, cols: usize) -> Self {
        Self { rows, cols, entries: Vec::new() }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of stored triplets (before duplicate summing).
    #[inline]
    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Append one entry; bounds-checked.
    pub fn push(&mut self, row: usize, col: usize, value: f32) -> Result<()> {
        if row >= self.rows || col >= self.cols {
            return Err(DapcError::Shape(format!(
                "entry ({row},{col}) out of bounds for {}x{}",
                self.rows, self.cols
            )));
        }
        self.entries.push((row, col, value));
        Ok(())
    }

    /// Borrow the raw triplets.
    pub fn entries(&self) -> &[(usize, usize, f32)] {
        &self.entries
    }

    /// Convert to CSR, summing duplicates and dropping explicit zeros.
    pub fn to_csr(&self) -> CsrMatrix {
        let mut sorted = self.entries.clone();
        sorted.sort_unstable_by_key(|&(r, c, _)| (r, c));

        let mut indptr = Vec::with_capacity(self.rows + 1);
        let mut indices = Vec::with_capacity(sorted.len());
        let mut values = Vec::with_capacity(sorted.len());
        indptr.push(0usize);

        let mut cur_row = 0usize;
        let mut i = 0usize;
        while i < sorted.len() {
            let (r, c, _) = sorted[i];
            while cur_row < r {
                indptr.push(indices.len());
                cur_row += 1;
            }
            // sum duplicates at (r, c)
            let mut v = 0.0f32;
            while i < sorted.len() && sorted[i].0 == r && sorted[i].1 == c {
                v += sorted[i].2;
                i += 1;
            }
            if v != 0.0 {
                indices.push(c);
                values.push(v);
            }
        }
        while cur_row < self.rows {
            indptr.push(indices.len());
            cur_row += 1;
        }
        CsrMatrix::from_raw(self.rows, self.cols, indptr, indices, values)
            .expect("COO->CSR conversion produced invalid structure")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_convert() {
        let mut m = CooMatrix::new(3, 3);
        m.push(0, 0, 1.0).unwrap();
        m.push(2, 1, 5.0).unwrap();
        m.push(1, 2, -2.0).unwrap();
        let csr = m.to_csr();
        assert_eq!(csr.nnz(), 3);
        assert_eq!(csr.get(0, 0), 1.0);
        assert_eq!(csr.get(2, 1), 5.0);
        assert_eq!(csr.get(1, 2), -2.0);
        assert_eq!(csr.get(1, 1), 0.0);
    }

    #[test]
    fn duplicates_summed_zeros_dropped() {
        let mut m = CooMatrix::new(2, 2);
        m.push(0, 0, 1.5).unwrap();
        m.push(0, 0, 2.5).unwrap();
        m.push(1, 1, 3.0).unwrap();
        m.push(1, 1, -3.0).unwrap();
        let csr = m.to_csr();
        assert_eq!(csr.get(0, 0), 4.0);
        assert_eq!(csr.nnz(), 1); // the cancelled entry is dropped
    }

    #[test]
    fn out_of_bounds_rejected() {
        let mut m = CooMatrix::new(2, 2);
        assert!(m.push(2, 0, 1.0).is_err());
        assert!(m.push(0, 2, 1.0).is_err());
    }

    #[test]
    fn empty_rows_have_empty_ranges() {
        let mut m = CooMatrix::new(4, 4);
        m.push(3, 3, 1.0).unwrap();
        let csr = m.to_csr();
        assert_eq!(csr.row_nnz(0), 0);
        assert_eq!(csr.row_nnz(1), 0);
        assert_eq!(csr.row_nnz(3), 1);
    }
}
