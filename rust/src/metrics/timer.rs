//! Wall-clock timing utilities for the bench harness and solver reports.

use std::time::{Duration, Instant};

/// Simple stopwatch with named laps.
#[derive(Debug)]
pub struct StopWatch {
    start: Instant,
    last: Instant,
    laps: Vec<(String, Duration)>,
}

impl Default for StopWatch {
    fn default() -> Self {
        Self::new()
    }
}

impl StopWatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Self { start: now, last: now, laps: Vec::new() }
    }

    /// Record a lap since the previous lap (or start).
    pub fn lap(&mut self, name: impl Into<String>) -> Duration {
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        self.laps.push((name.into(), d));
        d
    }

    /// Total elapsed since construction.
    pub fn total(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn laps(&self) -> &[(String, Duration)] {
        &self.laps
    }

    /// Sum of laps matching a name.
    pub fn lap_total(&self, name: &str) -> Duration {
        self.laps
            .iter()
            .filter(|(n, _)| n == name)
            .map(|(_, d)| *d)
            .sum()
    }
}

/// Summary statistics over repeated timing samples (bench harness).
#[derive(Debug, Clone)]
pub struct TimingStats {
    pub samples: Vec<f64>, // seconds
}

impl TimingStats {
    pub fn from_secs(samples: Vec<f64>) -> Self {
        Self { samples }
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(0.0, f64::max)
    }

    pub fn std_dev(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|s| (s - m).powi(2)).sum::<f64>()
            / (self.samples.len() - 1) as f64)
            .sqrt()
    }

    /// Linear-interpolated percentile (p in [0, 100]).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_laps_accumulate() {
        let mut sw = StopWatch::new();
        std::thread::sleep(Duration::from_millis(2));
        sw.lap("a");
        std::thread::sleep(Duration::from_millis(2));
        sw.lap("b");
        sw.lap("a");
        assert_eq!(sw.laps().len(), 3);
        assert!(sw.lap_total("a") >= Duration::from_millis(2));
        assert!(sw.total() >= Duration::from_millis(4));
    }

    #[test]
    fn stats_basic() {
        let s = TimingStats::from_secs(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.median() - 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.std_dev() - (2.5f64).sqrt()).abs() < 1e-12);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((s.percentile(100.0) - 5.0).abs() < 1e-12);
        assert!((s.percentile(25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stats_degenerate() {
        let e = TimingStats::from_secs(vec![]);
        assert_eq!(e.mean(), 0.0);
        assert_eq!(e.median(), 0.0);
        let one = TimingStats::from_secs(vec![7.0]);
        assert_eq!(one.median(), 7.0);
        assert_eq!(one.std_dev(), 0.0);
    }
}
