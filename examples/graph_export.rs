//! Figure 1 reproduction: export the Algorithm-1 computational graph
//! (J partitions, T epochs) as Graphviz DOT — structurally identical to
//! the Dask graph in the paper (which shows J=2, T=1).
//!
//! ```sh
//! cargo run --release --example graph_export -- [J] [T] [out.dot]
//! ```

use dapc::coordinator::TaskGraph;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let j: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(2);
    let t: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1);
    let graph = TaskGraph::algorithm1(j, t);

    println!(
        "Algorithm 1 task graph: J={j} partitions, T={t} epochs, {} tasks",
        graph.len()
    );
    let waves = graph.waves();
    println!("parallel schedule ({} waves):", waves.len());
    for (i, wave) in waves.iter().enumerate() {
        println!("  wave {i}: {} tasks", wave.len());
    }

    let dot = graph.to_dot();
    match args.get(2) {
        Some(path) => {
            std::fs::write(path, &dot).expect("write dot file");
            println!("wrote {path}");
        }
        None => {
            let out = "target/figure1.dot";
            std::fs::create_dir_all("target").ok();
            std::fs::write(out, &dot).expect("write dot file");
            println!("wrote {out} (render with: dot -Tpng {out} -o figure1.png)");
        }
    }
}
