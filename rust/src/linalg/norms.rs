//! Vector norms and error metrics (MSE [23], MAE [25] from the paper).

/// Euclidean norm with f64 accumulation.
pub fn norm2(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

/// Mean squared error between two equal-length vectors (Fig. 2 y-axis).
pub fn mse(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    if x.is_empty() {
        return 0.0;
    }
    x.iter()
        .zip(y)
        .map(|(a, b)| {
            let d = (*a as f64) - (*b as f64);
            d * d
        })
        .sum::<f64>()
        / x.len() as f64
}

/// Mean absolute error (paper §5 uses MAE between the initial solution and
/// the one-iteration solution).
pub fn mae(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    if x.is_empty() {
        return 0.0;
    }
    x.iter()
        .zip(y)
        .map(|(a, b)| ((*a as f64) - (*b as f64)).abs())
        .sum::<f64>()
        / x.len() as f64
}

/// Sample mean.
pub fn mean(x: &[f32]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().map(|&v| v as f64).sum::<f64>() / x.len() as f64
}

/// Population standard deviation (the paper reports mu/sigma of datasets
/// and solutions in §5).
pub fn std_dev(x: &[f32]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    let m = mean(x);
    (x.iter().map(|&v| (v as f64 - m).powi(2)).sum::<f64>() / x.len() as f64)
        .sqrt()
}

/// Max absolute entry.  NaN entries propagate: `f32::max` silently
/// discards NaN operands, so the old fold reported an all-NaN iterate as
/// `max_abs == 0.0` — a poisoned solve would sail straight through every
/// residual and convergence check instead of failing it loudly.
pub fn max_abs(x: &[f32]) -> f32 {
    let mut m = 0.0f32;
    for &v in x {
        if v.is_nan() {
            return f32::NAN;
        }
        m = m.max(v.abs());
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norm2_pythagorean() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(norm2(&[]), 0.0);
    }

    #[test]
    fn mse_mae_basic() {
        let x = [1.0f32, 2.0, 3.0];
        let y = [1.0f32, 0.0, 6.0];
        assert!((mse(&x, &y) - (0.0 + 4.0 + 9.0) / 3.0).abs() < 1e-12);
        assert!((mae(&x, &y) - (0.0 + 2.0 + 3.0) / 3.0).abs() < 1e-12);
        assert_eq!(mse(&x, &x), 0.0);
        assert_eq!(mse(&[], &[]), 0.0);
    }

    #[test]
    fn mean_std() {
        let x = [2.0f32, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&x) - 5.0).abs() < 1e-12);
        assert!((std_dev(&x) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn max_abs_signs() {
        assert_eq!(max_abs(&[-3.0, 2.0, 1.0]), 3.0);
        assert_eq!(max_abs(&[]), 0.0);
    }

    #[test]
    fn max_abs_propagates_nan() {
        // an all-NaN vector used to report 0.0 — "converged"
        assert!(max_abs(&[f32::NAN, f32::NAN, f32::NAN]).is_nan());
        // one poisoned entry is enough, wherever it sits
        assert!(max_abs(&[1.0, f32::NAN, 3.0]).is_nan());
        assert!(max_abs(&[f32::NAN, 1.0]).is_nan());
        assert!(max_abs(&[1.0, f32::NAN]).is_nan());
        // non-NaN specials are ordinary magnitudes
        assert_eq!(max_abs(&[f32::NEG_INFINITY, 1.0]), f32::INFINITY);
    }
}
