"""Pure-HLO dense linear algebra (Layer 2 substrate).

The rust runtime executes AOT HLO on the ``xla`` crate's PJRT CPU client
(xla_extension 0.5.1).  That client has *no* LAPACK custom-call targets, so
``jnp.linalg.qr`` / ``solve_triangular`` / ``inv`` — which jax lowers to
``lapack_*`` custom-calls — cannot appear in any exported artifact.  This
module re-implements the three primitives the paper needs using only basic
lax ops (dot, while-loop, select), so the lowered HLO is portable:

* :func:`householder_qr` — reduced QR ``A = Q1 R`` (paper eq. (1)),
* :func:`back_substitution` — upper-triangular solve (paper eqs. (2)-(3)),
* :func:`forward_substitution` — lower-triangular solve (fat regime),
* :func:`gauss_jordan_inverse` — the O(n^3) inverse the *classical* APC
  baseline pays for (paper §2 complexity argument).

Everything is shape-polymorphic in python but lowers to static shapes at AOT
time (see ``aot.py``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "householder_qr",
    "apply_reflectors",
    "back_substitution",
    "forward_substitution",
    "gauss_jordan_inverse",
]


def _house_vector(x: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """Householder reflector v for column x, acting on rows >= k.

    Returns unit-norm v with rows < k zeroed; H = I - 2 v v^T maps the
    masked x onto alpha * e_k.
    """
    l = x.shape[0]
    rows = jnp.arange(l)
    mask = rows >= k
    xm = jnp.where(mask, x, 0.0)
    sigma = jnp.sqrt(jnp.sum(xm * xm))
    xk = x[k]
    # sign convention avoiding cancellation: alpha = -sign(x_k) * ||x||.
    alpha = -jnp.where(xk >= 0.0, 1.0, -1.0) * sigma
    v = xm - alpha * (rows == k).astype(x.dtype)
    vnorm = jnp.sqrt(jnp.sum(v * v))
    # Guard: if the column is already zero below k, use a null reflector.
    safe = vnorm > 1e-30
    v = jnp.where(safe, v / jnp.where(safe, vnorm, 1.0), 0.0)
    return v


def householder_qr(a: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Reduced (economy) QR of a tall matrix ``a`` of shape (l, n), l >= n.

    Returns (q1, r) with q1: (l, n) semi-orthogonal, r: (n, n) upper
    triangular, ``a ~= q1 @ r`` (paper eq. (1)).  Implemented as n
    Householder steps inside a fori_loop; only lax ops, no custom calls.
    """
    l, n = a.shape
    dtype = a.dtype

    def step(k, state):
        r, vs = state
        v = _house_vector(r[:, k], k)
        # R <- R - 2 v (v^T R)
        vtr = v @ r  # (n,)
        r = r - 2.0 * jnp.outer(v, vtr)
        vs = vs.at[k].set(v)
        return r, vs

    r_full, vs = lax.fori_loop(
        0, n, step, (a, jnp.zeros((n, l), dtype=dtype))
    )
    # Zero out rounding noise below the diagonal and truncate to (n, n).
    rows = jnp.arange(n)[:, None]
    cols = jnp.arange(n)[None, :]
    r = jnp.where(rows <= cols, r_full[:n, :n], 0.0)

    # Q1 = H_0 ... H_{n-1} E  with E = first n columns of I_l.
    e = jnp.eye(l, n, dtype=dtype)

    def apply_back(i, q):
        k = n - 1 - i
        v = vs[k]
        return q - 2.0 * jnp.outer(v, v @ q)

    q1 = lax.fori_loop(0, n, apply_back, e)
    return q1, r


def apply_reflectors(vs: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Apply Q^T = H_{n-1} ... H_0 to a vector b (length l)."""
    n = vs.shape[0]

    def step(k, y):
        v = vs[k]
        return y - 2.0 * v * (v @ y)

    return lax.fori_loop(0, n, step, b)


def back_substitution(r: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Solve R x = c for upper-triangular R in O(n^2) (paper eqs. (2)-(3)).

    x_n = c_n / r_nn, then x_p = (c_p - sum_{k>p} r_pk x_k) / r_pp,
    p = n-1, ..., 1 — the backward-substitution decomposition the paper uses
    in place of inverting R.
    """
    n = r.shape[0]

    def step(i, x):
        p = n - 1 - i
        # entries of x at indices <= p are still zero, so a full dot works.
        s = r[p] @ x
        xp = (c[p] - s) / r[p, p]
        return x.at[p].set(xp)

    return lax.fori_loop(0, n, step, jnp.zeros_like(c))


def forward_substitution(lo: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Solve L x = c for lower-triangular L in O(n^2) (fat-regime init)."""
    n = lo.shape[0]

    def step(p, x):
        s = lo[p] @ x
        xp = (c[p] - s) / lo[p, p]
        return x.at[p].set(xp)

    return lax.fori_loop(0, n, step, jnp.zeros_like(c))


def gauss_jordan_inverse(a: jnp.ndarray) -> jnp.ndarray:
    """Invert a square matrix via Gauss-Jordan with partial pivoting.

    This is the O(n^3) elimination the paper's *classical* APC baseline
    relies on ([18] in the paper); kept as a pure-HLO artifact so the
    classical/decomposed comparison (Table 1) can run entirely on the rust
    PJRT hot path.
    """
    n = a.shape[0]
    dtype = a.dtype
    aug = jnp.concatenate([a, jnp.eye(n, dtype=dtype)], axis=1)  # (n, 2n)
    rows = jnp.arange(n)

    def step(k, aug):
        # partial pivot: argmax |aug[i, k]| over i >= k
        col = jnp.where(rows >= k, jnp.abs(aug[:, k]), -1.0)
        p = jnp.argmax(col)
        # swap rows k and p via gather-free select
        rk, rp = aug[k], aug[p]
        aug = aug.at[k].set(rp).at[p].set(rk)
        piv = aug[k, k]
        rowk = aug[k] / piv
        factors = aug[:, k]
        aug = aug - jnp.outer(factors, rowk)
        aug = aug.at[k].set(rowk)
        return aug

    aug = lax.fori_loop(0, n, step, aug)
    return aug[:, n:]
