//! Cold-registration scaling: `SessionBackend::register_matrix` wall
//! time (densify every partition + panel-blocked QR factorization of
//! each block) on the sequential `NativeEngine` vs the `ParallelEngine`
//! at 2/4/8 threads.
//!
//! Registration is the dominant cost a `SolverSession` pays (PR 3): the
//! per-partition factorization is O(l n^2) while every later right-hand
//! side is served at O(l n + n^2) + epochs.  The panel-blocked QR makes
//! that cold phase scale with `--threads`: partitions factorize
//! concurrently, and when partitions are scarcer than pool workers each
//! factorization fans its trailing updates over the whole pool instead.
//! The trailing sweeps run through the packed gemm microkernel and the
//! in-panel reflector applications fan over the pool too (the previously
//! serial O(l·PANEL²) per panel) — this bench is the scaling gate for
//! both: the 4-thread assert below fails if either path stops paying.
//!
//! The bench asserts that cold-register wall time strictly improves from
//! the sequential engine to 4 threads, and that every engine registers
//! bit-identical state (one warm solve per engine compared against the
//! sequential session's).  Results go to `BENCH_register_scaling.json`.

use dapc::benchkit::{quick_mode, Bench, BenchResult, JsonReport};
use dapc::parallel::default_threads;
use dapc::prelude::*;
use dapc::rng::seeded;
use dapc::solver::{
    ApcVariant, ComputeEngine, InProcessBackend, InitKind, SessionBackend,
};
use dapc::sparse::generate::GeneratorConfig;

/// Time registration alone: partition densify + factorize_all, the
/// exact cold cost a session pays before it can serve.
fn register_bench<E: ComputeEngine>(
    bench: &Bench,
    name: &str,
    engine: &E,
    a: &CsrMatrix,
    plan: &PartitionPlan,
) -> BenchResult {
    bench.run(name, || {
        let mut backend = InProcessBackend::new(engine, plan.j());
        backend
            .register_matrix(InitKind::Qr, plan, a)
            .expect("register");
    })
}

/// One warm solve through a fresh session — the registered state's
/// fingerprint (untimed; used to prove engine-independence bit for bit).
fn warm_solve<E: ComputeEngine>(
    engine: &E,
    a: &CsrMatrix,
    b: &[f32],
    j: usize,
    opts: &SolveOptions,
) -> Vec<f32> {
    let mut backend = InProcessBackend::new(engine, j);
    let mut session = SolverSession::register(
        &mut backend,
        a.clone(),
        SessionAlgorithm::Apc(ApcVariant::Decomposed),
        opts.clone(),
    )
    .expect("session register");
    session.solve(b).expect("warm solve").xbar
}

fn main() {
    let n = if quick_mode() { 192 } else { 320 };
    let m = 12 * n;
    let j = 8usize;
    let shape = format!("{m}x{n}");
    let ds = GeneratorConfig::table1(m, n).generate(1413);
    let plan = PartitionPlan::contiguous(m, n, j).expect("plan");
    let opts = SolveOptions { epochs: 5, ..Default::default() };
    let bench = Bench::default();
    let mut report = JsonReport::new("register_scaling");

    // one consistent rhs: the registered state's warm solve must be
    // engine-independent bit for bit
    let b = {
        let mut g = seeded(77);
        let x: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
        let mut b = vec![0.0f32; m];
        ds.matrix.spmv_into(&x, &mut b);
        b
    };

    println!(
        "=== cold-register scaling: {shape}, J = {j} partitions, threads \
         {{1 (native), 2, 4, 8}} ==="
    );

    let native = NativeEngine::new();
    let seq = register_bench(
        &bench,
        "register sequential (native)",
        &native,
        &ds.matrix,
        &plan,
    );
    let seq_s = seq.stats.mean();
    report.add(
        &seq,
        &[("threads", 1.0), ("j", j as f64)],
        &[("shape", shape.as_str()), ("engine", "native")],
    );
    let seq_xbar = warm_solve(&native, &ds.matrix, &b, j, &opts);

    let mut mean_at_4 = f64::INFINITY;
    for &t in &[2usize, 4, 8] {
        let engine = ParallelEngine::new(t);
        let res = register_bench(
            &bench,
            &format!("register threads={t}"),
            &engine,
            &ds.matrix,
            &plan,
        );
        let speedup = seq_s / res.stats.mean();
        println!("  -> threads={t}: speedup {speedup:.2}x");
        report.add(
            &res,
            &[
                ("threads", t as f64),
                ("j", j as f64),
                ("speedup_vs_sequential", speedup),
            ],
            &[("shape", shape.as_str()), ("engine", "parallel")],
        );
        if t == 4 {
            mean_at_4 = res.stats.mean();
        }
        // registration must leave engine-independent state: a warm solve
        // through the parallel-registered session is bit-identical to
        // the sequential one
        let xbar = warm_solve(&engine, &ds.matrix, &b, j, &opts);
        assert!(
            xbar == seq_xbar,
            "parallel registration diverged from sequential at t={t}"
        );
    }

    // the acceptance gate: strict improvement sequential -> 4 threads.
    // Only meaningful where 4 hardware threads exist — on a starved 1-2
    // core runner the premise is unmeetable, not a code defect.
    if default_threads() >= 4 {
        assert!(
            mean_at_4 < seq_s,
            "cold register at 4 threads ({mean_at_4:.4}s) must strictly \
             beat the sequential engine ({seq_s:.4}s): parallel \
             factorization is broken"
        );
    } else {
        println!(
            "(skipping strict 4-thread assert: only {} hardware threads)",
            default_threads()
        );
    }

    match report.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
}
