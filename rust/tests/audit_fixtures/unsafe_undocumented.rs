// Seeded violation: an `unsafe` block with no SAFETY comment in the
// contiguous comment/attribute block above it (the blank line below
// breaks the chain).  Under a pretend non-kernel path the rule fires on
// confinement; under the pretend simd.rs path it fires on the missing
// documentation.
pub fn first_byte(v: &[u8]) -> u8 {
    assert!(!v.is_empty());

    unsafe { *v.as_ptr() }
}
