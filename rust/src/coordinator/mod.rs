//! Distributed coordinator — the Dask-cluster substrate of the paper's
//! pipeline, rebuilt as a Rust leader/worker runtime.
//!
//! * [`message`] — the wire protocol (hand-framed binary; no serde);
//! * [`transport`] — in-process channels and TCP streams behind one trait;
//! * [`worker`] — the worker loop: owns its partition, its projector and
//!   its estimate; only n-length vectors ever cross the wire (the paper's
//!   key communication property: `P_j` never leaves the worker);
//! * [`leader`] — drives Algorithm 1 across workers and aggregates;
//! * [`cluster`] — spawn helpers for local (threaded) and TCP clusters;
//! * [`graph`] — the lazy task-graph representation + DOT export
//!   (reproduces the paper's Figure 1).

pub mod cluster;
pub mod graph;
pub mod leader;
pub mod message;
pub mod transport;
pub mod worker;

pub use cluster::LocalCluster;
pub use graph::TaskGraph;
pub use leader::Leader;
pub use message::Message;
