//! Solvers: the paper's decomposed APC, the classical APC baseline and
//! distributed gradient descent, all generic over a [`ComputeEngine`]
//! (native Rust linalg or AOT HLO artifacts on PJRT).
//!
//! # Architecture: one driver, many backends
//!
//! The epoch loop of Algorithm 1 exists exactly once, in [`driver`]:
//!
//! ```text
//!   drive_apc / drive_dgd            (eq. (7) mixing, tracing, timing,
//!        |                            SolveReport assembly)
//!        v
//!   ConsensusBackend  ---- InProcessBackend  -> ComputeEngine
//!                     \                         (native | parallel | xla)
//!                      --- ClusterBackend    -> Vec<Transport> -> workers
//!                          (crate::coordinator)
//! ```
//!
//! [`InProcessBackend`] executes partitions on an engine in this process
//! through the allocation-free `round_into`/[`RoundWorkspace`] path;
//! `coordinator::ClusterBackend` scatters them over message transports.
//! Both produce bit-identical iterates (`tests/distributed_equivalence`),
//! so any new algorithm variant written against the driver runs unchanged
//! from a laptop to a cluster.

mod consensus;
mod dgd;
pub mod driver;
pub(crate) mod engine;
mod report;

pub use consensus::{ApcClassicalSolver, ApcVariant, DapcSolver};
pub use dgd::DgdSolver;
pub use driver::{
    auto_dgd_step, drive_apc, drive_apc_epochs_multi, drive_dgd,
    drive_dgd_epochs_multi, init_kind_for, ConsensusBackend,
    InProcessBackend, RequestId, RoundOutcome, SessionBackend, SessionId,
};
pub use engine::{
    resident_partition_bytes, ComputeEngine, InitKind, NativeEngine,
    RoundWorkspace, SeedFactors, WorkerFactorization, WorkerInit, XlaEngine,
};
pub use report::{residual_norm, SolveOptions, SolveReport};

pub use crate::parallel::ParallelEngine;

use crate::error::Result;
use crate::sparse::CsrMatrix;

/// Common interface over all three algorithms.
pub trait Solver {
    /// Solve `A x = b` split into `j` partitions, returning the averaged
    /// solution and run metadata.
    fn solve<E: ComputeEngine>(
        &self,
        engine: &E,
        a: &CsrMatrix,
        b: &[f32],
        j: usize,
    ) -> Result<SolveReport>;

    /// Human-readable name for reports/tables.
    fn name(&self) -> &'static str;
}
