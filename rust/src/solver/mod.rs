//! Solvers: the paper's decomposed APC, the classical APC baseline and
//! distributed gradient descent, all generic over a [`ComputeEngine`]
//! (native Rust linalg or AOT HLO artifacts on PJRT).
//!
//! The single-process path lives here (used by benches and most examples);
//! the multi-worker leader/worker path in [`crate::coordinator`] reuses
//! the same engines and produces identical iterates.

mod consensus;
mod dgd;
pub(crate) mod engine;
mod report;

pub use consensus::{ApcClassicalSolver, ApcVariant, DapcSolver};
pub use dgd::DgdSolver;
pub use engine::{
    ComputeEngine, InitKind, NativeEngine, RoundWorkspace, WorkerInit,
    XlaEngine,
};
pub use report::{residual_norm, SolveOptions, SolveReport};

pub use crate::parallel::ParallelEngine;

use crate::error::Result;
use crate::sparse::CsrMatrix;

/// Common interface over all three algorithms.
pub trait Solver {
    /// Solve `A x = b` split into `j` partitions, returning the averaged
    /// solution and run metadata.
    fn solve<E: ComputeEngine>(
        &self,
        engine: &E,
        a: &CsrMatrix,
        b: &[f32],
        j: usize,
    ) -> Result<SolveReport>;

    /// Human-readable name for reports/tables.
    fn name(&self) -> &'static str;
}
