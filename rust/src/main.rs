//! `dapc` CLI — leader entrypoint for the DAPC system.
//!
//! Subcommands:
//!   solve          run a solver on a dataset (MatrixMarket or synthetic)
//!   serve          multi-tenant solve server smoke: register --sessions
//!                  matrices into a SessionManager, stream interleaved
//!                  right-hand sides from concurrent clients (in-process
//!                  channels, or real sockets with --tcp), verify every
//!                  reply bitwise against isolated reference sessions;
//!                  --max-resident-bytes exercises LRU eviction and
//!                  --queue-depth the Busy backpressure path
//!   worker         serve a TCP worker (multi-process cluster)
//!   graph          export the Algorithm-1 task graph as Graphviz DOT
//!   info           list available AOT artifacts
//!   generate       write a synthetic Schenk-like dataset to MatrixMarket files
//!   kernels        report the runtime-dispatched kernel backend, the active
//!                  f32 kernel tier, and the gemm tiling constants (CI logs
//!                  this on every leg of the dispatch matrix)
//!   bench-validate check BENCH_*.json bench artifacts parse and are non-hollow
//!   metrics-validate  check METRICS_*.json telemetry dumps parse, are
//!                  non-hollow and internally consistent
//!   audit          static determinism/unsafety analysis over the repo's own
//!                  sources (six named rules; see CONTRIBUTING.md "The
//!                  determinism contract, statically"); `--ci` exits nonzero
//!                  on any unsuppressed finding
//!
//! Any command that does work accepts `--metrics-json PATH`: after a
//! successful run the process-global metrics registry (latency
//! histograms, counters, gauges — including imported `cluster.w*`
//! worker telemetry on distributed runs) is written as a validated JSON
//! artifact and summarized on stdout.  Recording defaults to on; set
//! `DAPC_METRICS=off` to prove the zero-instrumentation path.

use std::path::{Path, PathBuf};

use dapc::cli::{self, OptSpec};
use dapc::config::{Algorithm, EngineKind, RunConfig};
use dapc::coordinator::cluster;
use dapc::coordinator::TaskGraph;
use dapc::error::{DapcError, Result};
use dapc::linalg::norms;
use dapc::linalg::simd::KernelTier;
use dapc::runtime::executor::XlaExecutorHost;
use dapc::service::{
    serve_connections, ClientReply, ServeOptions, SessionAlgorithm,
    SessionConfig, SessionManager, SolveClient, SolverSession,
};
use dapc::solver::{
    drive_apc, drive_dgd, ApcClassicalSolver, ApcVariant, ComputeEngine,
    DapcSolver, DgdSolver, InProcessBackend, NativeEngine, ParallelEngine,
    SessionBackend, SolveOptions, Solver, XlaEngine,
};
use dapc::sparse::{generate::GeneratorConfig, matrix_market, CsrMatrix};

fn specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "config", help: "JSON config file", takes_value: true },
        OptSpec { name: "algorithm", help: "dapc|apc|dgd", takes_value: true },
        OptSpec { name: "engine", help: "native|xla", takes_value: true },
        OptSpec { name: "partitions", help: "number of partitions J", takes_value: true },
        OptSpec { name: "threads", help: "native-engine worker threads (1 = sequential, 0 = auto)", takes_value: true },
        OptSpec { name: "kernel-tier", help: "deterministic|fast f32 kernel tier (default: DAPC_KERNEL_TIER env; in-process native engines only)", takes_value: true },
        OptSpec { name: "epochs", help: "consensus epochs T", takes_value: true },
        OptSpec { name: "eta", help: "mixing weight (0,1]", takes_value: true },
        OptSpec { name: "gamma", help: "projection step (0,1]", takes_value: true },
        OptSpec { name: "matrix", help: "MatrixMarket coefficient matrix", takes_value: true },
        OptSpec { name: "rhs", help: "MatrixMarket rhs vector", takes_value: true },
        OptSpec { name: "synth-n", help: "synthetic problem size n", takes_value: true },
        OptSpec { name: "seed", help: "synthetic data seed", takes_value: true },
        OptSpec { name: "artifacts", help: "artifact directory", takes_value: true },
        OptSpec { name: "distributed", help: "run over a local worker cluster", takes_value: false },
        OptSpec { name: "serve-rhs", help: "solve-service mode: register the matrix once, stream K generated right-hand sides", takes_value: true },
        OptSpec { name: "sessions", help: "serve: number of tenant matrices to register (default 2)", takes_value: true },
        OptSpec { name: "max-resident-bytes", help: "serve: resident-memory cap across live sessions; LRU sessions are evicted (and transparently re-factorized) to stay under it", takes_value: true },
        OptSpec { name: "queue-depth", help: "serve: bounded request-queue depth; a full queue answers Busy (default 8)", takes_value: true },
        OptSpec { name: "tcp", help: "serve: run client connections over real loopback sockets instead of in-process channels", takes_value: false },
        OptSpec { name: "workers", help: "comma-separated worker addrs (TCP leader)", takes_value: true },
        OptSpec { name: "listen", help: "worker listen address", takes_value: true },
        OptSpec { name: "out", help: "output path (graph/generate)", takes_value: true },
        OptSpec { name: "trace", help: "print per-epoch MSE (synthetic only)", takes_value: false },
        OptSpec { name: "metrics-json", help: "write the metrics registry (latency histograms, wire counters) to this JSON path after the run", takes_value: true },
        OptSpec { name: "ci", help: "audit: exit nonzero on any unsuppressed finding", takes_value: false },
        OptSpec { name: "json", help: "audit: also write the findings as JSON to this path", takes_value: true },
        OptSpec { name: "root", help: "audit: repo root to scan (default: nearest ancestor of the cwd containing rust/src)", takes_value: true },
        OptSpec { name: "help", help: "show usage", takes_value: false },
    ]
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<()> {
    let specs = specs();
    let parsed = cli::parse(args, &specs)?;
    if parsed.has_flag("help") || parsed.command.is_none() {
        println!(
            "dapc — Distributed Accelerated Projection-Based Consensus Decomposition\n\n\
             usage: dapc <solve|serve|worker|graph|info|generate|kernels\
             |bench-validate|metrics-validate|audit> [options]\n\n{}",
            cli::usage(&specs)
        );
        return Ok(());
    }
    if parsed.get("metrics-json").is_some() {
        // an explicit dump request overrides DAPC_METRICS=off: a knowingly
        // hollow artifact would just fail metrics-validate downstream
        dapc::obs::set_enabled(true);
    }
    match parsed.command.as_deref().unwrap() {
        "solve" => cmd_solve(&parsed),
        "serve" => cmd_serve_multi(&parsed),
        "worker" => cmd_worker(&parsed),
        "graph" => cmd_graph(&parsed),
        "info" => cmd_info(&parsed),
        "generate" => cmd_generate(&parsed),
        "kernels" => cmd_kernels(),
        "bench-validate" => cmd_bench_validate(&parsed),
        "metrics-validate" => cmd_metrics_validate(&parsed),
        "audit" => cmd_audit(&parsed),
        other => Err(DapcError::Parse(format!(
            "unknown command {other:?} (expected \
             solve|serve|worker|graph|info|generate|kernels|bench-validate\
             |metrics-validate|audit)"
        ))),
    }?;
    if let Some(path) = parsed.get("metrics-json") {
        dump_metrics(Path::new(path))?;
    }
    Ok(())
}

/// `dapc metrics-validate FILE...`: fail loudly if any metrics JSON dump
/// is missing, unparseable, hollow, or internally inconsistent (quantile
/// ordering, bucket/count mismatches, the served-RHS cross-check).
fn cmd_metrics_validate(parsed: &cli::ParsedArgs) -> Result<()> {
    if parsed.positionals.is_empty() {
        return Err(DapcError::Config(
            "metrics-validate needs one or more METRICS_*.json paths".into(),
        ));
    }
    let mut total = 0usize;
    for p in &parsed.positionals {
        let n = dapc::obs::export::validate_metrics_file(Path::new(p))
            .map_err(|e| DapcError::Parse(format!("{p}: {e}")))?;
        println!("OK {p} ({n} metrics)");
        total += n;
    }
    println!("{} file(s) valid, {total} metrics", parsed.positionals.len());
    Ok(())
}

/// Write the process-global registry as a JSON artifact (the shape
/// `metrics-validate` checks) and print the human summary table.
fn dump_metrics(path: &Path) -> Result<()> {
    let reg = dapc::obs::global();
    std::fs::write(path, reg.render_json())?;
    let table = reg.render_table();
    if !table.is_empty() {
        println!("{table}");
    }
    println!("wrote metrics to {}", path.display());
    Ok(())
}

/// Pull each worker's registry snapshot over the wire (v4
/// `StatsRequest`/`StatsReport`), import every entry into this process's
/// registry as a `cluster.w{id}.{name}` gauge (so one `--metrics-json`
/// dump carries leader and worker telemetry side by side), and print a
/// per-worker summary table.
fn collect_cluster_telemetry<T: dapc::coordinator::transport::Transport>(
    leader: &mut dapc::coordinator::Leader<T>,
) -> Result<()> {
    if !dapc::obs::enabled() {
        return Ok(());
    }
    let reports = leader.collect_worker_stats()?;
    let mut tb = dapc::metrics::TableBuilder::new(&[
        "worker",
        "frames",
        "update_p99_ns",
        "seed_p99_ns",
    ]);
    for (wid, stats) in &reports {
        for (name, v) in stats {
            dapc::obs::gauge(&format!("cluster.w{wid}.{name}")).set(*v);
        }
        let get = |key: &str| {
            stats.iter().find(|(n, _)| n == key).map(|(_, v)| *v)
        };
        let cell = |v: Option<f64>| {
            v.map(|v| format!("{v:.0}")).unwrap_or_else(|| "-".into())
        };
        tb.row(&[
            format!("w{wid}"),
            cell(get("worker.frames")),
            cell(get("worker.update_ns.p99")),
            cell(get("worker.seed_ns.p99")),
        ]);
    }
    println!("worker telemetry ({} workers):", reports.len());
    print!("{}", tb.render());
    Ok(())
}

/// `dapc kernels`: which SIMD kernel backend and kernel tier this
/// process would run, plus the blocking constants and thread count — the
/// full configuration a bench artifact should be attributed to.  CI runs
/// this on every leg of the dispatch matrix so the log records the
/// detected CPU features next to each test run.
fn cmd_kernels() -> Result<()> {
    use dapc::linalg::{blas, qr, simd};
    use dapc::config::envvars;
    println!("kernel backend: {}", simd::description());
    println!("  avx2+fma detected: {}", simd::avx2_available());
    println!(
        "  lane contract: {} fixed f64 accumulator lanes, shared reduction \
         tree — dispatch never changes output bits",
        simd::LANES
    );
    println!("kernel tier: {}", simd::tier_description());
    println!(
        "env registry ({} DAPC_* variables; all reads go through \
         config::envvars):",
        envvars::REGISTRY.len()
    );
    for ((name, value), var) in
        envvars::snapshot().iter().zip(envvars::REGISTRY.iter())
    {
        println!("  {name:<18} = {value:<12} [default: {}]", var.default);
        println!("  {:<18}   {}", "", var.help);
    }
    println!(
        "tiling: MR={} NR={} MC={} KC={} NC={} PANEL={}",
        simd::MR,
        simd::NR,
        blas::MC,
        blas::KC,
        blas::NC,
        qr::PANEL
    );
    println!(
        "threads: {} (pool default; --threads overrides per run)",
        dapc::parallel::default_threads()
    );
    println!(
        "resident factorization (per registered partition, l x n block): \
         l*n + n*n f32 + packed_a_len(n, n) f32 panels + seed factors"
    );
    for (label, kind, l, n) in [
        ("qr 4096x1024", dapc::solver::InitKind::Qr, 4096usize, 1024usize),
        ("classical 4096x1024", dapc::solver::InitKind::Classical, 4096, 1024),
        ("fat 256x1024", dapc::solver::InitKind::Fat, 256, 1024),
    ] {
        println!(
            "  e.g. {label}: {} B",
            dapc::solver::resident_partition_bytes(kind, l, n)
        );
    }
    Ok(())
}

/// `dapc audit [--ci] [--json PATH] [--root DIR]`: run the static
/// determinism/unsafety pass (`dapc::audit`) over `rust/src`,
/// `rust/tests`, and `benches`.  Prints findings as `file:line: [rule]`,
/// optionally writes them as JSON, and with `--ci` turns any
/// unsuppressed finding into a nonzero exit — the gate CI runs on every
/// leg of the dispatch matrix.
fn cmd_audit(parsed: &cli::ParsedArgs) -> Result<()> {
    let root = match parsed.get("root") {
        Some(r) => PathBuf::from(r),
        None => audit_default_root()?,
    };
    let report = dapc::audit::audit_root(&root)?;
    for f in &report.findings {
        println!("{}", f.render());
    }
    println!(
        "audit: {} file(s) scanned under {}, {} finding(s), {} suppressed",
        report.files_scanned,
        root.display(),
        report.findings.len(),
        report.suppressed
    );
    if let Some(path) = parsed.get("json") {
        std::fs::write(path, dapc::audit::render_json(&report))?;
        println!("wrote audit report to {path}");
    }
    if parsed.has_flag("ci") && !report.clean() {
        return Err(DapcError::Config(format!(
            "audit --ci: {} unsuppressed finding(s)",
            report.findings.len()
        )));
    }
    Ok(())
}

/// Nearest ancestor of the working directory that contains `rust/src` —
/// works from the workspace root (`cargo run`) and from the package dir
/// (`rust/`, where cargo puts test/bench cwd).
fn audit_default_root() -> Result<PathBuf> {
    let start = std::env::current_dir()?;
    let mut dir = start.as_path();
    loop {
        if dir.join("rust/src").is_dir() {
            return Ok(dir.to_path_buf());
        }
        match dir.parent() {
            Some(p) => dir = p,
            None => {
                return Err(DapcError::Config(format!(
                    "audit: no ancestor of {} contains rust/src (pass \
                     --root)",
                    start.display()
                )))
            }
        }
    }
}

/// Parse `--kernel-tier` into the [`SolveOptions::kernel_tier`] override
/// (None = inherit the `DAPC_KERNEL_TIER` process default).
fn parse_kernel_tier(parsed: &cli::ParsedArgs) -> Result<Option<KernelTier>> {
    match parsed.get("kernel-tier") {
        None => Ok(None),
        Some("deterministic") => Ok(Some(KernelTier::Deterministic)),
        Some("fast") => Ok(Some(KernelTier::Fast)),
        Some(other) => Err(DapcError::Config(format!(
            "--kernel-tier expects deterministic|fast, got {other:?}"
        ))),
    }
}

/// `dapc bench-validate FILE...`: fail loudly if any bench JSON artifact
/// is missing, unparseable, or hollow (no records / broken keys).
fn cmd_bench_validate(parsed: &cli::ParsedArgs) -> Result<()> {
    if parsed.positionals.is_empty() {
        return Err(DapcError::Config(
            "bench-validate needs one or more BENCH_*.json paths".into(),
        ));
    }
    let mut total = 0usize;
    for p in &parsed.positionals {
        let n = dapc::benchkit::validate_report_file(Path::new(p))
            .map_err(|e| DapcError::Parse(format!("{p}: {e}")))?;
        println!("OK {p} ({n} records)");
        total += n;
    }
    println!("{} file(s) valid, {total} records", parsed.positionals.len());
    Ok(())
}

fn build_config(parsed: &cli::ParsedArgs) -> Result<RunConfig> {
    let mut cfg = match parsed.get("config") {
        Some(path) => RunConfig::from_json_file(Path::new(path))?,
        None => RunConfig::default(),
    };
    if let Some(a) = parsed.get("algorithm") {
        cfg.algorithm = Algorithm::parse(a)?;
    }
    if let Some(e) = parsed.get("engine") {
        cfg.engine = EngineKind::parse(e)?;
    }
    if let Some(v) = parsed.get_parse::<usize>("partitions")? {
        cfg.partitions = v;
    }
    if let Some(v) = parsed.get_parse::<usize>("threads")? {
        cfg.threads = v;
    }
    if let Some(v) = parsed.get_parse::<usize>("epochs")? {
        cfg.epochs = v;
    }
    if let Some(v) = parsed.get_parse::<f32>("eta")? {
        cfg.eta = v;
    }
    if let Some(v) = parsed.get_parse::<f32>("gamma")? {
        cfg.gamma = v;
    }
    if let Some(v) = parsed.get("matrix") {
        cfg.matrix_path = Some(PathBuf::from(v));
    }
    if let Some(v) = parsed.get("rhs") {
        cfg.rhs_path = Some(PathBuf::from(v));
    }
    if let Some(v) = parsed.get_parse::<usize>("synth-n")? {
        cfg.synth_n = v;
    }
    if let Some(v) = parsed.get_parse::<u64>("seed")? {
        cfg.seed = v;
    }
    if let Some(v) = parsed.get("artifacts") {
        cfg.artifacts_dir = PathBuf::from(v);
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Load the dataset: MatrixMarket pair or synthetic Schenk-like system.
fn load_data(cfg: &RunConfig) -> Result<(CsrMatrix, Vec<f32>, Option<Vec<f32>>)> {
    match (&cfg.matrix_path, &cfg.rhs_path) {
        (Some(mp), Some(rp)) => {
            let a = matrix_market::read_matrix(mp)?;
            let b = matrix_market::read_vector(rp)?;
            if b.len() != a.rows() {
                return Err(DapcError::Shape(format!(
                    "rhs length {} != matrix rows {}",
                    b.len(),
                    a.rows()
                )));
            }
            Ok((a, b, None))
        }
        _ => {
            let ds = GeneratorConfig::schenk_like(cfg.synth_n)
                .try_generate(cfg.seed)?;
            println!(
                "synthetic dataset: {}x{} ({} nnz, {:.2}% sparse)",
                ds.matrix.rows(),
                ds.matrix.cols(),
                ds.matrix.nnz(),
                ds.matrix.sparsity_pct()
            );
            Ok((ds.matrix, ds.rhs, Some(ds.x_true)))
        }
    }
}

fn cmd_solve(parsed: &cli::ParsedArgs) -> Result<()> {
    let cfg = build_config(parsed)?;
    let (a, b, x_true) = load_data(&cfg)?;
    let opts = SolveOptions {
        epochs: cfg.epochs,
        eta: cfg.eta,
        gamma: cfg.gamma,
        dgd_step: cfg.dgd_step,
        x_true: if parsed.has_flag("trace") { x_true.clone() } else { None },
        kernel_tier: parse_kernel_tier(parsed)?,
        ..Default::default()
    };

    if let Some(k) = parsed.get_parse::<usize>("serve-rhs")? {
        return cmd_serve(&cfg, parsed, &a, k);
    }

    let report = if let Some(workers) = parsed.get("workers") {
        // TCP leader over remote workers
        let addrs: Vec<String> =
            workers.split(',').map(str::to_string).collect();
        let mut leader = cluster::connect_tcp_workers(&addrs)?;
        let variant = match cfg.algorithm {
            Algorithm::DapcDecomposed => dapc::solver::ApcVariant::Decomposed,
            Algorithm::ApcClassical => dapc::solver::ApcVariant::Classical,
            Algorithm::Dgd => {
                let r = leader.solve_dgd(&a, &b, &opts)?;
                collect_cluster_telemetry(&mut leader)?;
                leader.shutdown();
                print_report(&r, x_true.as_deref());
                return Ok(());
            }
        };
        let r = leader.solve_apc(&a, &b, variant, &opts)?;
        collect_cluster_telemetry(&mut leader)?;
        leader.shutdown();
        r
    } else if parsed.has_flag("distributed") {
        run_local_cluster(&cfg, &a, &b, &opts)?
    } else {
        run_single(&cfg, &a, &b, &opts)?
    };
    print_report(&report, x_true.as_deref());
    Ok(())
}

fn run_single(
    cfg: &RunConfig,
    a: &CsrMatrix,
    b: &[f32],
    opts: &SolveOptions,
) -> Result<dapc::solver::SolveReport> {
    match cfg.engine {
        EngineKind::Native if cfg.threads == 1 => {
            let engine = match opts.kernel_tier {
                Some(t) => NativeEngine::with_tier(t),
                None => NativeEngine::new(),
            };
            dispatch_solver(cfg, &engine, a, b, opts)
        }
        EngineKind::Native => {
            // 0 = one worker per hardware thread (pool default)
            let engine = match opts.kernel_tier {
                Some(t) => ParallelEngine::with_tier(cfg.threads, t),
                None => ParallelEngine::new(cfg.threads),
            };
            println!("parallel native engine: {} threads", engine.threads());
            dispatch_solver(cfg, &engine, a, b, opts)
        }
        EngineKind::Xla => {
            let host = XlaExecutorHost::spawn(&cfg.artifacts_dir)?;
            let engine = XlaEngine::new(host.executor());
            dispatch_solver(cfg, &engine, a, b, opts)
        }
    }
}

fn dispatch_solver<E: dapc::solver::ComputeEngine>(
    cfg: &RunConfig,
    engine: &E,
    a: &CsrMatrix,
    b: &[f32],
    opts: &SolveOptions,
) -> Result<dapc::solver::SolveReport> {
    match cfg.algorithm {
        Algorithm::DapcDecomposed => {
            DapcSolver::new(opts.clone()).solve(engine, a, b, cfg.partitions)
        }
        Algorithm::ApcClassical => ApcClassicalSolver::new(opts.clone())
            .solve(engine, a, b, cfg.partitions),
        Algorithm::Dgd => {
            DgdSolver::new(opts.clone()).solve(engine, a, b, cfg.partitions)
        }
    }
}

fn run_local_cluster(
    cfg: &RunConfig,
    a: &CsrMatrix,
    b: &[f32],
    opts: &SolveOptions,
) -> Result<dapc::solver::SolveReport> {
    let variant = match cfg.algorithm {
        Algorithm::DapcDecomposed => dapc::solver::ApcVariant::Decomposed,
        Algorithm::ApcClassical => dapc::solver::ApcVariant::Classical,
        Algorithm::Dgd => {
            let mut c =
                cluster::LocalCluster::spawn(cfg.partitions, NativeEngine::new)?;
            let r = c.leader.solve_dgd(a, b, opts)?;
            collect_cluster_telemetry(&mut c.leader)?;
            return Ok(r);
        }
    };
    match cfg.engine {
        EngineKind::Native => {
            let mut c =
                cluster::LocalCluster::spawn(cfg.partitions, NativeEngine::new)?;
            let r = c.leader.solve_apc(a, b, variant, opts)?;
            collect_cluster_telemetry(&mut c.leader)?;
            Ok(r)
        }
        EngineKind::Xla => {
            let host = XlaExecutorHost::spawn(&cfg.artifacts_dir)?;
            let exec = host.executor();
            let mut c = cluster::LocalCluster::spawn(cfg.partitions, move || {
                XlaEngine::new(exec.clone())
            })?;
            let r = c.leader.solve_apc(a, b, variant, opts)?;
            collect_cluster_telemetry(&mut c.leader)?;
            Ok(r)
        }
    }
}

fn print_report(r: &dapc::solver::SolveReport, x_true: Option<&[f32]>) {
    println!("{}", r.summary());
    println!(
        "solution: n={} mu={:.6} sigma={:.6}",
        r.xbar.len(),
        norms::mean(&r.xbar),
        norms::std_dev(&r.xbar)
    );
    if let Some(xt) = x_true {
        println!("MSE vs known solution: {:.3e}", r.final_mse(xt));
    }
    if let Some(trace) = &r.trace {
        for (e, m) in &trace.points {
            println!("epoch {e}: mse {m:.6e}");
        }
    }
}

/// `solve --serve-rhs K`: register the matrix once into a warm solver
/// session, stream K generated right-hand sides through it one at a
/// time, then once more as a single column-blocked batch, and print the
/// cold-vs-amortized timing comparison.
fn cmd_serve(
    cfg: &RunConfig,
    parsed: &cli::ParsedArgs,
    a: &CsrMatrix,
    k: usize,
) -> Result<()> {
    if k == 0 {
        return Err(DapcError::Config("--serve-rhs needs K >= 1".into()));
    }
    let algorithm = match cfg.algorithm {
        Algorithm::DapcDecomposed => {
            SessionAlgorithm::Apc(ApcVariant::Decomposed)
        }
        Algorithm::ApcClassical => SessionAlgorithm::Apc(ApcVariant::Classical),
        Algorithm::Dgd => SessionAlgorithm::Dgd,
    };
    let opts = SolveOptions {
        epochs: cfg.epochs,
        eta: cfg.eta,
        gamma: cfg.gamma,
        dgd_step: cfg.dgd_step,
        kernel_tier: parse_kernel_tier(parsed)?,
        ..Default::default()
    };

    // K consistent right-hand sides b_i = A x_i from seeded generators —
    // the "requests" this service session will stream
    let (m, n) = a.shape();
    let mut bs = Vec::with_capacity(k);
    for i in 0..k as u64 {
        let mut g = dapc::rng::seeded(cfg.seed.wrapping_add(1 + i));
        let x: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
        let mut b = vec![0.0f32; m];
        a.spmv_into(&x, &mut b);
        bs.push(b);
    }
    println!(
        "solve service: streaming {k} rhs over {m}x{n} (J = {})",
        cfg.partitions
    );

    if let Some(workers) = parsed.get("workers") {
        // TCP leader: the remote workers hold the registered state; the
        // cold reference runs over the same connections first (workers
        // replace their one-shot state on RegisterMatrix)
        let addrs: Vec<String> =
            workers.split(',').map(str::to_string).collect();
        let mut leader = cluster::connect_tcp_workers(&addrs)?;
        let cold_s =
            time_cold(leader.backend_mut(), a, &bs[0], algorithm, &opts)?;
        let result = serve_stream(
            leader.backend_mut(),
            a,
            algorithm,
            &opts,
            &bs,
            cold_s,
        )
        .and_then(|()| collect_cluster_telemetry(&mut leader));
        leader.shutdown();
        return result;
    }
    if parsed.has_flag("distributed") {
        // one cluster for both phases: workers replace their one-shot
        // state when the session's RegisterMatrix arrives
        let mut c =
            cluster::LocalCluster::spawn(cfg.partitions, NativeEngine::new)?;
        let cold_s =
            time_cold(c.leader.backend_mut(), a, &bs[0], algorithm, &opts)?;
        serve_stream(
            c.leader.backend_mut(),
            a,
            algorithm,
            &opts,
            &bs,
            cold_s,
        )?;
        return collect_cluster_telemetry(&mut c.leader);
    }
    match cfg.engine {
        EngineKind::Native if cfg.threads == 1 => {
            let engine = match opts.kernel_tier {
                Some(t) => NativeEngine::with_tier(t),
                None => NativeEngine::new(),
            };
            serve_in_process(&engine, cfg, a, algorithm, &opts, &bs)
        }
        EngineKind::Native => {
            let engine = match opts.kernel_tier {
                Some(t) => ParallelEngine::with_tier(cfg.threads, t),
                None => ParallelEngine::new(cfg.threads),
            };
            println!("parallel native engine: {} threads", engine.threads());
            serve_in_process(&engine, cfg, a, algorithm, &opts, &bs)
        }
        EngineKind::Xla => Err(DapcError::Config(
            "--serve-rhs requires the native engine (the XLA init is a \
             fused artifact with no retained factorization)"
                .into(),
        )),
    }
}

fn serve_in_process<E: ComputeEngine>(
    engine: &E,
    cfg: &RunConfig,
    a: &CsrMatrix,
    algorithm: SessionAlgorithm,
    opts: &SolveOptions,
    bs: &[Vec<f32>],
) -> Result<()> {
    // the cold reference backend is dropped before the session starts,
    // so its one-shot state never inflates the serving footprint
    let cold_s = {
        let mut cold_backend = InProcessBackend::new(engine, cfg.partitions);
        time_cold(&mut cold_backend, a, &bs[0], algorithm, opts)?
    };
    let mut backend = InProcessBackend::new(engine, cfg.partitions);
    serve_stream(&mut backend, a, algorithm, opts, bs, cold_s)
}

/// One cold one-shot solve (init + epochs) for the baseline timing.
fn time_cold<B: SessionBackend + ?Sized>(
    backend: &mut B,
    a: &CsrMatrix,
    b: &[f32],
    algorithm: SessionAlgorithm,
    opts: &SolveOptions,
) -> Result<f64> {
    let t0 = std::time::Instant::now();
    let r = match algorithm {
        SessionAlgorithm::Apc(variant) => {
            drive_apc(backend, a, b, variant, opts)?
        }
        SessionAlgorithm::Dgd => drive_dgd(backend, a, b, opts)?,
    };
    let s = t0.elapsed().as_secs_f64();
    println!("cold one-shot reference: {}", r.summary());
    Ok(s)
}

fn serve_stream<B: SessionBackend + ?Sized>(
    backend: &mut B,
    a: &CsrMatrix,
    algorithm: SessionAlgorithm,
    opts: &SolveOptions,
    bs: &[Vec<f32>],
    cold_s: f64,
) -> Result<()> {
    let config = SessionConfig::new(algorithm).options(opts.clone());
    let mut session = SolverSession::register(backend, a.clone(), config)?;
    let mut worst_residual = 0.0f64;
    let t0 = std::time::Instant::now();
    for b in bs {
        let r = session.solve(b)?;
        if let Some(res) = r.residual {
            worst_residual = worst_residual.max(res);
        }
    }
    let warm_per_rhs = t0.elapsed().as_secs_f64() / bs.len() as f64;

    let t1 = std::time::Instant::now();
    let batch = session.solve_batch(bs)?;
    let batch_per_rhs = t1.elapsed().as_secs_f64() / batch.len() as f64;

    println!("{}", session.stats().summary());
    println!("cold solve:          {cold_s:.6}s / rhs");
    println!(
        "warm single solves:  {warm_per_rhs:.6}s / rhs ({:.2}x vs cold)",
        cold_s / warm_per_rhs.max(1e-12)
    );
    println!(
        "warm batch (k = {}): {batch_per_rhs:.6}s / rhs ({:.2}x vs cold)",
        bs.len(),
        cold_s / batch_per_rhs.max(1e-12)
    );
    println!("worst residual across the stream: {worst_residual:.3e}");
    Ok(())
}

/// One tenant of the multi-session smoke: its matrix, the right-hand
/// sides it will be asked to solve, and the isolated-session reference
/// solutions every served reply must match bitwise.
struct Tenant {
    a: CsrMatrix,
    bs: Vec<Vec<f32>>,
    expected: Vec<Vec<f32>>,
}

/// Generate `n_sessions` synthetic tenants and solve each one's
/// right-hand sides through an ISOLATED warm session on a fresh
/// in-process backend — the references the served replies are checked
/// against (bit-for-bit, per the interleaving-equivalence contract).
fn build_tenants<E: ComputeEngine>(
    cfg: &RunConfig,
    ref_engine: &E,
    config: &SessionConfig,
    n_sessions: usize,
    per_session: usize,
) -> Result<Vec<Tenant>> {
    let mut tenants = Vec::with_capacity(n_sessions);
    for s in 0..n_sessions as u64 {
        let ds = GeneratorConfig::schenk_like(cfg.synth_n)
            .try_generate(cfg.seed.wrapping_add(s))?;
        let a = ds.matrix;
        let (m, n) = a.shape();
        let mut bs = Vec::with_capacity(per_session);
        for r in 0..per_session as u64 {
            let mut g = dapc::rng::seeded(
                cfg.seed.wrapping_add(1000 * (s + 1) + r),
            );
            let x: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
            let mut b = vec![0.0f32; m];
            a.spmv_into(&x, &mut b);
            bs.push(b);
        }
        let mut backend = InProcessBackend::new(ref_engine, cfg.partitions);
        let mut session =
            SolverSession::register(&mut backend, a.clone(), config.clone())?;
        let mut expected = Vec::with_capacity(per_session);
        for b in &bs {
            expected.push(session.solve(b)?.xbar);
        }
        tenants.push(Tenant { a, bs, expected });
    }
    Ok(tenants)
}

/// Smoke-client request: (session id, global request index, rhs).
type SmokeReq = (u64, usize, Vec<f32>);

/// Drive one client connection: handshake, submit every assigned
/// request (retrying through transient `Busy`), return `(global index,
/// xbar)` per reply.
fn run_smoke_client<T: dapc::coordinator::transport::Transport>(
    conn: &mut T,
    reqs: &[SmokeReq],
) -> Result<Vec<(usize, Vec<f32>)>> {
    let mut client = SolveClient::connect(conn)?;
    let mut out = Vec::with_capacity(reqs.len());
    for (sid, idx, b) in reqs {
        // wait out transient Busy rejections: the server is making
        // progress on other connections, so back off briefly and
        // resubmit; bounded so a wedged server fails loudly
        let mut reply = client.submit(*sid, std::slice::from_ref(b))?;
        let mut attempts = 0u32;
        while let ClientReply::Busy { .. } = reply {
            attempts += 1;
            if attempts > 10_000 {
                return Err(DapcError::Coordinator(format!(
                    "request {idx}: still Busy after {attempts} retries"
                )));
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
            reply = client.submit(*sid, std::slice::from_ref(b))?;
        }
        match reply {
            ClientReply::Solved { mut xbars, .. } => {
                let xbar = xbars.pop().ok_or_else(|| {
                    DapcError::Coordinator(format!(
                        "request {idx}: SolveResult carried no columns"
                    ))
                })?;
                out.push((*idx, xbar));
            }
            other => {
                return Err(DapcError::Coordinator(format!(
                    "request {idx} (session {sid}): expected Solved, got \
                     {other:?}"
                )))
            }
        }
    }
    client.shutdown()?;
    Ok(out)
}

/// Spawn one client thread per connection pair, run the server on this
/// thread, and scatter each client's replies into `results` by global
/// request index.
fn serve_over<B, T>(
    mgr: &mut SessionManager<'_, B>,
    pairs: Vec<(T, T)>,
    assigned: &[Vec<SmokeReq>],
    opts: &ServeOptions,
    results: &mut [Option<Vec<f32>>],
) -> Result<dapc::service::ServeReport>
where
    B: SessionBackend + ?Sized,
    T: dapc::coordinator::transport::Transport,
{
    std::thread::scope(|sc| {
        let mut conns = Vec::with_capacity(pairs.len());
        let mut handles = Vec::with_capacity(pairs.len());
        for ((srv, mut cli), reqs) in pairs.into_iter().zip(assigned) {
            conns.push(srv);
            handles.push(sc.spawn(move || run_smoke_client(&mut cli, reqs)));
        }
        let report = serve_connections(mgr, conns, opts)?;
        for h in handles {
            let got = h.join().map_err(|_| {
                DapcError::Coordinator("smoke client thread panicked".into())
            })??;
            for (idx, xbar) in got {
                results[idx] = Some(xbar);
            }
        }
        Ok(report)
    })
}

/// Register every tenant into a [`SessionManager`] over `backend`, serve
/// the interleaved request schedule through concurrent client
/// connections, and verify each reply bitwise against the tenant's
/// isolated reference solution.
fn run_multi_session_server<B: SessionBackend + ?Sized>(
    backend: &mut B,
    tenants: &[Tenant],
    config: &SessionConfig,
    cap: Option<u64>,
    queue_depth: usize,
    tcp: bool,
) -> Result<()> {
    use dapc::coordinator::transport::{channel_pair, TcpTransport};

    let mut mgr = match cap {
        Some(c) => SessionManager::with_memory_cap(backend, c),
        None => SessionManager::new(backend),
    };
    let mut sids = Vec::with_capacity(tenants.len());
    for t in tenants {
        sids.push(mgr.register(t.a.clone(), config.clone())?);
    }
    println!(
        "registered {} sessions (ids {:?}); resident {} B, cap {}",
        sids.len(),
        sids,
        mgr.resident_bytes(),
        cap.map(|c| c.to_string()).unwrap_or_else(|| "none".into()),
    );

    // strict round-robin across sessions, split round-robin across one
    // client connection per tenant — every connection touches EVERY
    // session, so the wire multiplexing is exercised, not just the map
    let per_session = tenants[0].bs.len();
    let mut reqs: Vec<SmokeReq> = Vec::new();
    let mut sched: Vec<(usize, usize)> = Vec::new();
    for r in 0..per_session {
        for (s, t) in tenants.iter().enumerate() {
            reqs.push((sids[s], reqs.len(), t.bs[r].clone()));
            sched.push((s, r));
        }
    }
    let n_clients = tenants.len();
    let assigned: Vec<Vec<SmokeReq>> = (0..n_clients)
        .map(|c| {
            reqs.iter()
                .filter(|(_, idx, _)| idx % n_clients == c)
                .cloned()
                .collect()
        })
        .collect();

    let opts = ServeOptions { queue_depth, credit_window: 4 };
    let mut results: Vec<Option<Vec<f32>>> = vec![None; reqs.len()];
    let report = if tcp {
        let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        println!("serving over loopback TCP on {addr}");
        let mut pairs = Vec::with_capacity(n_clients);
        for _ in 0..n_clients {
            // connect-then-accept on one thread: the listener backlog
            // holds the pending connection, so this cannot block
            let out = std::net::TcpStream::connect(addr)?;
            let (inn, _) = listener.accept()?;
            pairs.push((TcpTransport::new(inn)?, TcpTransport::new(out)?));
        }
        serve_over(&mut mgr, pairs, &assigned, &opts, &mut results)?
    } else {
        let pairs: Vec<_> = (0..n_clients)
            .map(|_| {
                let (srv, cli) = channel_pair();
                (srv, cli)
            })
            .collect();
        serve_over(&mut mgr, pairs, &assigned, &opts, &mut results)?
    };

    // every reply must be bitwise identical to the isolated reference
    let mut missing = 0usize;
    for (i, got) in results.iter().enumerate() {
        let (s, r) = sched[i];
        match got {
            Some(x) if *x == tenants[s].expected[r] => {}
            Some(_) => {
                return Err(DapcError::Coordinator(format!(
                    "request {i} (session {}, rhs {r}): served solution \
                     diverges from the isolated reference",
                    sids[s]
                )))
            }
            None => missing += 1,
        }
    }
    if missing > 0 {
        return Err(DapcError::Coordinator(format!(
            "{missing} request(s) never produced a SolveResult"
        )));
    }
    println!(
        "verified {} interleaved replies bitwise against isolated \
         sessions ({} served, {} busy rejections, {} evictions)",
        results.len(),
        report.served,
        report.busy,
        mgr.evictions(),
    );
    if let Some(c) = cap {
        let live = sids.iter().filter(|s| mgr.is_resident(**s)).count();
        if live > 1 && mgr.resident_bytes() > c {
            return Err(DapcError::Coordinator(format!(
                "resident bytes {} exceed the cap {c} with {live} \
                 sessions live",
                mgr.resident_bytes()
            )));
        }
    }
    for sid in &sids {
        if let Some(stats) = mgr.stats(*sid) {
            println!("session {sid}: {}", stats.summary());
        }
    }
    // unregister the first tenant so the metrics dump proves the
    // accounting decrements (the validator cross-checks the per-session
    // gauges against the total)
    mgr.unregister(sids[0])?;
    println!(
        "unregistered session {}; resident now {} B across {} sessions",
        sids[0],
        mgr.resident_bytes(),
        mgr.len(),
    );
    Ok(())
}

/// `dapc serve`: the multi-tenant solve-server smoke.  Registers
/// `--sessions` synthetic matrices into one [`SessionManager`], streams
/// `--serve-rhs` right-hand sides per session from concurrent client
/// connections (each client touching every session), and fails unless
/// every reply is bitwise identical to an isolated single-session
/// reference.  `--max-resident-bytes` forces LRU eviction mid-stream;
/// `--tcp` swaps in-process channels for real loopback sockets;
/// `--distributed` serves over a local worker cluster.
fn cmd_serve_multi(parsed: &cli::ParsedArgs) -> Result<()> {
    let cfg = build_config(parsed)?;
    let n_sessions = parsed.get_parse::<usize>("sessions")?.unwrap_or(2);
    let per_session = parsed.get_parse::<usize>("serve-rhs")?.unwrap_or(3);
    let queue_depth = parsed.get_parse::<usize>("queue-depth")?.unwrap_or(8);
    let cap = parsed.get_parse::<u64>("max-resident-bytes")?;
    let tcp = parsed.has_flag("tcp");
    if n_sessions == 0 || per_session == 0 {
        return Err(DapcError::Config(
            "serve needs --sessions >= 1 and --serve-rhs >= 1".into(),
        ));
    }
    let algorithm = match cfg.algorithm {
        Algorithm::DapcDecomposed => {
            SessionAlgorithm::Apc(ApcVariant::Decomposed)
        }
        Algorithm::ApcClassical => SessionAlgorithm::Apc(ApcVariant::Classical),
        Algorithm::Dgd => SessionAlgorithm::Dgd,
    };
    let config = SessionConfig::new(algorithm)
        .partitions(cfg.partitions)
        .options(SolveOptions {
            epochs: cfg.epochs,
            eta: cfg.eta,
            gamma: cfg.gamma,
            dgd_step: cfg.dgd_step,
            kernel_tier: parse_kernel_tier(parsed)?,
            ..Default::default()
        });
    println!(
        "multi-tenant serve: {n_sessions} sessions x {per_session} rhs, \
         queue depth {queue_depth}, J = {}",
        cfg.partitions
    );

    if parsed.has_flag("distributed") {
        // cluster workers run NativeEngine; the in-process NativeEngine
        // references are bitwise-equivalent by the distributed contract
        let ref_engine = NativeEngine::new();
        let tenants = build_tenants(
            &cfg,
            &ref_engine,
            &config,
            n_sessions,
            per_session,
        )?;
        let mut c =
            cluster::LocalCluster::spawn(cfg.partitions, NativeEngine::new)?;
        run_multi_session_server(
            c.leader.backend_mut(),
            &tenants,
            &config,
            cap,
            queue_depth,
            tcp,
        )?;
        return collect_cluster_telemetry(&mut c.leader);
    }
    match cfg.engine {
        EngineKind::Native if cfg.threads == 1 => {
            let engine = match config.solve_options().kernel_tier {
                Some(t) => NativeEngine::with_tier(t),
                None => NativeEngine::new(),
            };
            let tenants = build_tenants(
                &cfg,
                &engine,
                &config,
                n_sessions,
                per_session,
            )?;
            let mut backend = InProcessBackend::new(&engine, cfg.partitions);
            run_multi_session_server(
                &mut backend,
                &tenants,
                &config,
                cap,
                queue_depth,
                tcp,
            )
        }
        EngineKind::Native => {
            let engine = match config.solve_options().kernel_tier {
                Some(t) => ParallelEngine::with_tier(cfg.threads, t),
                None => ParallelEngine::new(cfg.threads),
            };
            println!("parallel native engine: {} threads", engine.threads());
            let tenants = build_tenants(
                &cfg,
                &engine,
                &config,
                n_sessions,
                per_session,
            )?;
            let mut backend = InProcessBackend::new(&engine, cfg.partitions);
            run_multi_session_server(
                &mut backend,
                &tenants,
                &config,
                cap,
                queue_depth,
                tcp,
            )
        }
        EngineKind::Xla => Err(DapcError::Config(
            "serve requires the native engine (the XLA init is a fused \
             artifact with no retained factorization)"
                .into(),
        )),
    }
}

fn cmd_worker(parsed: &cli::ParsedArgs) -> Result<()> {
    let cfg = build_config(parsed)?;
    let addr = parsed
        .get("listen")
        .ok_or_else(|| DapcError::Config("worker requires --listen".into()))?;
    println!("dapc worker listening on {addr} (engine: {:?})", cfg.engine);
    let tier = parse_kernel_tier(parsed)?;
    match cfg.engine {
        EngineKind::Native if cfg.threads == 1 => {
            let engine = match tier {
                Some(t) => NativeEngine::with_tier(t),
                None => NativeEngine::new(),
            };
            cluster::serve_tcp_worker(&engine, addr)
        }
        EngineKind::Native => {
            let engine = match tier {
                Some(t) => ParallelEngine::with_tier(cfg.threads, t),
                None => ParallelEngine::new(cfg.threads),
            };
            cluster::serve_tcp_worker(&engine, addr)
        }
        EngineKind::Xla => {
            let host = XlaExecutorHost::spawn(&cfg.artifacts_dir)?;
            let engine = XlaEngine::new(host.executor());
            cluster::serve_tcp_worker(&engine, addr)
        }
    }
}

fn cmd_graph(parsed: &cli::ParsedArgs) -> Result<()> {
    let j = parsed.get_parse::<usize>("partitions")?.unwrap_or(2);
    let t = parsed.get_parse::<usize>("epochs")?.unwrap_or(1);
    let dot = TaskGraph::algorithm1(j, t).to_dot();
    match parsed.get("out") {
        Some(path) => {
            std::fs::write(path, &dot)?;
            println!("wrote {path}");
        }
        None => print!("{dot}"),
    }
    Ok(())
}

fn cmd_info(parsed: &cli::ParsedArgs) -> Result<()> {
    let dir = parsed.get("artifacts").unwrap_or("artifacts");
    let manifest =
        dapc::runtime::ArtifactManifest::load(Path::new(dir))?;
    println!("{} artifacts in {dir}:", manifest.len());
    for name in manifest.names() {
        println!("  {name}");
    }
    Ok(())
}

fn cmd_generate(parsed: &cli::ParsedArgs) -> Result<()> {
    let cfg = build_config(parsed)?;
    let out = parsed.get("out").unwrap_or("data");
    std::fs::create_dir_all(out)?;
    let ds = GeneratorConfig::schenk_like(cfg.synth_n).try_generate(cfg.seed)?;
    let dir = Path::new(out);
    matrix_market::write_matrix(&dir.join("A.mtx"), &ds.matrix)?;
    matrix_market::write_vector(&dir.join("b.mtx"), &ds.rhs)?;
    matrix_market::write_vector(&dir.join("x_true.mtx"), &ds.x_true)?;
    println!(
        "wrote {}/A.mtx ({}x{}, {} nnz), b.mtx, x_true.mtx",
        out,
        ds.matrix.rows(),
        ds.matrix.cols(),
        ds.matrix.nnz()
    );
    Ok(())
}
