// A justified `audit:allow` marker: the violation below must be
// suppressed (counted, not reported).
pub fn mean(xs: &[f32]) -> f32 {
    // audit:allow(fixed-order-reduce): fixture — reporting-only value,
    // never feeds back into an iterate
    let s = xs.iter().sum::<f32>();
    s / xs.len().max(1) as f32
}
