// Seeded violation: a fused multiply-add outside simd.rs.  Fusing
// drops an intermediate rounding, so scalar and SIMD paths stop being
// bitwise-identical.
pub fn horner(coeffs: &[f32], x: f32) -> f32 {
    let mut acc = 0.0f32;
    for &c in coeffs.iter().rev() {
        acc = acc.mul_add(x, c);
    }
    acc
}
