//! Per-epoch convergence traces — the data behind Figure 2.

use std::io::Write;
use std::path::Path;

use crate::error::Result;

/// MSE-per-epoch trace for one solver run.
#[derive(Debug, Clone, Default)]
pub struct ConvergenceTrace {
    pub label: String,
    /// (epoch, mse) samples; epoch 0 is the initial solution.
    pub points: Vec<(usize, f64)>,
}

impl ConvergenceTrace {
    pub fn new(label: impl Into<String>) -> Self {
        Self { label: label.into(), points: Vec::new() }
    }

    pub fn push(&mut self, epoch: usize, mse: f64) {
        self.points.push((epoch, mse));
    }

    pub fn final_mse(&self) -> Option<f64> {
        self.points.last().map(|&(_, m)| m)
    }

    pub fn initial_mse(&self) -> Option<f64> {
        self.points.first().map(|&(_, m)| m)
    }

    /// First epoch at which the trace dips below `threshold` (the "reaches
    /// its minima" point used for Table 1's T column).
    pub fn epochs_to_reach(&self, threshold: f64) -> Option<usize> {
        self.points.iter().find(|&&(_, m)| m <= threshold).map(|&(e, _)| e)
    }

    /// Whether the trace is (weakly) decreasing within a tolerance factor —
    /// the paper notes the decomposed variant may wobble after some epoch.
    pub fn monotone_within(&self, slack: f64) -> bool {
        self.points
            .windows(2)
            .all(|w| w[1].1 <= w[0].1 * (1.0 + slack) + 1e-30)
    }

    /// Write several traces as a single CSV: epoch,label1,label2,...
    pub fn write_csv(path: &Path, traces: &[&ConvergenceTrace]) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        write!(f, "epoch")?;
        for t in traces {
            write!(f, ",{}", t.label)?;
        }
        writeln!(f)?;
        let max_len = traces.iter().map(|t| t.points.len()).max().unwrap_or(0);
        for i in 0..max_len {
            let epoch = traces
                .iter()
                .find_map(|t| t.points.get(i).map(|&(e, _)| e))
                .unwrap_or(i);
            write!(f, "{epoch}")?;
            for t in traces {
                match t.points.get(i) {
                    Some(&(_, m)) => write!(f, ",{m:e}")?,
                    None => write!(f, ",")?,
                }
            }
            writeln!(f)?;
        }
        Ok(())
    }

    /// Render traces as an ASCII log-scale chart (Fig. 2 in a terminal).
    pub fn ascii_chart(traces: &[&ConvergenceTrace], width: usize, height: usize) -> String {
        let all: Vec<f64> = traces
            .iter()
            .flat_map(|t| t.points.iter().map(|&(_, m)| m.max(1e-30)))
            .collect();
        if all.is_empty() {
            return String::from("(no data)\n");
        }
        let lo = all.iter().copied().fold(f64::INFINITY, f64::min).ln();
        let hi = all.iter().copied().fold(f64::NEG_INFINITY, f64::max).ln();
        let span = (hi - lo).max(1e-12);
        let max_epoch = traces
            .iter()
            .flat_map(|t| t.points.iter().map(|&(e, _)| e))
            .max()
            .unwrap_or(1)
            .max(1);
        let marks = ['*', '+', 'o', 'x', '#'];
        let mut grid = vec![vec![' '; width]; height];
        for (ti, t) in traces.iter().enumerate() {
            let mark = marks[ti % marks.len()];
            for &(e, m) in &t.points {
                let x = (e * (width - 1)) / max_epoch;
                let yf = ((m.max(1e-30)).ln() - lo) / span;
                let y = height - 1 - ((yf * (height - 1) as f64).round() as usize).min(height - 1);
                grid[y][x] = mark;
            }
        }
        let mut out = String::new();
        out.push_str(&format!("log10(MSE) range [{:.1}, {:.1}]\n", lo / std::f64::consts::LN_10, hi / std::f64::consts::LN_10));
        for row in grid {
            out.push('|');
            out.extend(row);
            out.push('\n');
        }
        out.push('+');
        out.extend(std::iter::repeat('-').take(width));
        out.push_str(&format!("> epochs (0..{max_epoch})\n"));
        for (ti, t) in traces.iter().enumerate() {
            out.push_str(&format!("  {} {}\n", marks[ti % marks.len()], t.label));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ConvergenceTrace {
        let mut t = ConvergenceTrace::new("apc");
        for e in 0..10 {
            t.push(e, 1.0 / (1 << e) as f64);
        }
        t
    }

    #[test]
    fn basics() {
        let t = sample();
        assert_eq!(t.initial_mse(), Some(1.0));
        assert!((t.final_mse().unwrap() - 1.0 / 512.0).abs() < 1e-15);
        assert_eq!(t.epochs_to_reach(0.1), Some(4));
        assert_eq!(t.epochs_to_reach(0.0), None);
        assert!(t.monotone_within(0.0));
    }

    #[test]
    fn monotone_slack() {
        let mut t = ConvergenceTrace::new("x");
        t.push(0, 1.0);
        t.push(1, 1.05);
        assert!(!t.monotone_within(0.0));
        assert!(t.monotone_within(0.1));
    }

    #[test]
    fn csv_format() {
        let a = sample();
        let mut b = ConvergenceTrace::new("dgd");
        b.push(0, 0.5);
        let dir = std::env::temp_dir().join("dapc_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("fig2.csv");
        ConvergenceTrace::write_csv(&p, &[&a, &b]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let mut lines = text.lines();
        assert_eq!(lines.next().unwrap(), "epoch,apc,dgd");
        let first = lines.next().unwrap();
        assert!(first.starts_with("0,1e0,5e-1"), "{first}");
        // ragged rows keep the column count
        assert_eq!(text.lines().nth(2).unwrap().matches(',').count(), 2);
    }

    #[test]
    fn ascii_chart_renders() {
        let t = sample();
        let chart = ConvergenceTrace::ascii_chart(&[&t], 40, 10);
        assert!(chart.contains('*'));
        assert!(chart.contains("apc"));
        assert_eq!(ConvergenceTrace::ascii_chart(&[], 10, 5), "(no data)\n");
    }
}
