"""Layer-2 graph correctness: init variants, consensus rounds, solve loop.

Validates the *algorithm*, not just the kernels: both init variants must
agree with the oracles; Algorithm 1 must drive the MSE down on a consistent
augmented system (the paper's Fig. 2 setup, scaled down).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

F32 = np.float32


def _tall_system(rng, l, n):
    a = rng.normal(size=(l, n)).astype(F32)
    x_true = rng.normal(size=(n,)).astype(F32)
    b = (a @ x_true).astype(F32)
    return a, b, x_true


class TestInitQr:
    @pytest.mark.parametrize("l,n", [(16, 8), (64, 32), (40, 40)])
    def test_x0_solves_consistent_system(self, rng, l, n):
        a, b, x_true = _tall_system(rng, l, n)
        x0, _ = model.init_qr(jnp.asarray(a), jnp.asarray(b))
        np.testing.assert_allclose(np.asarray(x0), x_true, atol=1e-2)

    def test_matches_ref(self, rng):
        a, b, _ = _tall_system(rng, 48, 24)
        x0, p = model.init_qr(jnp.asarray(a), jnp.asarray(b))
        x0r, pr = ref.worker_init_qr_ref(a, b)
        np.testing.assert_allclose(np.asarray(x0), x0r, atol=1e-3)
        # Tall regime: P = I - Q1^T Q1 is rounding-level noise (paper eq. 4;
        # see DESIGN.md soundness note) — assert it is small like the ref's.
        assert np.abs(np.asarray(p)).max() < 1e-4
        assert np.abs(pr).max() < 1e-4

    def test_projector_symmetric_psd_structure(self, rng):
        a, b, _ = _tall_system(rng, 32, 16)
        _, p = model.init_qr(jnp.asarray(a), jnp.asarray(b))
        p = np.asarray(p)
        np.testing.assert_allclose(p, p.T, atol=1e-5)


class TestInitClassical:
    @pytest.mark.parametrize("l,n", [(16, 8), (64, 32)])
    def test_x0_solves_consistent_system(self, rng, l, n):
        a, b, x_true = _tall_system(rng, l, n)
        x0, _ = model.init_classical(jnp.asarray(a), jnp.asarray(b))
        np.testing.assert_allclose(np.asarray(x0), x_true, atol=5e-2)

    def test_matches_ref(self, rng):
        a, b, _ = _tall_system(rng, 48, 24)
        x0, p = model.init_classical(jnp.asarray(a), jnp.asarray(b))
        x0r, _ = ref.worker_init_classical_ref(a, b)
        np.testing.assert_allclose(np.asarray(x0), x0r, atol=1e-2)

    def test_decomposed_init_mse_ge_classical_is_bounded(self, rng):
        # Paper §4: 'the decomposed APC MSE of the initial solution should
        # always be greater than in classical APC' — both must still be tiny
        # on a consistent system.
        a, b, x_true = _tall_system(rng, 64, 32)
        xq, _ = model.init_qr(jnp.asarray(a), jnp.asarray(b))
        xc, _ = model.init_classical(jnp.asarray(a), jnp.asarray(b))
        mq = float(np.mean((np.asarray(xq) - x_true) ** 2))
        mc = float(np.mean((np.asarray(xc) - x_true) ** 2))
        assert mq < 1e-4 and mc < 1e-2


class TestInitFat:
    def test_min_norm_solution(self, rng):
        p_rows, n = 12, 32
        a = rng.normal(size=(p_rows, n)).astype(F32)
        b = rng.normal(size=(p_rows,)).astype(F32)
        x0, p = model.init_fat(jnp.asarray(a), jnp.asarray(b))
        # residual ~ 0 (underdetermined, consistent by construction)
        np.testing.assert_allclose(a @ np.asarray(x0), b, atol=1e-3)
        # min-norm: x0 orthogonal to nullspace => P x0 ~ 0
        np.testing.assert_allclose(np.asarray(p) @ np.asarray(x0), 0, atol=1e-3)

    def test_projector_idempotent(self, rng):
        a = rng.normal(size=(8, 24)).astype(F32)
        b = rng.normal(size=(8,)).astype(F32)
        _, p = model.init_fat(jnp.asarray(a), jnp.asarray(b))
        p = np.asarray(p)
        np.testing.assert_allclose(p @ p, p, atol=1e-4)
        np.testing.assert_allclose(p, p.T, atol=1e-5)
        # rank = n - p_rows
        assert abs(np.trace(p) - (24 - 8)) < 1e-2


class TestConsensusRound:
    def test_matches_ref(self, rng):
        j, n = 3, 40
        x = rng.normal(size=(j, n)).astype(F32)
        xbar = rng.normal(size=(n,)).astype(F32)
        p = rng.normal(size=(j, n, n)).astype(F32) * 0.1
        xn, xbn = model.consensus_round(
            jnp.asarray(x), jnp.asarray(xbar), jnp.asarray(p),
            jnp.float32(0.6), jnp.float32(0.4),
        )
        xr, xbr = ref.consensus_round_ref(x, xbar, p, 0.6, 0.4)
        np.testing.assert_allclose(np.asarray(xn), np.asarray(xr), atol=1e-4)
        np.testing.assert_allclose(np.asarray(xbn), np.asarray(xbr), atol=1e-4)


class TestSolveLoop:
    def test_matches_unrolled_ref(self, rng):
        j, n, t = 2, 24, 7
        x = rng.normal(size=(j, n)).astype(F32)
        xbar = rng.normal(size=(n,)).astype(F32)
        p = (rng.normal(size=(j, n, n)) * 0.05).astype(F32)
        xs, xbs = model.solve_loop(
            jnp.asarray(x), jnp.asarray(xbar), jnp.asarray(p),
            jnp.float32(0.5), jnp.float32(0.5), jnp.int32(t),
        )
        xr, xbr = ref.solve_loop_ref(x, xbar, p, 0.5, 0.5, t)
        np.testing.assert_allclose(np.asarray(xbs), np.asarray(xbr), atol=1e-3)
        np.testing.assert_allclose(np.asarray(xs), np.asarray(xr), atol=1e-3)

    def test_zero_epochs_identity(self, rng):
        j, n = 2, 16
        x = rng.normal(size=(j, n)).astype(F32)
        xbar = rng.normal(size=(n,)).astype(F32)
        p = rng.normal(size=(j, n, n)).astype(F32)
        xs, xbs = model.solve_loop(
            jnp.asarray(x), jnp.asarray(xbar), jnp.asarray(p),
            jnp.float32(0.5), jnp.float32(0.5), jnp.int32(0),
        )
        np.testing.assert_allclose(np.asarray(xs), x, atol=0)
        np.testing.assert_allclose(np.asarray(xbs), xbar, atol=0)


class TestAlgorithmEndToEnd:
    def test_consensus_converges_on_augmented_system(self, rng):
        """Paper §4 setup, scaled: square system + augmented rows, J tall
        partitions; Algorithm 1 must drive MSE(xbar, x_true) to ~0."""
        n, j = 24, 3
        a0 = (rng.normal(size=(n, n)) + 3 * np.eye(n)).astype(F32)
        x_true = rng.normal(size=(n,)).astype(F32)
        b0 = a0 @ x_true
        # augment: D_A rows are random combinations of A's rows (paper eq. 8)
        m_extra = 2 * n
        c = rng.normal(size=(m_extra, n)).astype(F32)
        da, db = c @ a0, c @ b0
        a_full = np.vstack([a0, da])
        b_full = np.concatenate([b0, db])
        # J partitions of l = n rows each
        xs, ps = [], []
        for jj in range(j):
            sl = slice(jj * n, (jj + 1) * n)
            x0, p = model.init_qr(jnp.asarray(a_full[sl]), jnp.asarray(b_full[sl]))
            xs.append(np.asarray(x0))
            ps.append(np.asarray(p))
        x = np.stack(xs)
        p = np.stack(ps)
        xbar = x.mean(axis=0)  # eq. (5)
        mse0 = float(np.mean((xbar - x_true) ** 2))
        _, xbar_t = model.solve_loop(
            jnp.asarray(x), jnp.asarray(xbar), jnp.asarray(p),
            jnp.float32(0.8), jnp.float32(0.9), jnp.int32(40),
        )
        mse_t = float(np.mean((np.asarray(xbar_t) - x_true) ** 2))
        assert mse_t < 1e-6
        assert mse_t <= mse0 + 1e-12

    def test_dgd_gradient_matches_ref(self, rng):
        l, n = 20, 10
        a = rng.normal(size=(l, n)).astype(F32)
        x = rng.normal(size=(n,)).astype(F32)
        b = rng.normal(size=(l,)).astype(F32)
        g = model.dgd_grad(jnp.asarray(a), jnp.asarray(x), jnp.asarray(b))
        np.testing.assert_allclose(
            np.asarray(g), ref.dgd_gradient_ref(a, x, b), atol=1e-4
        )

    def test_mse_graph(self, rng):
        x = rng.normal(size=(32,)).astype(F32)
        y = rng.normal(size=(32,)).astype(F32)
        got = float(model.mse(jnp.asarray(x), jnp.asarray(y)))
        assert abs(got - float(np.mean((x - y) ** 2))) < 1e-6
