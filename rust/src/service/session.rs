//! [`SolverSession`]: register a matrix once, then serve an arbitrary
//! stream of right-hand sides (single or batched) over any
//! [`SessionBackend`].
//!
//! Since the multi-tenant redesign every session owns a process-unique
//! [`SessionId`] and every backend call is scoped to it, so any number
//! of sessions can share one backend (and one cluster of workers).  The
//! shared serving logic lives in [`SessionCore`] — a backend-less value
//! the [`super::SessionManager`] can hold MANY of while driving them
//! all over a single `&mut B`; [`SolverSession`] is the one-session
//! convenience wrapper that bundles a core with its backend borrow.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{DapcError, Result};
use crate::obs::{self, Counter, Histogram};
use crate::partition::PartitionPlan;
use crate::solver::driver::apc_label;
use crate::solver::{
    auto_dgd_step, drive_apc_epochs_multi, drive_dgd_epochs_multi,
    init_kind_for, resident_partition_bytes, residual_norm, SessionBackend,
    SessionId, SolveOptions, SolveReport,
};
use crate::sparse::CsrMatrix;

use super::{ServiceStats, SessionConfig};

/// Process-wide session-id allocator: ids are unique across every
/// manager and standalone session in the process, so two tenants
/// sharing one backend can never collide.
static NEXT_SESSION_ID: AtomicU64 = AtomicU64::new(1);

pub(crate) fn next_session_id() -> SessionId {
    NEXT_SESSION_ID.fetch_add(1, Ordering::Relaxed)
}

/// Service-layer metric handles, resolved from the global registry once
/// at registration.  Contract (checked by the metrics validator): the
/// `service.rhs_served` counter always equals `service.warm_rhs_ns`
/// observations plus `service.batch_rhs_ns` observations — a batch of k
/// records its amortized per-RHS latency k times.
struct SessionObs {
    cold_register_ns: Arc<Histogram>,
    warm_rhs_ns: Arc<Histogram>,
    batch_rhs_ns: Arc<Histogram>,
    rhs_served: Arc<Counter>,
}

impl SessionObs {
    fn new() -> Self {
        Self {
            cold_register_ns: obs::histogram("service.cold_register_ns"),
            warm_rhs_ns: obs::histogram("service.warm_rhs_ns"),
            batch_rhs_ns: obs::histogram("service.batch_rhs_ns"),
            rhs_served: obs::counter("service.rhs_served"),
        }
    }
}

/// Which algorithm a session serves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionAlgorithm {
    /// Consensus solves (decomposed or classical init, chosen once at
    /// registration together with the regime).
    Apc(crate::solver::ApcVariant),
    /// Distributed gradient descent (gradient-only workers, no
    /// factorization; the step size is resolved once at registration).
    Dgd,
}

/// What a registration of `a` under `config` will pin resident on the
/// backend, in bytes — pure shape arithmetic, usable BEFORE paying the
/// factorization.  [`super::SessionManager`] evicts against this
/// projection so its memory cap is never exceeded even transiently.
pub(crate) fn projected_resident_bytes(
    a: &CsrMatrix,
    config: &SessionConfig,
    j: usize,
) -> Result<u64> {
    let (m, n) = a.shape();
    let plan = PartitionPlan::contiguous(m, n, j)?;
    Ok(match config.algorithm() {
        SessionAlgorithm::Apc(variant) => {
            let kind = init_kind_for(variant, plan.regime);
            plan.blocks
                .iter()
                .map(|b| resident_partition_bytes(kind, b.len(), plan.n))
                .sum()
        }
        SessionAlgorithm::Dgd => 0,
    })
}

/// The backend-independent half of a warm session: id, matrix, plan,
/// resolved algorithm parameters, reusable accumulators and stats.
///
/// Holds NO backend borrow — callers pass `&mut B` into every
/// operation — which is exactly what lets [`super::SessionManager`]
/// own many cores while multiplexing them over one backend.
pub(crate) struct SessionCore {
    sid: SessionId,
    a: Arc<CsrMatrix>,
    plan: PartitionPlan,
    algorithm: SessionAlgorithm,
    opts: SolveOptions,
    n_target: usize,
    /// DGD step size, resolved once at registration (unused for APC).
    alpha: f32,
    /// Reused per-solve eq. (5)/(7) accumulators (k columns).
    accs: Vec<Vec<f64>>,
    stats: ServiceStats,
    obs: SessionObs,
}

impl SessionCore {
    /// Register `a` into the backend under `sid`: partition, factorize,
    /// retain.  This is the session's one-time cold cost
    /// ([`ServiceStats`] records it).
    pub(crate) fn register<B: SessionBackend + ?Sized>(
        backend: &mut B,
        sid: SessionId,
        a: Arc<CsrMatrix>,
        config: SessionConfig,
    ) -> Result<Self> {
        let j = config.resolve_partitions(backend.partitions())?;
        let (algorithm, opts) = config.into_parts();
        if opts.x_true.is_some() || opts.collect_x_parts {
            // the serving layer returns raw solves only; silently
            // dropping a requested trace/x_parts would hand callers a
            // report that is NOT equivalent to the cold path's
            return Err(DapcError::Config(
                "solver sessions do not support per-epoch traces (x_true) \
                 or x_parts collection; use the one-shot \
                 drive_apc/drive_dgd path for convergence analysis"
                    .into(),
            ));
        }
        let (m, n) = a.shape();
        let plan = PartitionPlan::contiguous(m, n, j)?;
        let session_obs = SessionObs::new();
        let t0 = Instant::now();
        let ot = obs::now();
        let (n_target, alpha) = match algorithm {
            SessionAlgorithm::Apc(variant) => {
                let kind = init_kind_for(variant, plan.regime);
                (backend.register_matrix(sid, kind, &plan, &a)?, 0.0)
            }
            SessionAlgorithm::Dgd => {
                backend.register_grad(sid, &plan, &a)?;
                let alpha = if opts.dgd_step > 0.0 {
                    opts.dgd_step
                } else {
                    auto_dgd_step(&a)
                };
                (plan.n, alpha)
            }
        };
        // pure shape arithmetic: what each registered partition keeps
        // resident for warm serving (block + projector + prepacked
        // panels + seed factors); DGD workers retain no factorization
        let resident = match algorithm {
            SessionAlgorithm::Apc(variant) => {
                let kind = init_kind_for(variant, plan.regime);
                plan.blocks
                    .iter()
                    .map(|b| resident_partition_bytes(kind, b.len(), plan.n))
                    .collect()
            }
            SessionAlgorithm::Dgd => Vec::new(),
        };
        obs::record_since(&session_obs.cold_register_ns, ot);
        let stats = ServiceStats {
            register_time: t0.elapsed(),
            resident_partition_bytes: resident,
            ..ServiceStats::default()
        };
        Ok(Self {
            sid,
            a,
            plan,
            algorithm,
            opts,
            n_target,
            alpha,
            accs: Vec::new(),
            stats,
            obs: session_obs,
        })
    }

    pub(crate) fn solve_batch_refs<B: SessionBackend + ?Sized>(
        &mut self,
        backend: &mut B,
        bs: &[&[f32]],
    ) -> Result<Vec<SolveReport>> {
        let k = bs.len();
        if k == 0 {
            return Err(DapcError::Shape(
                "solve_batch needs at least one rhs".into(),
            ));
        }
        let (m, n) = self.a.shape();
        for b in bs {
            if b.len() != m {
                return Err(DapcError::Shape(format!(
                    "rhs length {} != matrix rows {m}",
                    b.len()
                )));
            }
        }

        let t0 = Instant::now();
        let (seed_time, mut xbars, algorithm) = match self.algorithm {
            SessionAlgorithm::Apc(variant) => {
                self.accs.resize_with(k, Vec::new);
                backend.seed_rhs(self.sid, &self.plan, bs, &mut self.accs)?;
                let seed_time = t0.elapsed();
                let xbars = drive_apc_epochs_multi(
                    backend,
                    self.sid,
                    &mut self.accs,
                    &self.opts,
                )?;
                (seed_time, xbars, apc_label(variant))
            }
            SessionAlgorithm::Dgd => {
                backend.seed_grad_rhs(self.sid, &self.plan, bs)?;
                let seed_time = t0.elapsed();
                let xs = drive_dgd_epochs_multi(
                    backend,
                    self.sid,
                    k,
                    self.n_target,
                    self.alpha,
                    self.opts.epochs,
                )?;
                (seed_time, xs, "dgd")
            }
        };
        let total = t0.elapsed();
        let iterate_time = total.saturating_sub(seed_time);

        // amortized per-RHS timing view (f64 division: no clamping cast,
        // same fix as ServiceStats::amortized_per_rhs)
        let per_init =
            Duration::from_secs_f64(seed_time.as_secs_f64() / k as f64);
        let per_iter =
            Duration::from_secs_f64(iterate_time.as_secs_f64() / k as f64);

        let mut reports = Vec::with_capacity(k);
        for (mut xbar, b) in xbars.drain(..).zip(bs) {
            xbar.truncate(n);
            let residual = residual_norm(&self.a, b, &xbar);
            reports.push(SolveReport {
                xbar,
                x_parts: Vec::new(),
                trace: None,
                residual: Some(residual),
                init_time: per_init,
                iterate_time: per_iter,
                algorithm,
                engine: backend.backend_name(),
                epochs: self.opts.epochs,
            });
        }
        self.stats.record(k, total);
        // per-RHS latency: a single serve lands in the warm histogram, a
        // batch of k records its amortized per-RHS cost k times into the
        // batched one — so warm + batched observation counts always sum
        // to the rhs_served counter (the validator cross-checks this)
        let per_rhs_ns = (total.as_nanos() / k as u128) as u64;
        if k == 1 {
            self.obs.warm_rhs_ns.record(per_rhs_ns);
        } else {
            for _ in 0..k {
                self.obs.batch_rhs_ns.record(per_rhs_ns);
            }
        }
        self.obs.rhs_served.add(k as u64);
        Ok(reports)
    }

    pub(crate) fn session_id(&self) -> SessionId {
        self.sid
    }

    pub(crate) fn stats(&self) -> &ServiceStats {
        &self.stats
    }

    pub(crate) fn stats_mut(&mut self) -> &mut ServiceStats {
        &mut self.stats
    }

    pub(crate) fn matrix(&self) -> &CsrMatrix {
        &self.a
    }

    pub(crate) fn partitions(&self) -> usize {
        self.plan.j()
    }

    pub(crate) fn algorithm(&self) -> SessionAlgorithm {
        self.algorithm
    }

    /// Total backend-resident factorization bytes this session pins
    /// (0 for DGD sessions, which retain no factorization).
    pub(crate) fn resident_bytes(&self) -> u64 {
        self.stats.resident_bytes_total()
    }
}

/// A warm solver session: the matrix is registered (factorized and
/// retained partition-side) exactly once, after which [`Self::solve`]
/// and [`Self::solve_batch`] serve right-hand sides at per-RHS cost
/// O(l n + n^2) + epochs — never a second factorization.
///
/// Registration goes through the [`SessionConfig`] builder:
///
/// ```
/// use dapc::service::{SessionConfig, SolverSession};
/// use dapc::solver::{ApcVariant, InProcessBackend, NativeEngine};
/// use dapc::sparse::generate::GeneratorConfig;
///
/// let ds = GeneratorConfig::small_demo(16, 2).generate(1);
/// let engine = NativeEngine::new();
/// let mut backend = InProcessBackend::new(&engine, 2);
/// let mut session = SolverSession::register(
///     &mut backend,
///     ds.matrix.clone(),
///     SessionConfig::apc(ApcVariant::Decomposed).epochs(10),
/// )?;
/// let report = session.solve(&ds.rhs)?;
/// # assert!(report.residual.unwrap() < 1.0);
/// # Ok::<(), dapc::error::DapcError>(())
/// ```
///
/// Works over any [`SessionBackend`]: the in-process backend for
/// single-host serving, the cluster backend (wire protocol v5) for
/// distributed serving.  Warm results are bit-identical to cold
/// one-shot solves on both, and every backend call is scoped to this
/// session's [`SessionId`], so other sessions may share the backend
/// (see [`super::SessionManager`] for the many-session owner with
/// capped-memory eviction).
///
/// When metrics are enabled ([`crate::obs`]) the session feeds the
/// `service.cold_register_ns` / `service.warm_rhs_ns` /
/// `service.batch_rhs_ns` latency histograms and the
/// `service.rhs_served` counter — ROADMAP item 5's p50/p99 per-RHS
/// serving latency comes straight from these.
pub struct SolverSession<'b, B: SessionBackend + ?Sized> {
    backend: &'b mut B,
    core: SessionCore,
}

impl<'b, B: SessionBackend + ?Sized> SolverSession<'b, B> {
    /// Register `a` into the backend under a fresh process-unique
    /// session id: partition, factorize, retain.
    pub fn register(
        backend: &'b mut B,
        a: CsrMatrix,
        config: SessionConfig,
    ) -> Result<Self> {
        let sid = next_session_id();
        let core =
            SessionCore::register(backend, sid, Arc::new(a), config)?;
        Ok(Self { backend, core })
    }

    /// Serve one right-hand side through the warm session.
    pub fn solve(&mut self, b: &[f32]) -> Result<SolveReport> {
        let mut reports = self.solve_batch(&[b])?;
        Ok(reports.pop().expect("one report per rhs"))
    }

    /// Serve `bs.len()` right-hand sides as ONE column-blocked batch:
    /// all columns move through a single epoch loop, so each projector
    /// sweep is shared by the whole batch.  Results are bit-identical
    /// to calling [`Self::solve`] per column; reported times are the
    /// batch cost divided evenly across columns (the amortized view).
    ///
    /// Accepts any slice of rhs-shaped values — `&[Vec<f32>]`,
    /// `&[&[f32]]`, arrays — via `AsRef<[f32]>`.
    pub fn solve_batch<S: AsRef<[f32]>>(
        &mut self,
        bs: &[S],
    ) -> Result<Vec<SolveReport>> {
        let refs: Vec<&[f32]> = bs.iter().map(|b| b.as_ref()).collect();
        self.core.solve_batch_refs(self.backend, &refs)
    }

    /// Release this session's backend-resident state (factorization,
    /// prepacked panels, blocks) and consume the session.
    pub fn unregister(self) -> Result<()> {
        self.backend.unregister_session(self.core.sid)
    }

    /// The process-unique id scoping this session's backend state.
    pub fn session_id(&self) -> crate::solver::SessionId {
        self.core.session_id()
    }

    /// Amortization counters for this session.
    pub fn stats(&self) -> &ServiceStats {
        self.core.stats()
    }

    /// The registered matrix.
    pub fn matrix(&self) -> &CsrMatrix {
        self.core.matrix()
    }

    /// Partition count the session was registered with.
    pub fn partitions(&self) -> usize {
        self.core.partitions()
    }

    /// The algorithm this session serves.
    pub fn algorithm(&self) -> SessionAlgorithm {
        self.core.algorithm()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::{
        drive_apc, drive_dgd, ApcVariant, InProcessBackend, NativeEngine,
        Solver as _,
    };
    use crate::sparse::generate::GeneratorConfig;

    fn apc_cfg(epochs: usize, variant: ApcVariant) -> SessionConfig {
        SessionConfig::apc(variant).epochs(epochs)
    }

    fn opts(epochs: usize) -> SolveOptions {
        SolveOptions { epochs, ..Default::default() }
    }

    #[test]
    fn warm_solve_bitwise_matches_cold_solve() {
        let ds = GeneratorConfig::small_demo(16, 3).generate(11);
        let e = NativeEngine::new();
        for variant in [ApcVariant::Decomposed, ApcVariant::Classical] {
            let mut cold_backend = InProcessBackend::new(&e, 3);
            let cold = drive_apc(
                &mut cold_backend,
                &ds.matrix,
                &ds.rhs,
                variant,
                &opts(15),
            )
            .unwrap();

            let mut backend = InProcessBackend::new(&e, 3);
            let mut session = SolverSession::register(
                &mut backend,
                ds.matrix.clone(),
                apc_cfg(15, variant),
            )
            .unwrap();
            let warm = session.solve(&ds.rhs).unwrap();
            assert_eq!(warm.xbar, cold.xbar, "{variant:?}");
            assert_eq!(warm.residual, cold.residual, "{variant:?}");
            // second serve of the SAME rhs: state fully re-seeded
            let warm2 = session.solve(&ds.rhs).unwrap();
            assert_eq!(warm2.xbar, cold.xbar, "{variant:?} resolve");
        }
    }

    #[test]
    fn session_ids_are_process_unique() {
        let ds = GeneratorConfig::small_demo(12, 2).generate(18);
        let e = NativeEngine::new();
        let mut b1 = InProcessBackend::new(&e, 2);
        let s1 = SolverSession::register(
            &mut b1,
            ds.matrix.clone(),
            apc_cfg(2, ApcVariant::Decomposed),
        )
        .unwrap()
        .session_id();
        let mut b2 = InProcessBackend::new(&e, 2);
        let s2 = SolverSession::register(
            &mut b2,
            ds.matrix.clone(),
            apc_cfg(2, ApcVariant::Decomposed),
        )
        .unwrap()
        .session_id();
        assert_ne!(s1, s2);
    }

    #[test]
    fn partition_mismatch_rejected_at_register() {
        let ds = GeneratorConfig::small_demo(12, 2).generate(19);
        let e = NativeEngine::new();
        let mut backend = InProcessBackend::new(&e, 2);
        let err = SolverSession::register(
            &mut backend,
            ds.matrix.clone(),
            SessionConfig::apc(ApcVariant::Decomposed).partitions(5),
        )
        .map(|_| ())
        .unwrap_err()
        .to_string();
        assert!(err.contains("5 partitions"), "{err}");
    }

    #[test]
    fn register_reports_resident_factorization_bytes() {
        let ds = GeneratorConfig::small_demo(16, 3).generate(11);
        let e = NativeEngine::new();
        let mut backend = InProcessBackend::new(&e, 3);
        let session = SolverSession::register(
            &mut backend,
            ds.matrix.clone(),
            apc_cfg(5, ApcVariant::Decomposed),
        )
        .unwrap();
        let stats = session.stats();
        assert_eq!(stats.resident_partition_bytes.len(), 3);
        let (m, n) = ds.matrix.shape();
        let plan = PartitionPlan::contiguous(m, n, 3).unwrap();
        let kind = init_kind_for(ApcVariant::Decomposed, plan.regime);
        for (blk, &bytes) in
            plan.blocks.iter().zip(&stats.resident_partition_bytes)
        {
            assert_eq!(
                bytes,
                resident_partition_bytes(kind, blk.len(), plan.n)
            );
        }
        assert!(stats.summary().contains("resident"));

        // DGD workers retain no factorization: nothing to report
        let mut b2 = InProcessBackend::new(&e, 2);
        let dgd = SolverSession::register(
            &mut b2,
            ds.matrix.clone(),
            SessionConfig::dgd().epochs(2),
        )
        .unwrap();
        assert!(dgd.stats().resident_partition_bytes.is_empty());
        assert!(!dgd.stats().summary().contains("resident"));
    }

    #[test]
    fn warm_dgd_bitwise_matches_cold_dgd() {
        let ds = GeneratorConfig::small_demo(12, 2).generate(12);
        let e = NativeEngine::new();
        let o = SolveOptions { epochs: 30, dgd_step: 0.0, ..Default::default() };

        let mut cold_backend = InProcessBackend::new(&e, 2);
        let cold =
            drive_dgd(&mut cold_backend, &ds.matrix, &ds.rhs, &o).unwrap();

        let mut backend = InProcessBackend::new(&e, 2);
        let mut session = SolverSession::register(
            &mut backend,
            ds.matrix.clone(),
            SessionConfig::dgd().options(o),
        )
        .unwrap();
        let warm = session.solve(&ds.rhs).unwrap();
        assert_eq!(warm.xbar, cold.xbar);
        assert_eq!(warm.residual, cold.residual);
    }

    #[test]
    fn batch_bitwise_matches_sequential_solves() {
        let ds = GeneratorConfig::small_demo(14, 2).generate(13);
        let e = NativeEngine::new();
        // three distinct consistent rhs against the one registered matrix
        let bs: Vec<Vec<f32>> = (0..3)
            .map(|i| {
                let mut g = crate::rng::seeded(400 + i);
                let x: Vec<f32> =
                    (0..ds.matrix.cols()).map(|_| g.normal_f32()).collect();
                let mut b = vec![0.0f32; ds.matrix.rows()];
                ds.matrix.spmv_into(&x, &mut b);
                b
            })
            .collect();

        let mut b1 = InProcessBackend::new(&e, 2);
        let mut seq = SolverSession::register(
            &mut b1,
            ds.matrix.clone(),
            apc_cfg(20, ApcVariant::Decomposed),
        )
        .unwrap();
        let singles: Vec<_> =
            bs.iter().map(|b| seq.solve(b).unwrap()).collect();

        let mut b2 = InProcessBackend::new(&e, 2);
        let mut batched = SolverSession::register(
            &mut b2,
            ds.matrix.clone(),
            apc_cfg(20, ApcVariant::Decomposed),
        )
        .unwrap();
        let batch = batched.solve_batch(&bs).unwrap();

        assert_eq!(batch.len(), 3);
        for (one, many) in singles.iter().zip(&batch) {
            assert_eq!(one.xbar, many.xbar);
            assert_eq!(one.residual, many.residual);
        }
        assert_eq!(batched.stats().rhs_served, 3);
        assert_eq!(batched.stats().solve_calls, 1);
        assert_eq!(batched.stats().max_batch, 3);
        assert_eq!(seq.stats().solve_calls, 3);

        // AsRef flexibility: a slice of borrowed slices works unchanged
        let refs: Vec<&[f32]> = bs.iter().map(|b| b.as_slice()).collect();
        let again = batched.solve_batch(&refs).unwrap();
        for (one, many) in singles.iter().zip(&again) {
            assert_eq!(one.xbar, many.xbar);
        }
    }

    #[test]
    fn session_matches_solver_facade() {
        // the ergonomic one-shot facade and a warm session agree
        let ds = GeneratorConfig::small_demo(16, 2).generate(14);
        let e = NativeEngine::new();
        let via_facade = crate::solver::DapcSolver::new(opts(10))
            .solve(&e, &ds.matrix, &ds.rhs, 2)
            .unwrap();
        let mut backend = InProcessBackend::new(&e, 2);
        let mut session = SolverSession::register(
            &mut backend,
            ds.matrix.clone(),
            apc_cfg(10, ApcVariant::Decomposed),
        )
        .unwrap();
        assert_eq!(session.solve(&ds.rhs).unwrap().xbar, via_facade.xbar);
    }

    #[test]
    fn trace_and_x_parts_options_rejected_at_register() {
        let ds = GeneratorConfig::small_demo(8, 1).generate(16);
        let e = NativeEngine::new();
        let configs = [
            SessionConfig::apc(ApcVariant::Decomposed).options(
                SolveOptions {
                    x_true: Some(ds.x_true.clone()),
                    ..Default::default()
                },
            ),
            SessionConfig::apc(ApcVariant::Decomposed).collect_x_parts(true),
        ];
        for config in configs {
            let mut backend = InProcessBackend::new(&e, 1);
            let err = SolverSession::register(
                &mut backend,
                ds.matrix.clone(),
                config,
            )
            .map(|_| ())
            .unwrap_err();
            assert!(err.to_string().contains("do not support"), "{err}");
        }
    }

    #[test]
    fn unregister_releases_backend_state() {
        let ds = GeneratorConfig::small_demo(12, 2).generate(17);
        let e = NativeEngine::new();
        let mut backend = InProcessBackend::new(&e, 2);
        let mut session = SolverSession::register(
            &mut backend,
            ds.matrix.clone(),
            apc_cfg(5, ApcVariant::Decomposed),
        )
        .unwrap();
        let first = session.solve(&ds.rhs).unwrap();
        session.unregister().unwrap();
        // a fresh registration over the same backend reproduces the
        // solve bit-for-bit — eviction loses no numerics, only time
        let mut again = SolverSession::register(
            &mut backend,
            ds.matrix.clone(),
            apc_cfg(5, ApcVariant::Decomposed),
        )
        .unwrap();
        assert_eq!(again.solve(&ds.rhs).unwrap().xbar, first.xbar);
    }

    #[test]
    fn per_rhs_histograms_sum_to_served_counter() {
        // the metrics-validate cross-check relies on this exact split:
        // k == 1 -> one warm observation, k > 1 -> k batched ones
        let _g = crate::obs::test_lock();
        crate::obs::set_enabled(true);
        // the registry is process-global and cumulative: diff baselines
        let warm0 = obs::histogram("service.warm_rhs_ns").count();
        let batch0 = obs::histogram("service.batch_rhs_ns").count();
        let served0 = obs::counter("service.rhs_served").get();

        let ds = GeneratorConfig::small_demo(14, 2).generate(21);
        let bs: Vec<Vec<f32>> = (0..3)
            .map(|i| {
                let mut g = crate::rng::seeded(700 + i);
                let x: Vec<f32> =
                    (0..ds.matrix.cols()).map(|_| g.normal_f32()).collect();
                let mut b = vec![0.0f32; ds.matrix.rows()];
                ds.matrix.spmv_into(&x, &mut b);
                b
            })
            .collect();
        let e = NativeEngine::new();
        let mut backend = InProcessBackend::new(&e, 2);
        let mut session = SolverSession::register(
            &mut backend,
            ds.matrix.clone(),
            apc_cfg(5, ApcVariant::Decomposed),
        )
        .unwrap();
        session.solve(&ds.rhs).unwrap();
        session.solve_batch(&bs).unwrap();

        let warm = obs::histogram("service.warm_rhs_ns").count() - warm0;
        let batch = obs::histogram("service.batch_rhs_ns").count() - batch0;
        let served = obs::counter("service.rhs_served").get() - served0;
        assert_eq!(warm, 1);
        assert_eq!(batch, 3);
        assert_eq!(served, warm + batch);
        assert!(
            obs::histogram("service.cold_register_ns").count() >= 1,
            "registration latency was not observed"
        );
        crate::obs::set_enabled(false);
    }

    #[test]
    fn bad_rhs_rejected() {
        let ds = GeneratorConfig::small_demo(8, 1).generate(15);
        let e = NativeEngine::new();
        let mut backend = InProcessBackend::new(&e, 1);
        let mut session = SolverSession::register(
            &mut backend,
            ds.matrix.clone(),
            apc_cfg(5, ApcVariant::Decomposed),
        )
        .unwrap();
        assert!(session.solve(&ds.rhs[..3]).is_err());
        assert!(session.solve_batch::<Vec<f32>>(&[]).is_err());
    }
}
