//! Central registry of every `DAPC_*` environment variable.
//!
//! This module is the **only** place in the tree allowed to call
//! `std::env::var` on a `DAPC_*` name — the `env-registry` rule of
//! [`crate::audit`] rejects raw reads anywhere else.  Funneling every
//! knob through one file keeps the process-level configuration surface
//! enumerable: `dapc kernels` prints [`REGISTRY`] with live values, docs
//! link here, and a new variable cannot be introduced without a name,
//! a help line, and a documented default.
//!
//! Accessors are intentionally *value-typed* (`bool` / `PathBuf`), not
//! string-returning: call sites express the decision they need, and the
//! string-matching convention (`"1"`, `"off"`, `"fast"`) lives here
//! exactly once.

use std::path::PathBuf;

/// One registered environment variable.
pub struct EnvVar {
    /// Full variable name (`DAPC_…`).
    pub name: &'static str,
    /// One-line semantics, printed by `dapc kernels`.
    pub help: &'static str,
    /// Behaviour when the variable is unset.
    pub default: &'static str,
}

/// Every `DAPC_*` variable the binary, tests, or benches consult.
pub const REGISTRY: [EnvVar; 6] = [
    EnvVar {
        name: "DAPC_METRICS",
        help: "metrics recording; \"off\" disables the global registry",
        default: "on",
    },
    EnvVar {
        name: "DAPC_FORCE_SCALAR",
        help: "\"1\" pins the lane-structured scalar kernels even when \
               AVX2+FMA is detected (bitwise-equal by contract)",
        default: "0 (runtime dispatch)",
    },
    EnvVar {
        name: "DAPC_KERNEL_TIER",
        help: "\"fast\" opts into the f32-FMA tier (per-backend \
               reproducible, not scalar-bitwise)",
        default: "deterministic",
    },
    EnvVar {
        name: "DAPC_QUICK",
        help: "\"1\" shrinks bench shapes/iterations to CI smoke size",
        default: "0",
    },
    EnvVar {
        name: "DAPC_FULL",
        help: "\"1\" expands benches to the full Table-1 sweep",
        default: "0",
    },
    EnvVar {
        name: "DAPC_BENCH_DIR",
        help: "directory BENCH_*.json bench reports are written into",
        default: ". (working directory)",
    },
];

/// The single raw read.  `name` must be a [`REGISTRY`] entry — accessors
/// below guarantee this; the debug assert catches drift if one is added
/// without registering it.
fn raw(name: &str) -> Option<String> {
    debug_assert!(
        REGISTRY.iter().any(|v| v.name == name),
        "unregistered env var {name}"
    );
    std::env::var(name).ok()
}

/// `DAPC_METRICS`: metrics recording is on unless the value is `off`.
pub fn metrics_enabled() -> bool {
    raw("DAPC_METRICS").map(|v| v != "off").unwrap_or(true)
}

/// `DAPC_FORCE_SCALAR=1`: pin the scalar kernel backend.
pub fn force_scalar() -> bool {
    raw("DAPC_FORCE_SCALAR").as_deref() == Some("1")
}

/// `DAPC_KERNEL_TIER=fast`: opt into the tier-1 f32-FMA microkernel.
pub fn fast_tier() -> bool {
    raw("DAPC_KERNEL_TIER").as_deref() == Some("fast")
}

/// `DAPC_QUICK=1`: smoke-test bench iteration counts.
pub fn quick_bench() -> bool {
    raw("DAPC_QUICK").as_deref() == Some("1")
}

/// `DAPC_FULL=1`: paper-scale bench workloads.
pub fn full_bench() -> bool {
    raw("DAPC_FULL").as_deref() == Some("1")
}

/// `DAPC_BENCH_DIR`: where bench JSON reports land (default: cwd).
pub fn bench_dir() -> PathBuf {
    raw("DAPC_BENCH_DIR").map(PathBuf::from).unwrap_or_else(|| PathBuf::from("."))
}

/// `(name, live value or "(unset)")` for every registered variable, in
/// registry order — the `dapc kernels` display.
pub fn snapshot() -> Vec<(&'static str, String)> {
    REGISTRY
        .iter()
        .map(|v| (v.name, raw(v.name).unwrap_or_else(|| "(unset)".into())))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_and_prefixed() {
        for (i, v) in REGISTRY.iter().enumerate() {
            assert!(v.name.starts_with("DAPC_"), "{} not DAPC_*", v.name);
            assert!(!v.help.is_empty() && !v.default.is_empty());
            for w in &REGISTRY[i + 1..] {
                assert_ne!(v.name, w.name, "duplicate registry entry");
            }
        }
    }

    #[test]
    fn snapshot_covers_the_whole_registry() {
        let snap = snapshot();
        assert_eq!(snap.len(), REGISTRY.len());
        for ((name, value), reg) in snap.iter().zip(REGISTRY.iter()) {
            assert_eq!(*name, reg.name);
            assert!(!value.is_empty());
        }
    }
}
