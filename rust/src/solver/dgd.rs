//! Distributed Gradient Descent baseline (Fig. 2's third curve, [5]).
//!
//! Each partition computes its local least-squares gradient
//! `g_j = A_j^T (A_j x - b_j)`; the leader applies
//! `x <- x - alpha * sum_j g_j`.  Same partitioning and engine interface
//! as the APC solvers so the comparison is apples-to-apples.

use std::time::Instant;

use crate::error::{DapcError, Result};
use crate::linalg::{norms, Matrix};
use crate::metrics::ConvergenceTrace;
use crate::partition::PartitionPlan;
use crate::sparse::CsrMatrix;

use super::engine::ComputeEngine;
use super::report::{residual_norm, SolveOptions, SolveReport};
use super::Solver;

/// DGD solver over the same partition layout as APC.
#[derive(Debug, Clone)]
pub struct DgdSolver {
    pub options: SolveOptions,
}

impl DgdSolver {
    pub fn new(options: SolveOptions) -> Self {
        Self { options }
    }

    /// A conservative step size from the Gershgorin bound on
    /// `sum_j A_j^T A_j` when `options.dgd_step <= 0`.
    fn step_size(&self, blocks: &[(Matrix, Vec<f32>)]) -> f32 {
        if self.options.dgd_step > 0.0 {
            return self.options.dgd_step;
        }
        // bound lambda_max(A^T A) <= max_i sum_j |G_ij| via column norms
        let n = blocks[0].0.cols();
        let mut colsq = vec![0.0f64; n];
        for (a, _) in blocks {
            for r in 0..a.rows() {
                for (c, v) in a.row(r).iter().enumerate() {
                    colsq[c] += (*v as f64) * (*v as f64);
                }
            }
        }
        let total: f64 = colsq.iter().sum();
        (1.0 / total.max(1e-12)) as f32
    }
}

impl Solver for DgdSolver {
    fn solve<E: ComputeEngine>(
        &self,
        engine: &E,
        a: &CsrMatrix,
        b: &[f32],
        j: usize,
    ) -> Result<SolveReport> {
        let (m, n) = a.shape();
        if b.len() != m {
            return Err(DapcError::Shape(format!(
                "rhs length {} != matrix rows {m}",
                b.len()
            )));
        }
        let opts = &self.options;
        let plan = PartitionPlan::contiguous(m, n, j)?;

        let t0 = Instant::now();
        let blocks: Vec<(Matrix, Vec<f32>)> =
            (0..j).map(|i| plan.extract(a, b, i)).collect();
        let alpha = self.step_size(&blocks);
        let mut x = vec![0.0f32; n];
        let init_time = t0.elapsed();

        let mut trace = opts.x_true.as_ref().map(|xt| {
            let mut tr = ConvergenceTrace::new("dgd");
            tr.push(0, norms::mse(&x, xt));
            tr
        });

        let t1 = Instant::now();
        // steady-state buffers, allocated once: per-block `A_j x` scratch
        // (block row counts differ), one gradient output, one f64 total
        let mut ax_ws: Vec<Vec<f32>> =
            blocks.iter().map(|(sub, _)| vec![0.0f32; sub.rows()]).collect();
        let mut grad = vec![0.0f32; n];
        let mut total_grad = vec![0.0f64; n];
        for t in 0..opts.epochs {
            total_grad.iter_mut().for_each(|v| *v = 0.0);
            for ((sub, rhs), ax) in blocks.iter().zip(ax_ws.iter_mut()) {
                engine.dgd_grad_into(sub, &x, rhs, ax, &mut grad)?;
                for (tg, gi) in total_grad.iter_mut().zip(&grad) {
                    *tg += *gi as f64;
                }
            }
            for (xi, g) in x.iter_mut().zip(&total_grad) {
                *xi -= alpha * (*g as f32);
            }
            if let (Some(tr), Some(xt)) = (&mut trace, &opts.x_true) {
                tr.push(t + 1, norms::mse(&x, xt));
            }
        }
        let iterate_time = t1.elapsed();
        let residual = residual_norm(a, b, &x);

        Ok(SolveReport {
            xbar: x.clone(),
            x_parts: vec![x],
            trace,
            residual: Some(residual),
            init_time,
            iterate_time,
            algorithm: "dgd",
            engine: engine.name(),
            epochs: opts.epochs,
        })
    }

    fn name(&self) -> &'static str {
        "dgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::engine::NativeEngine;
    use crate::sparse::generate::GeneratorConfig;

    #[test]
    fn dgd_reduces_mse() {
        let ds = GeneratorConfig::small_demo(16, 2).generate(9);
        let e = NativeEngine::new();
        let solver = DgdSolver::new(SolveOptions {
            epochs: 400,
            dgd_step: 0.0, // auto
            x_true: Some(ds.x_true.clone()),
            ..Default::default()
        });
        let report = solver.solve(&e, &ds.matrix, &ds.rhs, 2).unwrap();
        let tr = report.trace.unwrap();
        assert!(
            tr.final_mse().unwrap() < tr.initial_mse().unwrap() * 0.2,
            "{:?} -> {:?}",
            tr.initial_mse(),
            tr.final_mse()
        );
    }

    #[test]
    fn dgd_slower_than_apc_at_same_epochs() {
        // the Fig. 2 qualitative relationship: at equal epoch budgets APC
        // reaches far lower error than DGD
        let ds = GeneratorConfig::small_demo(24, 2).generate(10);
        let e = NativeEngine::new();
        let t = 40;
        let apc = crate::solver::DapcSolver::new(SolveOptions {
            epochs: t,
            x_true: Some(ds.x_true.clone()),
            ..Default::default()
        })
        .solve(&e, &ds.matrix, &ds.rhs, 2)
        .unwrap();
        let dgd = DgdSolver::new(SolveOptions {
            epochs: t,
            dgd_step: 0.0,
            x_true: Some(ds.x_true.clone()),
            ..Default::default()
        })
        .solve(&e, &ds.matrix, &ds.rhs, 2)
        .unwrap();
        assert!(
            apc.final_mse(&ds.x_true) < dgd.final_mse(&ds.x_true),
            "apc {} vs dgd {}",
            apc.final_mse(&ds.x_true),
            dgd.final_mse(&ds.x_true)
        );
    }

    #[test]
    fn explicit_step_size_used() {
        let ds = GeneratorConfig::small_demo(8, 1).generate(11);
        let e = NativeEngine::new();
        let solver = DgdSolver::new(SolveOptions {
            epochs: 1,
            dgd_step: 1e-5,
            ..Default::default()
        });
        let r = solver.solve(&e, &ds.matrix, &ds.rhs, 1).unwrap();
        assert_eq!(r.epochs, 1);
    }
}
