// Clean twin of `unsafe_undocumented.rs`: the SAFETY comment sits
// directly above the site, so under the pretend simd.rs path this file
// must audit clean.
pub fn first_byte(v: &[u8]) -> u8 {
    assert!(!v.is_empty());
    // SAFETY: the assert above proves the slice is non-empty, so the
    // pointer read is in-bounds.
    unsafe { *v.as_ptr() }
}
