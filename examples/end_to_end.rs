//! END-TO-END DRIVER — exercises every layer of the system on a real
//! (synthetic Schenk-like) workload and reports the paper's headline
//! metrics.  This is the run recorded in EXPERIMENTS.md.
//!
//! Pipeline:
//!   1. generate the c-27-like dataset (§5 shape: 18252 x 4563, scaled by
//!      default; `--full` for exact);
//!   2. round-trip it through MatrixMarket files (the paper's input path);
//!   3. solve with decomposed APC on the **XLA engine** (AOT Pallas/JAX
//!      artifacts via PJRT — Layers 1+2) across a **local worker cluster**
//!      (Layer 3 coordinator; `Leader::solve_apc` runs the same unified
//!      `solver::drive_apc` loop as the single-process solvers, over a
//!      `ClusterBackend`);
//!   4. solve with classical APC for the acceleration factor (Table 1);
//!   5. report §5's statistics: solution mu/sigma, MAE(init, 1 epoch),
//!      MSE vs the known solution, wall times.
//!
//! ```sh
//! cargo run --release --example end_to_end [-- --full] [--native]
//! ```

use std::path::Path;

use dapc::coordinator::LocalCluster;
use dapc::linalg::norms;
use dapc::prelude::*;
use dapc::runtime::executor::XlaExecutorHost;
use dapc::solver::{ApcVariant, XlaEngine};
use dapc::sparse::{generate::GeneratorConfig, matrix_market};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let full = args.iter().any(|a| a == "--full");
    let native = args.iter().any(|a| a == "--native");

    // §5 example: (18252 x 4563); default 1/9 scale => (2048 x 512),
    // which maps exactly onto the (768, 512) J=2 artifact bucket.
    let n = if full { 4563 } else { 512 };
    let epochs = if full { 95 } else { 60 };
    let j = 2;

    println!("=== DAPC end-to-end driver ===");
    println!("step 1: generate c-27-like dataset (n={n}, m={})", 4 * n);
    let ds = GeneratorConfig::schenk_like(n).generate(5);
    println!(
        "  {}x{}, {} nnz ({:.2}% sparse), dense mu={:.4} sigma={:.2}",
        ds.matrix.rows(),
        ds.matrix.cols(),
        ds.matrix.nnz(),
        ds.matrix.sparsity_pct(),
        ds.matrix.dense_mean(),
        ds.matrix.dense_std(),
    );

    println!("step 2: MatrixMarket round-trip (scipy.io.mmread analog)");
    let dir = Path::new("target/e2e_data");
    std::fs::create_dir_all(dir)?;
    matrix_market::write_matrix(&dir.join("A.mtx"), &ds.matrix)?;
    matrix_market::write_vector(&dir.join("b.mtx"), &ds.rhs)?;
    let a = matrix_market::read_matrix(&dir.join("A.mtx"))?;
    let b = matrix_market::read_vector(&dir.join("b.mtx"))?;
    assert_eq!(a.shape(), ds.matrix.shape());
    println!("  round-trip OK ({} nnz preserved)", a.nnz());

    let opts = SolveOptions {
        epochs,
        eta: 0.9,
        gamma: 0.9,
        x_true: Some(ds.x_true.clone()),
        ..Default::default()
    };

    println!(
        "step 3: decomposed APC, {} engine, {} worker cluster (J={j})",
        if native { "native" } else { "XLA/PJRT" },
        j
    );
    let decomposed = if native {
        let mut cluster = LocalCluster::spawn(j, NativeEngine::new)?;
        let r =
            cluster.leader.solve_apc(&a, &b, ApcVariant::Decomposed, &opts)?;
        let (sent, received) = cluster.leader.wire_bytes();
        println!(
            "  wire traffic: {:.2} MiB out, {:.2} MiB in",
            sent as f64 / (1024.0 * 1024.0),
            received as f64 / (1024.0 * 1024.0)
        );
        r
    } else {
        let host = XlaExecutorHost::spawn(Path::new("artifacts"))?;
        let exec = host.executor();
        let mut cluster =
            LocalCluster::spawn(j, move || XlaEngine::new(exec.clone()))?;
        cluster.leader.solve_apc(&a, &b, ApcVariant::Decomposed, &opts)?
    };
    println!("  {}", decomposed.summary());

    println!("step 4: classical APC baseline (acceleration factor)");
    let classical = if native {
        let mut cluster = LocalCluster::spawn(j, NativeEngine::new)?;
        cluster.leader.solve_apc(&a, &b, ApcVariant::Classical, &opts)?
    } else {
        let host = XlaExecutorHost::spawn(Path::new("artifacts"))?;
        let exec = host.executor();
        let mut cluster =
            LocalCluster::spawn(j, move || XlaEngine::new(exec.clone()))?;
        cluster.leader.solve_apc(&a, &b, ApcVariant::Classical, &opts)?
    };
    println!("  {}", classical.summary());

    println!("step 5: report");
    let tc = classical.total_time().as_secs_f64();
    let td = decomposed.total_time().as_secs_f64();
    println!(
        "  solution: mu={:.6} sigma={:.6}  (paper §5: mu~-0.0027 sigma~0.0763 for its b)",
        norms::mean(&decomposed.xbar),
        norms::std_dev(&decomposed.xbar)
    );
    let trace = decomposed.trace.as_ref().expect("trace");
    // paper §5: MAE between init solution and the 1-epoch solution is tiny
    let mse0 = trace.initial_mse().unwrap();
    let mse1 = trace.points.get(1).map(|&(_, m)| m).unwrap_or(mse0);
    println!("  MSE epoch0={mse0:.3e} epoch1={mse1:.3e} final={:.3e}", trace.final_mse().unwrap());
    println!(
        "  wall: classical {tc:.3}s vs decomposed {td:.3}s => acceleration {:.2}x",
        tc / td
    );
    let final_mse = decomposed.final_mse(&ds.x_true);
    assert!(final_mse < 1e-5, "end-to-end convergence failed: {final_mse:e}");
    println!("=== end_to_end OK (final MSE {final_mse:.3e}) ===");
    Ok(())
}
