//! The chunk-stable packing contract, enforced bitwise.
//!
//! `linalg::blas` promises that packing is a pure gather and that the
//! packed microkernel's f32 accumulation order for any output element is
//! a function of its (row, col, depth) tile coordinates alone — never of
//! which thread packed a panel or how the output columns were chunked
//! across workers.  That contract is what lets the QR trailing sweeps
//! run through the packed gemm while `householder_qr_pooled` stays
//! bitwise-identical to the serial factorization at any thread count.
//!
//! This suite proves the two load-bearing halves directly:
//!
//! 1. packing the same matrix with 1, 2 and 7 worker threads (each
//!    worker packing a disjoint set of panels) produces `assert_eq!`-
//!    identical buffers to the serial pack, and
//! 2. computing a packed gemm as disjoint column chunks — any chunk
//!    widths, any thread count — produces `assert_eq!`-identical output
//!    to the full-width serial call,
//!
//! swept across every `m % MR`, `n % NR` and `k % 8` remainder class so
//! fringe panels, fringe columns and ragged depths are all covered.

use dapc::linalg::blas::{self, Accum, GemmPath, KC};
use dapc::linalg::simd::{self, KernelTier, MR, NR};
use dapc::parallel::ThreadPool;
use dapc::rng::seeded;

fn rand_f32(len: usize, seed: u64) -> Vec<f32> {
    let mut g = seeded(seed);
    (0..len).map(|_| g.normal_f32()).collect()
}

fn assert_bits_eq(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length mismatch");
    for (i, (x, y)) in got.iter().zip(want).enumerate() {
        assert!(x.to_bits() == y.to_bits(), "{ctx}: element {i}: {x:?} vs {y:?}");
    }
}

/// Every remainder class of the microtile and depth-unroll dimensions,
/// at sizes that still exercise at least two full panels.
fn shape_sweep() -> Vec<(usize, usize, usize)> {
    let mut v = Vec::new();
    for rm in 0..MR {
        v.push((2 * MR + rm, 2 * NR + (rm * 3) % NR, 16 + (rm * 5) % 8));
    }
    for rn in 0..NR {
        v.push((2 * MR + rn % MR, 2 * NR + rn, 16 + (rn * 3) % 8));
    }
    for rk in 0..8 {
        v.push((2 * MR + rk % MR, 2 * NR + rk % NR, 16 + rk));
    }
    // degenerate edges: single fringe panel each way, and a depth past KC
    v.push((1, 1, 1));
    v.push((MR, NR, 8));
    v.push((MR - 1, NR + 1, KC + 3));
    v
}

/// Pack A row-panels with each panel packed by a pool task — the same
/// decomposition a parallel caller would use — into one shared buffer.
fn pack_a_pooled(src: &[f32], m: usize, k: usize, pool: &ThreadPool) -> Vec<f32> {
    let mut buf = vec![f32::NAN; blas::packed_a_len(m, k)];
    pool.scope(|s| {
        for (t, chunk) in buf.chunks_mut(k * MR).enumerate() {
            s.spawn(move || {
                let r0 = t * MR;
                let mr = MR.min(m - r0);
                // row-major src: rs = k, cs = 1; a panel is its own
                // one-panel pack (fringe rows zeroed inside)
                blas::pack_a_strided(&src[r0 * k..], k, 1, mr, k, chunk);
            });
        }
    });
    buf
}

/// Pack B column-panels the same way.
fn pack_b_pooled(src: &[f32], k: usize, n: usize, pool: &ThreadPool) -> Vec<f32> {
    let mut buf = vec![f32::NAN; blas::packed_b_len(k, n)];
    pool.scope(|s| {
        for (q, chunk) in buf.chunks_mut(k * NR).enumerate() {
            s.spawn(move || {
                let c0 = q * NR;
                let nr = NR.min(n - c0);
                blas::pack_b_strided(&src[c0..], n, 1, k, nr, chunk);
            });
        }
    });
    buf
}

#[test]
fn pooled_packing_is_bitwise_identical_across_thread_counts() {
    let pools: Vec<ThreadPool> = [1usize, 2, 7].iter().map(|&w| ThreadPool::new(w)).collect();
    for &(m, n, k) in &shape_sweep() {
        let a = rand_f32(m * k, 7_000 + (m * 131 + k) as u64);
        let b = rand_f32(k * n, 8_000 + (k * 131 + n) as u64);

        let mut a_ref = vec![0.0f32; blas::packed_a_len(m, k)];
        blas::pack_a_strided(&a, k, 1, m, k, &mut a_ref);
        let mut b_ref = vec![0.0f32; blas::packed_b_len(k, n)];
        blas::pack_b_strided(&b, n, 1, k, n, &mut b_ref);

        for pool in &pools {
            let got_a = pack_a_pooled(&a, m, k, pool);
            assert_bits_eq(
                &got_a,
                &a_ref,
                &format!("a_pack ({m},{n},{k}) {} workers", pool.size()),
            );
            let got_b = pack_b_pooled(&b, k, n, pool);
            assert_bits_eq(
                &got_b,
                &b_ref,
                &format!("b_pack ({m},{n},{k}) {} workers", pool.size()),
            );
        }
    }
}

#[test]
fn column_chunked_packed_gemm_is_bitwise_identical_to_full_width() {
    // tier-0 pinned: the suite's bitwise claims are the tier-0 contract
    // (tier-1 is chunk-stable too, but kernel_tier.rs owns that story)
    let backend = simd::active();
    let tier = KernelTier::Deterministic;
    let pools: Vec<ThreadPool> = [1usize, 2, 7].iter().map(|&w| ThreadPool::new(w)).collect();
    for &(m, n, k) in &shape_sweep() {
        let a = rand_f32(m * k, 9_000 + (m * 131 + k) as u64);
        let b = rand_f32(k * n, 10_000 + (k * 131 + n) as u64);
        let mut a_pack = vec![0.0f32; blas::packed_a_len(m, k)];
        blas::pack_a_strided(&a, k, 1, m, k, &mut a_pack);

        // full-width serial reference; C is column-major (rs = 1,
        // cs = m) so a column chunk is one contiguous slice — exactly
        // the layout the QR trailing sweep hands its pooled workers
        let mut c_ref = vec![0.0f32; m * n];
        let mut b_pack = vec![0.0f32; blas::packed_b_len(k, n)];
        blas::pack_b_strided(&b, n, 1, k, n, &mut b_pack);
        blas::packed_gemm_into(
            backend,
            tier,
            m,
            n,
            k,
            &a_pack,
            &b_pack,
            Accum::Store,
            &mut c_ref,
            1,
            m,
        );

        // the same product as disjoint column chunks, packed and computed
        // per-chunk by pool workers — the QR trailing-sweep decomposition
        for pool in &pools {
            for &parts in &[2usize, 3, 7] {
                let mut c = vec![f32::NAN; m * n];
                let chunk = n.div_ceil(parts);
                let ap = &a_pack[..];
                pool.scope(|s| {
                    for (idx, head) in c.chunks_mut(chunk * m).enumerate() {
                        let c0 = idx * chunk;
                        let nc = head.len() / m;
                        let bcol = &b[c0..];
                        s.spawn(move || {
                            let mut bp = vec![0.0f32; blas::packed_b_len(k, nc)];
                            blas::pack_b_strided(bcol, n, 1, k, nc, &mut bp);
                            blas::packed_gemm_into(
                                backend,
                                tier,
                                m,
                                nc,
                                k,
                                ap,
                                &bp,
                                Accum::Store,
                                head,
                                1,
                                m,
                            );
                        });
                    }
                });
                let ctx = format!(
                    "chunked gemm ({m},{n},{k}) parts={parts} workers={}",
                    pool.size()
                );
                assert_bits_eq(&c, &c_ref, &ctx);
            }
        }
    }
}

#[test]
fn direct_path_agrees_with_packed_path_on_fringe_shapes() {
    // the per-shape dispatch (Auto) must be a pure function of shape, and
    // the two paths it picks between must agree bitwise under tier-0 —
    // re-asserted here through the public Matrix entrypoint
    use dapc::linalg::Matrix;
    let backend = simd::active();
    let tier = KernelTier::Deterministic;
    for &(m, n, k) in &[(1usize, 3usize, 9usize), (3, 1, 17), (MR - 1, NR - 1, 40)] {
        let mut g = seeded((m * 1009 + n * 31 + k) as u64);
        let a = Matrix::from_fn(m, k, |_, _| g.normal_f32());
        let b = Matrix::from_fn(k, n, |_, _| g.normal_f32());
        let mut c_direct = Matrix::zeros(m, n);
        blas::gemm_into_on(backend, tier, GemmPath::Direct, &a, &b, &mut c_direct);
        let mut c_packed = Matrix::zeros(m, n);
        blas::gemm_into_on(backend, tier, GemmPath::Packed, &a, &b, &mut c_packed);
        let mut c_auto = Matrix::zeros(m, n);
        blas::gemm_into_on(backend, tier, GemmPath::Auto, &a, &b, &mut c_auto);
        for i in 0..m {
            for j in 0..n {
                assert_eq!(
                    c_direct[(i, j)].to_bits(),
                    c_packed[(i, j)].to_bits(),
                    "direct vs packed ({m},{n},{k}) at ({i},{j})"
                );
                assert_eq!(
                    c_direct[(i, j)].to_bits(),
                    c_auto[(i, j)].to_bits(),
                    "direct vs auto ({m},{n},{k}) at ({i},{j})"
                );
            }
        }
    }
}
