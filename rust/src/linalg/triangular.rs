//! Triangular solves — the O(n^2) backward substitution at the heart of
//! the paper's decomposition (eqs. (2)-(3)) plus the forward variant used
//! by the fat-regime init.

use super::Matrix;

/// Solve `R x = c` for upper-triangular `R` by backward substitution.
///
/// Implements paper eqs. (2)-(3): the n-th component first, then each
/// p-th component from the previously solved ones — O(n^2) total versus
/// the O(n^3) Gauss-Jordan inversion of classical APC.
pub fn back_substitute(r: &Matrix, c: &[f32]) -> Vec<f32> {
    let n = r.rows();
    assert_eq!(r.cols(), n);
    assert_eq!(c.len(), n);
    let mut x = vec![0.0f32; n];
    for p in (0..n).rev() {
        let row = r.row(p);
        let mut s = 0.0f64;
        for k in p + 1..n {
            s += row[k] as f64 * x[k] as f64;
        }
        x[p] = ((c[p] as f64 - s) / row[p] as f64) as f32;
    }
    x
}

/// Solve `L x = c` for lower-triangular `L` by forward substitution.
pub fn forward_substitute(l: &Matrix, c: &[f32]) -> Vec<f32> {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(c.len(), n);
    let mut x = vec![0.0f32; n];
    for p in 0..n {
        let row = l.row(p);
        let mut s = 0.0f64;
        for k in 0..p {
            s += row[k] as f64 * x[k] as f64;
        }
        x[p] = ((c[p] as f64 - s) / row[p] as f64) as f32;
    }
    x
}

/// Explicit upper-triangular inverse via the recurrence the paper quotes
/// (`r*_{c-1,c} ≈ -r_{c-1,c} / (r_{c-1,c-1} r_{c,c})` generalized) —
/// kept for the init-method ablation; the solvers use back_substitute.
pub fn upper_triangular_inverse(r: &Matrix) -> Matrix {
    let n = r.rows();
    assert_eq!(r.cols(), n);
    let mut inv = Matrix::zeros(n, n);
    // column-by-column: solve R x = e_j
    for j in 0..n {
        let mut e = vec![0.0f32; n];
        e[j] = 1.0;
        let x = back_substitute(r, &e);
        for i in 0..n {
            inv[(i, j)] = x[i];
        }
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::{gemm, gemv};
    use crate::rng::seeded;

    fn upper(n: usize, seed: u64) -> Matrix {
        let mut g = seeded(seed);
        let scale = 1.0 / (n as f32).sqrt();
        Matrix::from_fn(n, n, |i, j| {
            if j > i {
                g.normal_f32() * scale
            } else if j == i {
                3.0 + g.uniform_f32()
            } else {
                0.0
            }
        })
    }

    #[test]
    fn back_substitute_residual() {
        for &n in &[1usize, 2, 8, 32, 100] {
            let r = upper(n, n as u64);
            let mut g = seeded(n as u64 + 1);
            let c: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
            let x = back_substitute(&r, &c);
            let mut rx = vec![0.0f32; n];
            gemv(&r, &x, &mut rx);
            for i in 0..n {
                assert!((rx[i] - c[i]).abs() < 1e-4, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn forward_substitute_residual() {
        for &n in &[1usize, 3, 16, 64] {
            let l = upper(n, n as u64 * 7).transpose();
            let mut g = seeded(n as u64 + 2);
            let c: Vec<f32> = (0..n).map(|_| g.normal_f32()).collect();
            let x = forward_substitute(&l, &c);
            let mut lx = vec![0.0f32; n];
            gemv(&l, &x, &mut lx);
            for i in 0..n {
                assert!((lx[i] - c[i]).abs() < 1e-4, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn triangular_inverse_is_inverse() {
        let r = upper(24, 5);
        let inv = upper_triangular_inverse(&r);
        let prod = gemm(&r, &inv);
        assert!(prod.max_abs_diff(&Matrix::eye(24)) < 1e-4);
        // inverse of upper triangular is upper triangular
        for i in 0..24 {
            for j in 0..i {
                assert!(inv[(i, j)].abs() < 1e-6);
            }
        }
    }

    #[test]
    fn property_sweep() {
        let mut g = seeded(123);
        for case in 0..20 {
            let n = g.gen_range(1, 48);
            let r = upper(n, 500 + case);
            let mut rg = seeded(600 + case);
            let c: Vec<f32> = (0..n).map(|_| rg.normal_f32()).collect();
            let x = back_substitute(&r, &c);
            let mut rx = vec![0.0f32; n];
            gemv(&r, &x, &mut rx);
            let err = rx.iter().zip(&c).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
            assert!(err < 1e-3, "case {case} n={n} err={err}");
        }
    }
}
